"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel (interpret=True) is checked against its pure-jnp
oracle in kernels/ref.py — exact equality for integer kernels, allclose
for float — across fixed shapes and hypothesis-driven shape/value sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.histogram import NUM_BINS, VAL_BLOCK, histogram
from compile.kernels.parity import LANE_BLOCK, parity
from compile.kernels.particle_filter import PART_BLOCK, particle_filter

jax.config.update("jax_platform_name", "cpu")


def rand_i32(rng, shape):
    return jnp.asarray(rng.integers(-(2**31), 2**31 - 1, size=shape, dtype=np.int64)
                       .astype(np.int32))


def rand_particles(rng, n):
    p = rng.standard_normal((n, 8)).astype(np.float32)
    p[:, 7] = np.arange(n, dtype=np.float32)  # ids
    return jnp.asarray(p)


# --------------------------------------------------------------- parity ---

class TestParity:
    @pytest.mark.parametrize("k", [2, 4, 8])
    @pytest.mark.parametrize("lanes", [LANE_BLOCK, 4 * LANE_BLOCK])
    def test_matches_ref_tiled(self, k, lanes):
        rng = np.random.default_rng(k * 1000 + lanes)
        stripe = rand_i32(rng, (k, lanes))
        out = parity(stripe)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(ref.parity_ref(stripe)))

    def test_ragged_lanes_fallback(self):
        rng = np.random.default_rng(7)
        stripe = rand_i32(rng, (4, 1000))  # not a multiple of LANE_BLOCK
        out = parity(stripe)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(ref.parity_ref(stripe)))

    def test_parity_reconstructs_lost_unit(self):
        """RAID property: XOR of parity + survivors == the lost unit."""
        rng = np.random.default_rng(11)
        stripe = rand_i32(rng, (4, LANE_BLOCK))
        p = np.asarray(parity(stripe))
        s = np.asarray(stripe)
        lost = 2
        recon = p.copy()
        for k in range(4):
            if k != lost:
                recon ^= s[k]
        np.testing.assert_array_equal(recon, s[lost])

    def test_parity_of_identical_pair_is_zero(self):
        rng = np.random.default_rng(13)
        unit = rand_i32(rng, (1, 256))
        stripe = jnp.concatenate([unit, unit], axis=0)
        assert not np.asarray(parity(stripe)).any()

    @settings(max_examples=20, deadline=None)
    @given(k=st.integers(2, 8), lanes=st.sampled_from([64, 256, 1000]),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, k, lanes, seed):
        rng = np.random.default_rng(seed)
        stripe = rand_i32(rng, (k, lanes))
        np.testing.assert_array_equal(np.asarray(parity(stripe)),
                                      np.asarray(ref.parity_ref(stripe)))


# ------------------------------------------------------- particle filter ---

class TestParticleFilter:
    @pytest.mark.parametrize("n", [PART_BLOCK, 4 * PART_BLOCK, 1000])
    def test_matches_ref(self, n):
        rng = np.random.default_rng(n)
        parts = rand_particles(rng, n)
        thr = jnp.asarray([0.5], dtype=jnp.float32)
        energy, mask = particle_filter(parts, thr)
        e_ref, m_ref = ref.particle_filter_ref(parts, thr)
        np.testing.assert_allclose(np.asarray(energy), np.asarray(e_ref),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(m_ref))

    def test_zero_threshold_selects_all_moving(self):
        rng = np.random.default_rng(3)
        parts = rand_particles(rng, 256)
        thr = jnp.asarray([0.0], dtype=jnp.float32)
        energy, mask = particle_filter(parts, thr)
        assert (np.asarray(mask) == (np.asarray(energy) > 0)).all()

    def test_huge_threshold_selects_none(self):
        rng = np.random.default_rng(4)
        parts = rand_particles(rng, 256)
        thr = jnp.asarray([1e30], dtype=jnp.float32)
        _, mask = particle_filter(parts, thr)
        assert not np.asarray(mask).any()

    def test_energy_nonnegative_and_mass_scaled(self):
        """E = 0.5|q|v^2: doubling q doubles energy."""
        rng = np.random.default_rng(5)
        parts = np.asarray(rand_particles(rng, 128))
        parts2 = parts.copy()
        parts2[:, 6] *= 2.0
        thr = jnp.asarray([0.0], dtype=jnp.float32)
        e1, _ = particle_filter(jnp.asarray(parts), thr)
        e2, _ = particle_filter(jnp.asarray(parts2), thr)
        assert (np.asarray(e1) >= 0).all()
        np.testing.assert_allclose(np.asarray(e2), 2 * np.asarray(e1), rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([32, 500, 4096]), thr=st.floats(0.0, 5.0),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, n, thr, seed):
        rng = np.random.default_rng(seed)
        parts = rand_particles(rng, n)
        t = jnp.asarray([thr], dtype=jnp.float32)
        energy, mask = particle_filter(parts, t)
        e_ref, m_ref = ref.particle_filter_ref(parts, t)
        np.testing.assert_allclose(np.asarray(energy), np.asarray(e_ref),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(m_ref))


# -------------------------------------------------------------- histogram ---

class TestHistogram:
    @pytest.mark.parametrize("n", [VAL_BLOCK, 4 * VAL_BLOCK, 777])
    def test_matches_ref(self, n):
        rng = np.random.default_rng(n)
        vals = jnp.asarray(rng.uniform(-1, 11, n).astype(np.float32))
        vrange = jnp.asarray([0.0, 10.0], dtype=jnp.float32)
        out = histogram(vals, vrange)
        expect = ref.histogram_ref(vals, vrange[0], vrange[1], NUM_BINS)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))

    def test_total_count_preserved(self):
        """Clamping semantics: every value lands in exactly one bin."""
        rng = np.random.default_rng(9)
        n = 2 * VAL_BLOCK
        vals = jnp.asarray(rng.normal(5, 20, n).astype(np.float32))
        out = histogram(vals, jnp.asarray([0.0, 10.0], dtype=jnp.float32))
        assert float(np.asarray(out).sum()) == float(n)

    def test_single_bin_concentration(self):
        vals = jnp.full((VAL_BLOCK,), 3.14, dtype=jnp.float32)
        out = np.asarray(histogram(vals, jnp.asarray([0.0, 6.4],
                                                     dtype=jnp.float32)))
        assert out.max() == VAL_BLOCK and (out > 0).sum() == 1

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([64, 1000, 8192]),
           lo=st.floats(-5, 0), span=st.floats(1, 20),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, n, lo, span, seed):
        rng = np.random.default_rng(seed)
        vals = jnp.asarray(rng.uniform(lo - 1, lo + span + 1, n)
                           .astype(np.float32))
        vrange = jnp.asarray([lo, lo + span], dtype=jnp.float32)
        out = histogram(vals, vrange)
        expect = ref.histogram_ref(vals, vrange[0], vrange[1], NUM_BINS)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
