"""AOT path tests: every export lowers to parseable HLO text + manifest."""

import json
import os

from compile import aot, model


def test_lower_every_export():
    for name in model.EXPORTS:
        text, entry = aot.lower_export(name)
        assert "HloModule" in text, name
        assert entry["name"] == name
        assert entry["num_outputs"] >= 1
        for inp in entry["inputs"]:
            assert inp["dtype"] in ("float32", "int32")


def test_manifest_written(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--outdir", str(tmp_path), "--only", "parity_k4"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    assert (tmp_path / "parity_k4.hlo.txt").exists()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest[0]["name"] == "parity_k4"
    assert manifest[0]["inputs"][0]["shape"] == [4, 16384]
