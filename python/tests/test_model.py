"""L2 graph tests: semantics + output shapes of every AOT export."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_sns_parity_roundtrip():
    rng = np.random.default_rng(0)
    stripe = jnp.asarray(
        rng.integers(-(2**31), 2**31 - 1, (4, 16384), dtype=np.int64)
        .astype(np.int32))
    (p,) = model.sns_parity(stripe)
    np.testing.assert_array_equal(np.asarray(p),
                                  np.asarray(ref.parity_ref(stripe)))


def test_postprocess_stats_consistent():
    rng = np.random.default_rng(1)
    parts = jnp.asarray(rng.standard_normal((16384, 8)).astype(np.float32))
    thr = jnp.asarray([1.0], dtype=jnp.float32)
    energies, mask, stats = model.postprocess(parts, thr)
    e = np.asarray(energies)
    m = np.asarray(mask)
    s = np.asarray(stats)
    assert s.shape == (4,)
    np.testing.assert_allclose(s[0], m.sum(), rtol=1e-6)
    np.testing.assert_allclose(s[1], (e * m).sum(), rtol=1e-5)
    np.testing.assert_allclose(s[2], e.max(), rtol=1e-6)
    np.testing.assert_allclose(s[3], e.mean(), rtol=1e-5)


def test_alf_histogram_moments():
    rng = np.random.default_rng(2)
    vals = jnp.asarray(rng.uniform(0, 10, 65536).astype(np.float32))
    counts, moments = model.alf_histogram(
        vals, jnp.asarray([0.0, 10.0], dtype=jnp.float32))
    assert counts.shape == (64,)
    assert float(np.asarray(counts).sum()) == 65536.0
    v = np.asarray(vals)
    np.testing.assert_allclose(np.asarray(moments)[1], v.mean(), rtol=1e-4)


def test_integrity_digest_detects_corruption():
    rng = np.random.default_rng(3)
    blocks = jnp.asarray(
        rng.integers(-(2**31), 2**31 - 1, (16, 4096), dtype=np.int64)
        .astype(np.int32))
    (d1,) = model.integrity_digest(blocks)
    corrupted = np.asarray(blocks).copy()
    corrupted[5, 100] ^= 0x1
    (d2,) = model.integrity_digest(jnp.asarray(corrupted))
    assert (np.asarray(d1)[5] != np.asarray(d2)[5]).any()
    # other blocks unaffected
    np.testing.assert_array_equal(np.asarray(d1)[[0, 1, 15]],
                                  np.asarray(d2)[[0, 1, 15]])


def test_integrity_digest_detects_swap():
    """The weighted sum catches lane reordering a plain sum misses."""
    blocks = np.zeros((1, 4096), dtype=np.int32)
    blocks[0, 0], blocks[0, 1] = 7, 9
    (d1,) = model.integrity_digest(jnp.asarray(blocks))
    blocks[0, 0], blocks[0, 1] = 9, 7
    (d2,) = model.integrity_digest(jnp.asarray(blocks))
    assert np.asarray(d1)[0, 0] == np.asarray(d2)[0, 0]  # plain sum equal
    assert np.asarray(d1)[0, 1] != np.asarray(d2)[0, 1]  # weighted differs


def test_every_export_lowers_and_runs():
    """Each EXPORTS entry must lower AND execute with zeros inputs."""
    for name, (fn, builder) in model.EXPORTS.items():
        specs = builder()
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        out = jax.jit(fn)(*args)
        leaves = jax.tree_util.tree_leaves(out)
        assert len(leaves) >= 1, name
