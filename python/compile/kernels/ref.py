"""Pure-jnp oracles for the SAGE L1 Pallas kernels.

Each function here is the correctness reference for the identically-named
Pallas kernel in this package. pytest (python/tests/) asserts allclose /
exact equality between kernel and oracle across shape/dtype sweeps; the
oracles are also what the L2 graphs are validated against before AOT.
"""

import jax
import jax.numpy as jnp


def parity_ref(stripe: jnp.ndarray) -> jnp.ndarray:
    """XOR parity across the data units of a stripe.

    ``stripe`` has shape (K, U_lanes) with an integer dtype: K data units,
    each of U_lanes 32-bit lanes. Returns the (U_lanes,) parity unit —
    the bitwise XOR of all K data units (RAID-5 / SNS single parity).
    """
    out = stripe[0]
    for k in range(1, stripe.shape[0]):
        out = jnp.bitwise_xor(out, stripe[k])
    return out


def particle_energy_ref(particles: jnp.ndarray) -> jnp.ndarray:
    """Kinetic energy per particle.

    ``particles`` has shape (N, 8) float32 with columns
    (x, y, z, u, v, w, q, id) — the paper's stream element (§4.2).
    Energy is 0.5*|q|*(u^2+v^2+w^2), using |q| as the mass proxy the
    iPIC3D post-processing uses for charged macro-particles.
    """
    u, v, w, q = particles[:, 3], particles[:, 4], particles[:, 5], particles[:, 6]
    return 0.5 * jnp.abs(q) * (u * u + v * v + w * w)


def particle_filter_ref(particles: jnp.ndarray, threshold: jnp.ndarray):
    """Energy filter: (energies, mask) where mask=1.0 iff energy > threshold."""
    energy = particle_energy_ref(particles)
    mask = (energy > threshold).astype(jnp.float32)
    return energy, mask


def histogram_ref(values: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                  num_bins: int) -> jnp.ndarray:
    """Uniform-bin histogram over [lo, hi); out-of-range values are clamped
    into the first/last bin (ALF log-analytics semantics: everything is
    counted). Returns float32 counts of shape (num_bins,)."""
    width = (hi - lo) / num_bins
    idx = jnp.floor((values - lo) / width).astype(jnp.int32)
    idx = jnp.clip(idx, 0, num_bins - 1)
    one_hot = jax.nn.one_hot(idx, num_bins, dtype=jnp.float32)
    return one_hot.sum(axis=0)
