"""L1 Pallas kernel: SNS (Server Network Striping) XOR parity.

The distributed-RAID write path of Mero computes, for every stripe of K
data units, a parity unit P = D_0 ^ D_1 ^ ... ^ D_{K-1} (§3.2.1
"Layouts" / "Server Network Striping"). This is the storage-side compute
hot-spot: every full-stripe write runs it over unit_size bytes * K.

Hardware adaptation (DESIGN.md §3): stripe units map to VMEM tiles.
The BlockSpec grid walks the lane axis in LANE_BLOCK-sized tiles so a
(K, LANE_BLOCK) window is resident in VMEM per grid step; the XOR
reduction over K is VPU work. interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lanes (i32) per VMEM tile. 8 units * 2048 lanes * 4 B = 64 KiB per tile,
# comfortably inside a TPU core's ~16 MiB VMEM with double buffering.
LANE_BLOCK = 2048


def _parity_kernel(stripe_ref, out_ref, *, k: int):
    """XOR-reduce the K axis of one (K, LANE_BLOCK) tile."""
    acc = stripe_ref[0, :]
    # K is a compile-time constant: the loop fully unrolls into a
    # vectorized XOR tree.
    for i in range(1, k):
        acc = jnp.bitwise_xor(acc, stripe_ref[i, :])
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def parity(stripe: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Compute the XOR parity unit of ``stripe`` (shape (K, U_lanes) i32).

    U_lanes must be a multiple of LANE_BLOCK for the tiled fast path;
    smaller/ragged inputs fall back to a single-tile call.
    """
    k, lanes = stripe.shape
    if lanes % LANE_BLOCK == 0 and lanes >= LANE_BLOCK:
        block = LANE_BLOCK
        grid = (lanes // LANE_BLOCK,)
    else:
        block = lanes
        grid = (1,)
    return pl.pallas_call(
        functools.partial(_parity_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((lanes,), stripe.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((k, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(stripe)
