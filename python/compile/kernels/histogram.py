"""L1 Pallas kernel: uniform-bin histogram (ALF log analytics).

The ALF use case (§2, challenge 5) "performs analytics on data
consumption log files"; its shipped function is a histogram over log
record values (sizes, latencies). Computed in-storage so raw logs never
cross the network (§3.2.1 "Minimize Data Movement").

Hardware adaptation: the one-hot/accumulate formulation turns the
histogram into a (VAL_BLOCK, NUM_BINS) one-hot matrix summed over rows —
dense VPU/MXU-friendly work instead of scatter (TPUs have no fast
scatter). The grid walks value blocks; each grid step accumulates into
the same output tile (revisited output => accumulation pattern).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VAL_BLOCK = 8192
NUM_BINS = 64


def _hist_kernel(vals_ref, range_ref, out_ref, *, num_bins: int):
    """Accumulate one VAL_BLOCK tile's bin counts into out_ref."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lo = range_ref[0]
    hi = range_ref[1]
    width = (hi - lo) / num_bins
    idx = jnp.floor((vals_ref[...] - lo) / width).astype(jnp.int32)
    idx = jnp.clip(idx, 0, num_bins - 1)
    # one-hot accumulate: (B, num_bins) -> (num_bins,)
    bins = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], num_bins), 1)
    one_hot = (idx[:, None] == bins).astype(jnp.float32)
    out_ref[...] += one_hot.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("num_bins", "interpret"))
def histogram(values: jnp.ndarray, value_range: jnp.ndarray,
              num_bins: int = NUM_BINS, interpret: bool = True) -> jnp.ndarray:
    """Histogram of ``values`` (N,) f32 over [range[0], range[1]) with
    ``num_bins`` uniform bins; out-of-range values clamp to edge bins.
    ``value_range`` is a shape-(2,) f32 array (lo, hi)."""
    n = values.shape[0]
    if n % VAL_BLOCK == 0 and n >= VAL_BLOCK:
        block = VAL_BLOCK
        grid = (n // VAL_BLOCK,)
    else:
        block = n
        grid = (1,)
    return pl.pallas_call(
        functools.partial(_hist_kernel, num_bins=num_bins),
        out_shape=jax.ShapeDtypeStruct((num_bins,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((num_bins,), lambda i: (0,)),
        interpret=interpret,
    )(values, value_range)
