"""L1 Pallas kernel: particle energy filter (iPIC3D post-processing).

This is the compute payload that SAGE function-ships to storage (§3.2.1
"Function Shipping") and that the MPI-stream consumers run on incoming
particle streams (§4.2, Fig 6/7): compute each particle's kinetic energy
and a high-energy mask so only interesting particles are tracked /
visualized.

Stream element layout (§4.2): 8 f32 scalars per particle —
(x, y, z, u, v, w, q, id).

Hardware adaptation: particles are tiled along N in PART_BLOCK rows; an
(PART_BLOCK, 8) tile is one VMEM window (PART_BLOCK*8*4 B = 128 KiB at
4096). Energy + mask are elementwise VPU ops; no MXU needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PART_BLOCK = 4096  # particles per VMEM tile


def _filter_kernel(parts_ref, thr_ref, energy_ref, mask_ref):
    """Energy + threshold mask for one (PART_BLOCK, 8) particle tile."""
    u = parts_ref[:, 3]
    v = parts_ref[:, 4]
    w = parts_ref[:, 5]
    q = parts_ref[:, 6]
    energy = 0.5 * jnp.abs(q) * (u * u + v * v + w * w)
    energy_ref[...] = energy
    mask_ref[...] = (energy > thr_ref[0]).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def particle_filter(particles: jnp.ndarray, threshold: jnp.ndarray,
                    interpret: bool = True):
    """(energies, mask) for ``particles`` (N, 8) f32; mask=1.0 where
    energy > threshold (threshold is a shape-(1,) f32 array)."""
    n = particles.shape[0]
    if n % PART_BLOCK == 0 and n >= PART_BLOCK:
        block = PART_BLOCK
        grid = (n // PART_BLOCK,)
    else:
        block = n
        grid = (1,)
    return pl.pallas_call(
        _filter_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 8), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        interpret=interpret,
    )(particles, threshold)
