"""AOT: lower every L2 export to HLO text + a manifest for the rust runtime.

HLO *text* (NOT .serialize()) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
(what the published `xla` crate binds) rejects; the text parser reassigns
ids, so text round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --outdir ../artifacts
Outputs: artifacts/<name>.hlo.txt per EXPORTS entry, artifacts/manifest.json.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import EXPORTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unpacks a tuple literal)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_export(name: str):
    """Lower one EXPORTS entry; returns (hlo_text, manifest_entry)."""
    fn, args_builder = EXPORTS[name]
    example_args = args_builder()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out_avals = lowered.out_info
    entry = {
        "name": name,
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
        ],
        "num_outputs": len(jax.tree_util.tree_leaves(out_avals)),
    }
    return text, entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of export names")
    # legacy single-file mode used by the original scaffold Makefile
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    outdir = args.outdir
    if args.out:
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    names = args.only or list(EXPORTS)
    manifest = []
    for name in names:
        text, entry = lower_export(name)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(entry)
        print(f"wrote {path} ({len(text)} chars, {entry['num_outputs']} outputs)")

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(outdir, 'manifest.json')} ({len(manifest)} entries)")


if __name__ == "__main__":
    main()
