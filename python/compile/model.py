"""L2: the SAGE compute graphs that get function-shipped to storage.

Each public function here is a complete jax computation that (a) calls
the L1 Pallas kernels for its hot-spot and (b) adds the surrounding
reductions/statistics in plain jnp so everything lowers into one HLO
module. ``aot.py`` lowers every entry in ``EXPORTS`` to
``artifacts/<name>.hlo.txt`` which the rust runtime loads via PJRT.

All functions return a tuple (lowered with return_tuple=True) so the
rust side can uniformly unpack a tuple literal.
"""

import jax
import jax.numpy as jnp

from .kernels.histogram import histogram
from .kernels.parity import parity
from .kernels.particle_filter import particle_filter


def sns_parity(stripe):
    """XOR parity for one SNS stripe: (K, U_lanes) i32 -> ((U_lanes,) i32,).

    The Mero SNS write path ships full stripes here; the returned unit is
    written to the parity device of the parity group.
    """
    return (parity(stripe),)


def postprocess(particles, threshold):
    """iPIC3D particle post-processing (Fig 6/7 payload).

    particles: (N, 8) f32 rows (x,y,z,u,v,w,q,id); threshold: (1,) f32.
    Returns (energies (N,), mask (N,), stats (4,)) where stats =
    [selected_count, selected_energy_sum, max_energy, mean_energy].
    The consumer uses `mask` to compact the high-energy particles into
    the VTK output and `stats` for the runtime dashboard (ADDB).
    """
    energies, mask = particle_filter(particles, threshold)
    count = mask.sum()
    sel_sum = (energies * mask).sum()
    stats = jnp.stack([count, sel_sum, energies.max(), energies.mean()])
    return energies, mask, stats


def alf_histogram(values, value_range):
    """ALF log-file analytics: histogram + moments, computed in-storage.

    values: (N,) f32; value_range: (2,) f32 (lo, hi).
    Returns (counts (64,) f32, moments (3,) f32 = [sum, mean, var]).
    """
    counts = histogram(values, value_range)
    mean = values.mean()
    var = ((values - mean) ** 2).mean()
    moments = jnp.stack([values.sum(), mean, var])
    return counts, moments


def integrity_digest(blocks):
    """Advanced integrity checking (§3.2.3 "HSM and Data Integrity").

    blocks: (B, L) i32 — B object blocks of L 32-bit lanes. Returns a
    (B, 2) i32 digest per block: [wrapping lane sum, wrapping weighted
    sum] (a Fletcher-style pair; the weighted sum catches reorderings
    that a plain sum misses). Pure jnp — the hot-spot is the memory
    walk, which XLA fuses into a single pass.
    """
    b, l = blocks.shape
    weights = jnp.arange(1, l + 1, dtype=jnp.int32)
    s1 = blocks.sum(axis=1)
    s2 = (blocks * weights[None, :]).sum(axis=1)
    return (jnp.stack([s1, s2], axis=1),)


# --- AOT export table -----------------------------------------------------
# name -> (function, example-input builder). Multiple shape variants
# become separate compiled executables: the rust runtime picks the
# variant matching the (padded) request size.

def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


EXPORTS = {
    # SNS parity: 4+1 and 8+1 parity groups, 64 KiB units (16384 i32 lanes)
    "parity_k4": (sns_parity, lambda: (_i32(4, 16384),)),
    "parity_k8": (sns_parity, lambda: (_i32(8, 16384),)),
    # particle post-processing: 16K and 64K particle batches
    "postprocess_16k": (postprocess, lambda: (_f32(16384, 8), _f32(1))),
    "postprocess_64k": (postprocess, lambda: (_f32(65536, 8), _f32(1))),
    # ALF histogram over 64K-value log segments
    "alf_histogram_64k": (alf_histogram, lambda: (_f32(65536), _f32(2))),
    # integrity digest over 16-block extents of 16 KiB blocks (4096 lanes)
    "integrity_16x4k": (integrity_digest, lambda: (_i32(16, 4096),)),
}
