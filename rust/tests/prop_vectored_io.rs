//! Property tests: the §Perf zero-copy batched I/O engine is
//! byte-identical to the preserved pre-change engine (`sns_baseline`)
//! and to the single-op Clovis path — across random geometries, random
//! (overlapping, partial-stripe) extent lists, and degraded
//! (one-device-failed) reads.

use sage::clovis::{Client, Extent};
use sage::config::Testbed;
use sage::mero::{sns_baseline, Layout, MeroStore, ObjectId};
use sage::proptest::prop_check;
use sage::sim::device::DeviceKind;

const BS: u64 = 4096;
const UNIT: u64 = 16384;

fn layout(k: u32, p: u32) -> Layout {
    Layout::Raid { data: k, parity: p, unit: UNIT, tier: DeviceKind::Ssd }
}

/// Deterministic payload for extent (idx, len_blocks).
fn bytes_for(idx: u64, len_blocks: u64) -> Vec<u8> {
    (0..len_blocks * BS)
        .map(|j| ((idx * 131 + len_blocks * 17 + j) % 251) as u8)
        .collect()
}

/// Total logical span of an extent list, in bytes.
fn span(extents: &[(u64, u64)]) -> u64 {
    extents.iter().map(|(i, l)| (i + l) * BS).max().unwrap_or(0)
}

/// Baseline store with the extents applied one op at a time.
fn baseline_store(k: u32, p: u32, extents: &[(u64, u64)]) -> (MeroStore, ObjectId) {
    let mut s = MeroStore::new(Testbed::sage_prototype().build_cluster());
    let id = s.create_object(BS, layout(k, p)).unwrap();
    for (i, (idx, lenb)) in extents.iter().enumerate() {
        let data = bytes_for(*idx, *lenb);
        if data.is_empty() {
            continue;
        }
        sns_baseline::write(&mut s, id, idx * BS, &data, i as f64, None)
            .unwrap();
    }
    (s, id)
}

/// Client with the extents applied as ONE batched writev.
fn batched_client(k: u32, p: u32, extents: &[(u64, u64)]) -> (Client, ObjectId) {
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let obj = c.create_object_with(BS, layout(k, p)).unwrap();
    let datas: Vec<Vec<u8>> = extents
        .iter()
        .map(|(idx, lenb)| bytes_for(*idx, *lenb))
        .collect();
    let ext_refs: Vec<(u64, &[u8])> = extents
        .iter()
        .zip(datas.iter())
        .filter(|(_, d)| !d.is_empty())
        .map(|((idx, _), d)| (idx * BS, d.as_slice()))
        .collect();
    c.writev(&obj, &ext_refs).unwrap();
    (c, obj)
}

fn gen_extents(r: &mut sage::sim::rng::SimRng) -> Vec<(u64, u64)> {
    let n = 1 + r.gen_range(6) as usize;
    (0..n)
        .map(|_| (r.gen_range(64), 1 + r.gen_range(16)))
        .collect()
}

#[test]
fn prop_writev_equals_baseline_single_ops() {
    for (k, p) in [(2u32, 1u32), (4, 1), (3, 2), (4, 0)] {
        prop_check(
            &format!("writev=={k}+{p}-baseline"),
            25,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                let (mut base, idb) = baseline_store(k, p, extents);
                let (mut cli, obj) = batched_client(k, p, extents);
                if total == 0 {
                    return true;
                }
                let (want, _) =
                    sns_baseline::read(&mut base, idb, 0, total, 100.0)
                        .unwrap();
                // read_object_into over a dirty buffer
                let mut got = vec![0x5Au8; total as usize];
                cli.read_object_into(&obj, 0, &mut got).unwrap();
                // plus the allocating single-op read
                let got2 = cli.read_object(&obj, 0, total).unwrap();
                want == got && want == got2
            },
        );
    }
}

#[test]
fn prop_degraded_reads_reconstruct_identically() {
    for (k, p) in [(2u32, 1u32), (4, 1), (3, 2)] {
        prop_check(
            &format!("degraded-{k}+{p}"),
            20,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                if total == 0 {
                    return true;
                }
                let (mut base, idb) = baseline_store(k, p, extents);
                let (mut cli, obj) = batched_client(k, p, extents);
                // fail the device of the same logical unit in each store
                let unit = if k > 1 { 1 } else { 0 };
                let db = base.object(idb).unwrap().placement(0, unit).copied();
                let dc = cli
                    .store
                    .object(obj)
                    .unwrap()
                    .placement(0, unit)
                    .copied();
                match (db, dc) {
                    (Some(ub), Some(uc)) => {
                        base.cluster.fail_device(ub.device);
                        cli.store.cluster.fail_device(uc.device);
                    }
                    // stripe 0 untouched by the extents: nothing to fail
                    (None, None) => return true,
                    _ => return false, // placement maps must agree
                }
                let want =
                    sns_baseline::read(&mut base, idb, 0, total, 100.0)
                        .map(|(d, _)| d);
                let mut buf = vec![0xC3u8; total as usize];
                let got =
                    cli.read_object_into(&obj, 0, &mut buf).map(|_| buf.clone());
                match (want, got) {
                    (Ok(a), Ok(b)) => a == b,
                    // both engines must agree that data is unavailable
                    (Err(_), Err(_)) => true,
                    _ => false,
                }
            },
        );
    }
}

#[test]
fn prop_readv_matches_single_op_reads() {
    prop_check(
        "readv==read",
        25,
        gen_extents,
        |extents: &Vec<(u64, u64)>| {
            let (mut cli, obj) = batched_client(4, 1, extents);
            let read_exts: Vec<Extent> = extents
                .iter()
                .filter(|(_, l)| *l > 0)
                .map(|(i, l)| Extent::new(i * BS, l * BS))
                .collect();
            if read_exts.is_empty() {
                return true;
            }
            let batched = cli.readv(&obj, &read_exts).unwrap();
            for (e, got) in read_exts.iter().zip(batched.iter()) {
                let single = cli.read_object(&obj, e.offset, e.len).unwrap();
                if &single != got {
                    return false;
                }
            }
            true
        },
    );
}
