//! Property tests for the Clovis session/op-builder API (ISSUE 4):
//!
//! 1. **Wrapper identity** — every legacy vectored entry point
//!    (`writev`, `writev_owned`, `readv`, `read_object_into`,
//!    `write_object`) equals its session-built equivalent: stored
//!    bytes, unit placements, and BIT-identical completion times.
//! 2. **Chain identity** — a fully `.after`-chained mixed-kind session
//!    (write → read → ship → tx → idx_put → idx_get) is identical to
//!    the same calls made sequentially through the legacy API, healthy
//!    AND degraded (one failed device, parity reconstruction in the
//!    read and the shipped compute's local read).
//! 3. **No-slower** — an unchained mixed session never completes later
//!    than the sequential legacy calls on any sampled geometry (shared
//!    shards overlap; the sequential fold cannot).

use sage::clovis::{Client, Extent, FunctionKind, OpOutput};
use sage::config::Testbed;
use sage::mero::{Layout, ObjectId};
use sage::proptest::prop_check;
use sage::sim::device::DeviceKind;

const BS: u64 = 4096;
const UNIT: u64 = 16384;

fn layout(k: u32, p: u32) -> Layout {
    Layout::Raid { data: k, parity: p, unit: UNIT, tier: DeviceKind::Ssd }
}

/// Deterministic payload for extent (idx, len_blocks).
fn bytes_for(idx: u64, len_blocks: u64) -> Vec<u8> {
    (0..len_blocks * BS)
        .map(|j| ((idx * 131 + len_blocks * 31 + j) % 251) as u8)
        .collect()
}

fn gen_extents(r: &mut sage::sim::rng::SimRng) -> Vec<(u64, u64)> {
    let n = 1 + r.gen_range(5) as usize;
    (0..n)
        .map(|_| (r.gen_range(48), 1 + r.gen_range(12)))
        .collect()
}

/// Total logical span of an extent list, in bytes.
fn span(extents: &[(u64, u64)]) -> u64 {
    extents.iter().map(|(i, l)| (i + l) * BS).max().unwrap_or(0)
}

/// (stripe, unit, device) placement triples, in deterministic order.
fn placements(c: &Client, obj: ObjectId) -> Vec<(u64, u32, usize)> {
    c.store
        .object(obj)
        .unwrap()
        .placed_units()
        .map(|u| (u.stripe, u.unit, u.device))
        .collect()
}

fn client() -> Client {
    Client::new_sim(Testbed::sage_prototype())
}

fn refs<'a>(
    extents: &[(u64, u64)],
    datas: &'a [Vec<u8>],
) -> Vec<(u64, &'a [u8])> {
    extents
        .iter()
        .zip(datas.iter())
        .map(|((idx, _), d)| (idx * BS, d.as_slice()))
        .collect()
}

#[test]
fn prop_legacy_writev_readv_equal_session_ops() {
    for (k, p) in [(4u32, 1u32), (3, 2)] {
        prop_check(
            &format!("session-wrapper-identity-{k}+{p}"),
            14,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                if total == 0 {
                    return true;
                }
                let datas: Vec<Vec<u8>> = extents
                    .iter()
                    .map(|(i, l)| bytes_for(*i, *l))
                    .collect();
                let read_exts: Vec<Extent> = extents
                    .iter()
                    .map(|(i, l)| Extent::new(i * BS, l * BS))
                    .collect();

                // legacy wrappers
                let mut a = client();
                let oa = a.create_object_with(BS, layout(k, p)).unwrap();
                let ta = a.writev(&oa, &refs(extents, &datas)).unwrap();
                let back_a = a.readv(&oa, &read_exts).unwrap();
                let mut buf_a = vec![0x11u8; total as usize];
                a.read_object_into(&oa, 0, &mut buf_a).unwrap();

                // explicit sessions, one op per legacy call
                let mut b = client();
                let ob = b.create_object_with(BS, layout(k, p)).unwrap();
                let tb = {
                    let r = refs(extents, &datas);
                    let mut s = b.session();
                    s.write(&ob, &r);
                    s.run().unwrap().completed_at
                };
                let back_b = {
                    let mut s = b.session();
                    let h = s.read(&ob, &read_exts);
                    let mut rep = s.run().unwrap();
                    match rep.outputs.swap_remove(h.index()) {
                        OpOutput::Read(bufs) => bufs,
                        _ => return false,
                    }
                };
                let mut buf_b = vec![0x11u8; total as usize];
                {
                    let mut s = b.session();
                    s.read_into(&ob, 0, &mut buf_b);
                    s.run().unwrap();
                }

                ta.to_bits() == tb.to_bits()
                    && a.now.to_bits() == b.now.to_bits()
                    && back_a == back_b
                    && buf_a == buf_b
                    && placements(&a, oa) == placements(&b, ob)
            },
        );
    }
}

/// The mixed chain both engines run: write → read → ship → tx →
/// idx_put → idx_get. Returns everything observable for comparison.
struct ChainOutcome {
    bytes: Vec<Vec<u8>>,
    ship_t_done: u64,
    ship_t_move: u64,
    ship_output: String,
    idx_got: Vec<Option<Vec<u8>>>,
    now_bits: u64,
    placements: Vec<(u64, u32, usize)>,
}

fn chain_sequential(
    extents: &[(u64, u64)],
    datas: &[Vec<u8>],
    k: u32,
    p: u32,
    fail_unit: Option<u32>,
) -> ChainOutcome {
    let mut c = client();
    let obj = c.create_object_with(BS, layout(k, p)).unwrap();
    // base coverage so every stripe-0 placement exists
    let base = bytes_for(7, 2 * k as u64 * UNIT / BS);
    c.writev(&obj, &[(0, &base)]).unwrap();
    if let Some(u) = fail_unit {
        let d = c.store.object(obj).unwrap().placement(0, u).unwrap().device;
        c.store.cluster.fail_device(d);
    } else {
        c.writev(&obj, &refs(extents, datas)).unwrap();
    }
    // the logical span both engines read back (identical by
    // construction: base, extended by the extents in the healthy case)
    let total = if fail_unit.is_none() {
        (base.len() as u64).max(span(extents))
    } else {
        base.len() as u64
    };
    let bytes = c
        .readv(&obj, &[Extent::new(0, total)])
        .unwrap();
    let ship = c.ship_to_object(obj, FunctionKind::IntegrityCheck).unwrap();
    let tx = c.tx_begin();
    c.tx_put(tx, b"chain".to_vec(), b"v".to_vec()).unwrap();
    c.tx_commit(tx).unwrap();
    let idx = c.create_index();
    c.idx_put(idx, vec![(b"a".to_vec(), b"1".to_vec())]).unwrap();
    let idx_got = c.idx_get(idx, &[b"a".to_vec(), b"miss".to_vec()]).unwrap();
    ChainOutcome {
        bytes,
        ship_t_done: ship.t_done.to_bits(),
        ship_t_move: ship.t_move_data.to_bits(),
        ship_output: format!("{:?}", ship.output),
        idx_got,
        now_bits: c.now.to_bits(),
        placements: placements(&c, obj),
    }
}

fn chain_session(
    extents: &[(u64, u64)],
    datas: &[Vec<u8>],
    k: u32,
    p: u32,
    fail_unit: Option<u32>,
) -> ChainOutcome {
    let mut c = client();
    let obj = c.create_object_with(BS, layout(k, p)).unwrap();
    let base = bytes_for(7, 2 * k as u64 * UNIT / BS);
    c.writev(&obj, &[(0, &base)]).unwrap();
    if let Some(u) = fail_unit {
        let d = c.store.object(obj).unwrap().placement(0, u).unwrap().device;
        c.store.cluster.fail_device(d);
    }
    let total = if fail_unit.is_none() {
        (base.len() as u64).max(span(extents))
    } else {
        base.len() as u64
    };
    let idx = c.create_index();
    let r = refs(extents, datas);
    let mut s = c.session();
    let mut prev = None;
    // in the degraded variant the write is skipped, exactly like the
    // sequential engine above
    if fail_unit.is_none() {
        prev = Some(s.write(&obj, &r));
    }
    let rd = s.read(&obj, &[Extent::new(0, total)]);
    if let Some(w) = prev {
        s.after(rd, w).unwrap();
    }
    let sh = s.ship(obj, FunctionKind::IntegrityCheck);
    s.after(sh, rd).unwrap();
    let tx = s.tx(vec![(b"chain".to_vec(), b"v".to_vec())]);
    s.after(tx, sh).unwrap();
    let put = s.idx_put(idx, vec![(b"a".to_vec(), b"1".to_vec())]);
    s.after(put, tx).unwrap();
    let get = s.idx_get(idx, vec![b"a".to_vec(), b"miss".to_vec()]);
    s.after(get, put).unwrap();
    let mut rep = s.run().unwrap();
    let idx_got = match rep.outputs.swap_remove(get.index()) {
        OpOutput::IdxGet(v) => v,
        _ => Vec::new(),
    };
    let ship = match rep.outputs.swap_remove(sh.index()) {
        OpOutput::Ship(r) => r,
        _ => panic!("ship output expected"),
    };
    let bytes = match rep.outputs.swap_remove(rd.index()) {
        OpOutput::Read(b) => b,
        _ => panic!("read output expected"),
    };
    ChainOutcome {
        bytes,
        ship_t_done: ship.t_done.to_bits(),
        ship_t_move: ship.t_move_data.to_bits(),
        ship_output: format!("{:?}", ship.output),
        idx_got,
        now_bits: c.now.to_bits(),
        placements: placements(&c, obj),
    }
}

fn outcomes_match(a: &ChainOutcome, b: &ChainOutcome) -> bool {
    a.bytes == b.bytes
        && a.ship_t_done == b.ship_t_done
        && a.ship_t_move == b.ship_t_move
        && a.ship_output == b.ship_output
        && a.idx_got == b.idx_got
        && a.now_bits == b.now_bits
        && a.placements == b.placements
}

#[test]
fn prop_chained_mixed_session_equals_sequential_legacy_healthy() {
    for (k, p) in [(4u32, 1u32), (3, 2)] {
        prop_check(
            &format!("session-chain-identity-{k}+{p}"),
            10,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let datas: Vec<Vec<u8>> = extents
                    .iter()
                    .map(|(i, l)| bytes_for(*i, *l))
                    .collect();
                let seq = chain_sequential(extents, &datas, k, p, None);
                let ses = chain_session(extents, &datas, k, p, None);
                outcomes_match(&seq, &ses)
            },
        );
    }
}

#[test]
fn prop_chained_mixed_session_equals_sequential_legacy_degraded() {
    // one failed device (never the primary unit, whose shard the
    // shipped compute reads from): the chained session reconstructs
    // through parity exactly like the sequential legacy calls
    for (k, p) in [(4u32, 1u32), (3, 2)] {
        prop_check(
            &format!("session-chain-degraded-{k}+{p}"),
            8,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let datas: Vec<Vec<u8>> = extents
                    .iter()
                    .map(|(i, l)| bytes_for(*i, *l))
                    .collect();
                let seq = chain_sequential(extents, &datas, k, p, Some(1));
                let ses = chain_session(extents, &datas, k, p, Some(1));
                outcomes_match(&seq, &ses)
            },
        );
    }
}

#[test]
fn prop_unchained_session_never_slower_than_sequential() {
    for (k, p) in [(4u32, 1u32), (4, 2)] {
        prop_check(
            &format!("session-no-slower-{k}+{p}"),
            12,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                if total == 0 {
                    return true;
                }
                let datas: Vec<Vec<u8>> = extents
                    .iter()
                    .map(|(i, l)| bytes_for(*i, *l))
                    .collect();
                // both engines: obj1 pre-written for the read + ship,
                // obj2 receives the write batch
                let prep = |c: &mut Client| {
                    let o1 = c.create_object_with(BS, layout(k, p)).unwrap();
                    let base = bytes_for(3, k as u64 * UNIT / BS);
                    c.writev(&o1, &[(0, &base)]).unwrap();
                    let o2 = c.create_object_with(BS, layout(k, p)).unwrap();
                    (o1, o2, base.len() as u64)
                };

                let mut a = client();
                let (a1, a2, blen) = prep(&mut a);
                let t0 = a.now;
                a.writev(&a2, &refs(extents, &datas)).unwrap();
                a.readv(&a1, &[Extent::new(0, blen)]).unwrap();
                a.ship_to_object(a1, FunctionKind::IntegrityCheck).unwrap();
                let t_seq = a.now - t0;

                let mut b = client();
                let (b1, b2, _) = prep(&mut b);
                let t1 = b.now;
                let r = refs(extents, &datas);
                let mut s = b.session();
                s.write(&b2, &r);
                s.read(&b1, &[Extent::new(0, blen)]);
                s.ship(b1, FunctionKind::IntegrityCheck);
                let rep = s.run().unwrap();
                let t_ses = rep.completed_at - t1;

                t_ses <= t_seq * (1.0 + 1e-9) + 1e-12
            },
        );
    }
}

#[test]
fn empty_batches_complete_at_now_without_special_cases() {
    // the pinned no-op bugfix: zero-op sessions, empty extent lists
    // and empty plans all complete at `now` and leave state untouched
    let mut c = client();
    let obj = c.create_object(4096).unwrap();
    c.write_object(&obj, 0, &vec![1u8; 4 * 65536]).unwrap();
    let now = c.now;
    let emitted = c.fdmi.emitted;

    assert_eq!(c.session().run().unwrap().completed_at, now);
    assert_eq!(c.writev(&obj, &[]).unwrap(), now);
    assert_eq!(c.writev_owned(&obj, Vec::new()).unwrap(), now);
    assert!(c.readv(&obj, &[]).unwrap().is_empty());
    let mut hsm = sage::hsm::Hsm::new(sage::hsm::TieringPolicy::HeatWeighted);
    assert_eq!(c.migrate_with(&mut hsm, &[]).unwrap(), now);
    assert_eq!(c.now, now, "no-op batches do not advance the clock");
    assert_eq!(c.fdmi.emitted, emitted, "and emit no events");
}
