//! Property tests for the QoS plane (ISSUE 5): the scheduler-level
//! repair/foreground bandwidth split must change *when* completions
//! land — never *what* is stored — and must honor its contracts on
//! every sampled geometry:
//!
//! 1. **Byte/placement equivalence** — a mixed session (device repair
//!    staged next to foreground writes) stores byte- and
//!    placement-identical state under the default split and under the
//!    unthrottled engine.
//! 2. **Determinism** — repeated split runs produce bit-identical
//!    completion times.
//! 3. **Cap respected** — on every shard repair touched, its observed
//!    device-time share never exceeds `QosConfig::repair_share`.
//! 4. **Foreground no-slower / repair no-faster** — under concurrent
//!    repair, the split never worsens a HEALTHY foreground op's
//!    completion vs the unthrottled engine, and never lets repair
//!    finish earlier than unthrottled (the throttle is real).
//! 5. **Edge cases** — zero background traffic is bit-identical to
//!    unthrottled; a repair-only workload on an idle-foreground
//!    cluster completes (no deadlock) with identical bytes; caps of
//!    1.0 reproduce the pre-QoS frontiers exactly.
//! 6. **Degraded reads are Repair-classed by design** (the ISSUE 5
//!    spec): a foreground read that must reconstruct through parity
//!    pays the repair cap — bytes identical, completion never earlier
//!    than unthrottled, reconstruction traffic visible on the Repair
//!    lane (OPERATIONS.md documents the operational consequence).

use sage::bench::testkit::{self, placements, span, Geometry, BS, UNIT};
use sage::clovis::Client;
use sage::mero::{Layout, ObjectId};
use sage::proptest::prop_check;
use sage::sim::sched::{QosConfig, QosShardReport, TrafficClass};

/// This suite's historical sampling family (see `bench::testkit`).
const GEO: Geometry = Geometry::QOS;

fn layout(k: u32, p: u32) -> Layout {
    testkit::raid(k, p)
}

/// Deterministic payload for extent (idx, len_blocks).
fn bytes_for(idx: u64, len_blocks: u64) -> Vec<u8> {
    GEO.bytes_for(idx, len_blocks)
}

fn gen_extents(r: &mut sage::sim::rng::SimRng) -> Vec<(u64, u64)> {
    GEO.gen_extents(r)
}

/// One mixed run: device repair staged FIRST on a session, foreground
/// writes after it (unchained — both dispatch at the session clock and
/// contend on shared shards). Returns everything the properties probe.
struct MixedOutcome {
    client: Client,
    repair_objs: Vec<(ObjectId, Vec<u8>)>,
    fg_obj: ObjectId,
    fg_span: u64,
    repair_completed: f64,
    fg_completed: f64,
    completed_bits: Vec<u64>,
    frontier_bits: Vec<(usize, u64)>,
    qos_table: Vec<QosShardReport>,
    bytes_rebuilt: u64,
}

fn run_mixed(
    qos: QosConfig,
    extents: &[(u64, u64)],
    k: u32,
    p: u32,
) -> MixedOutcome {
    let mut c = testkit::sage_client();
    c.store.cluster.qos = qos;
    let mut repair_objs = Vec::new();
    for i in 0..4u64 {
        let o = c.create_object_with(BS, layout(k, p)).unwrap();
        let data = bytes_for(i, 2 * k as u64 * UNIT / BS);
        c.write_object(&o, 0, &data).unwrap();
        repair_objs.push((o, data));
    }
    let dev = c
        .store
        .object(repair_objs[0].0)
        .unwrap()
        .placement(0, 0)
        .unwrap()
        .device;
    c.store.cluster.fail_device(dev);
    let fg_obj = c.create_object_with(BS, layout(k, p)).unwrap();
    let fg_datas: Vec<Vec<u8>> = extents
        .iter()
        .map(|(i, l)| bytes_for(100 + i, *l))
        .collect();
    let fg_refs: Vec<(u64, &[u8])> = extents
        .iter()
        .zip(fg_datas.iter())
        .map(|((i, _), d)| (i * BS, d.as_slice()))
        .collect();
    let ids: Vec<ObjectId> = repair_objs.iter().map(|(o, _)| *o).collect();
    let mut s = c.session();
    let r = s.repair(&ids, dev);
    let w = s.write(&fg_obj, &fg_refs);
    let rep = s.run().unwrap();
    let bytes_rebuilt = match rep.output(r) {
        sage::clovis::OpOutput::Repair { bytes } => *bytes,
        other => panic!("repair output expected, got {other:?}"),
    };
    let completed_bits: Vec<u64> =
        rep.completed.iter().map(|t| t.to_bits()).collect();
    let frontier_bits: Vec<(usize, u64)> =
        rep.frontiers.iter().map(|&(d, f)| (d, f.to_bits())).collect();
    MixedOutcome {
        repair_completed: rep.completed[r.index()],
        fg_completed: rep.completed[w.index()],
        completed_bits,
        frontier_bits,
        qos_table: rep.qos,
        bytes_rebuilt,
        fg_span: span(extents),
        fg_obj,
        repair_objs,
        client: c,
    }
}

/// Read back every object of a mixed run (repair set + foreground
/// object) for cross-engine comparison.
fn stored_bytes(out: &mut MixedOutcome) -> Vec<Vec<u8>> {
    let mut all = Vec::new();
    let objs: Vec<(ObjectId, u64)> = out
        .repair_objs
        .iter()
        .map(|(o, d)| (*o, d.len() as u64))
        .chain(std::iter::once((out.fg_obj, out.fg_span)))
        .collect();
    for (o, len) in objs {
        all.push(out.client.read_object(&o, 0, len).unwrap());
    }
    all
}

#[test]
fn prop_split_preserves_bytes_and_placements() {
    for (k, p) in [(4u32, 1u32), (4, 2), (3, 2)] {
        prop_check(
            &format!("qos-bytes-{k}+{p}"),
            10,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let mut split = run_mixed(QosConfig::default(), extents, k, p);
                let mut fifo = run_mixed(QosConfig::unlimited(), extents, k, p);
                if split.bytes_rebuilt != fifo.bytes_rebuilt {
                    return false;
                }
                if stored_bytes(&mut split) != stored_bytes(&mut fifo) {
                    return false;
                }
                // the repair data still matches the originally written
                // payloads (not just cross-engine agreement)
                for (o, want) in split.repair_objs.clone() {
                    let got = split
                        .client
                        .read_object(&o, 0, want.len() as u64)
                        .unwrap();
                    if got != want {
                        return false;
                    }
                }
                let objs: Vec<ObjectId> = split
                    .repair_objs
                    .iter()
                    .map(|(o, _)| *o)
                    .chain(std::iter::once(split.fg_obj))
                    .collect();
                objs.iter().all(|&o| {
                    placements(&split.client, o) == placements(&fifo.client, o)
                })
            },
        );
    }
}

#[test]
fn prop_split_is_deterministic() {
    prop_check(
        "qos-determinism",
        8,
        gen_extents,
        |extents: &Vec<(u64, u64)>| {
            let a = run_mixed(QosConfig::default(), extents, 4, 2);
            let b = run_mixed(QosConfig::default(), extents, 4, 2);
            a.completed_bits == b.completed_bits
                && a.frontier_bits == b.frontier_bits
        },
    );
}

#[test]
fn prop_repair_share_cap_respected_on_every_shard() {
    for (k, p) in [(4u32, 1u32), (4, 2), (3, 2)] {
        prop_check(
            &format!("qos-cap-{k}+{p}"),
            10,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let qos = QosConfig::default();
                let cap = qos.share(TrafficClass::Repair);
                let out = run_mixed(qos, extents, k, p);
                let mut saw_repair = false;
                for shard in &out.qos_table {
                    let share = shard.observed_share(TrafficClass::Repair);
                    if share > cap + 1e-9 {
                        return false;
                    }
                    saw_repair |= share > 0.0;
                }
                saw_repair // the workload really exercised the cap
            },
        );
    }
}

#[test]
fn prop_split_never_slows_foreground_and_never_speeds_repair() {
    for (k, p) in [(4u32, 1u32), (4, 2)] {
        prop_check(
            &format!("qos-ordering-{k}+{p}"),
            10,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let split = run_mixed(QosConfig::default(), extents, k, p);
                let fifo = run_mixed(QosConfig::unlimited(), extents, k, p);
                // the split exists to protect foreground from rebuild
                // backlog: the write op can only complete earlier
                if split.fg_completed > fifo.fg_completed * (1.0 + 1e-9) + 1e-12
                {
                    return false;
                }
                // and the throttle is real: capped repair never beats
                // the unthrottled engine
                fifo.repair_completed
                    <= split.repair_completed * (1.0 + 1e-9) + 1e-12
            },
        );
    }
}

#[test]
fn prop_zero_background_split_is_bit_identical() {
    // foreground-only sessions: the split is free — bit-identical
    // completion times to the unthrottled engine
    prop_check(
        "qos-zero-background",
        10,
        gen_extents,
        |extents: &Vec<(u64, u64)>| {
            let run = |qos: QosConfig| {
                let mut c = testkit::sage_client();
                c.store.cluster.qos = qos;
                let obj = c.create_object_with(BS, layout(4, 1)).unwrap();
                let datas: Vec<Vec<u8>> = extents
                    .iter()
                    .map(|(i, l)| bytes_for(*i, *l))
                    .collect();
                let refs: Vec<(u64, &[u8])> = extents
                    .iter()
                    .zip(datas.iter())
                    .map(|((i, _), d)| (i * BS, d.as_slice()))
                    .collect();
                let total = span(extents);
                let mut s = c.session();
                let w = s.write(&obj, &refs);
                let r = s.read(
                    &obj,
                    &[sage::clovis::Extent::new(0, total)],
                );
                s.after(r, w).unwrap();
                let rep = s.run().unwrap();
                let mut bits: Vec<u64> =
                    rep.completed.iter().map(|t| t.to_bits()).collect();
                bits.push(rep.completed_at.to_bits());
                bits
            };
            run(QosConfig::default()) == run(QosConfig::unlimited())
        },
    );
}

#[test]
fn repair_only_workload_completes_without_deadlock() {
    // an idle-foreground cluster: the cap stretches the rebuild but
    // never starves it — same bytes, a later (or equal) frontier, and
    // the device returns to service
    let run = |qos: QosConfig| {
        let mut c = testkit::sage_client();
        c.store.cluster.qos = qos;
        let mut objs = Vec::new();
        for i in 0..3u64 {
            let o = c.create_object_with(BS, layout(4, 2)).unwrap();
            let data = bytes_for(i, 2 * 4 * UNIT / BS);
            c.write_object(&o, 0, &data).unwrap();
            objs.push((o, data));
        }
        let dev =
            c.store.object(objs[0].0).unwrap().placement(0, 0).unwrap().device;
        c.store.cluster.fail_device(dev);
        let ids: Vec<ObjectId> = objs.iter().map(|(o, _)| *o).collect();
        let (bytes, t) = c.repair_with(&ids, dev).unwrap();
        (c, objs, dev, bytes, t)
    };
    let (mut c_split, objs, dev, bytes_split, t_split) =
        run(QosConfig::default());
    let (_c_fifo, _, _, bytes_fifo, t_fifo) = run(QosConfig::unlimited());
    assert!(bytes_split > 0);
    assert_eq!(bytes_split, bytes_fifo, "same units rebuilt");
    assert!(t_split.is_finite() && t_split > 0.0, "no deadlock");
    assert!(
        t_split >= t_fifo * (1.0 - 1e-9),
        "the static throttle cannot beat the unthrottled rebuild"
    );
    assert!(!c_split.store.cluster.devices[dev].failed, "device replaced");
    for (o, want) in objs {
        let got = c_split.read_object(&o, 0, want.len() as u64).unwrap();
        assert_eq!(got, want, "bytes intact after the throttled rebuild");
    }
}

#[test]
fn degraded_read_reconstruction_is_repair_classed_and_throttled() {
    // the pinned ISSUE 5 semantics: survivor reads of a degraded
    // foreground read dispatch as Repair, so reconstruction pays the
    // cap even with no rebuild running — bytes untouched, and the
    // share stays within the cap on every shard
    let run = |qos: QosConfig| {
        let mut c = testkit::sage_client();
        c.store.cluster.qos = qos;
        let obj = c.create_object_with(BS, layout(4, 2)).unwrap();
        let data = bytes_for(9, 2 * 4 * UNIT / BS);
        c.write_object(&obj, 0, &data).unwrap();
        let dev = c.store.object(obj).unwrap().placement(0, 1).unwrap().device;
        c.store.cluster.fail_device(dev);
        let mut s = c.session();
        let h = s.read(&obj, &[sage::clovis::Extent::new(0, data.len() as u64)]);
        let mut rep = s.run().unwrap();
        let bufs = match rep.outputs.swap_remove(h.index()) {
            sage::clovis::OpOutput::Read(b) => b,
            other => panic!("read output expected, got {other:?}"),
        };
        (bufs, data, rep.completed_at, rep.qos)
    };
    let (bytes_split, want, t_split, table) = run(QosConfig::default());
    let (bytes_fifo, _, t_fifo, _) = run(QosConfig::unlimited());
    assert_eq!(bytes_split[0], want, "reconstruction restores the bytes");
    assert_eq!(bytes_split, bytes_fifo, "the cap never changes bytes");
    assert!(
        t_split >= t_fifo * (1.0 - 1e-9),
        "throttled reconstruction cannot beat the unthrottled engine"
    );
    let repair_busy: f64 = table
        .iter()
        .map(|r| r.class_busy[TrafficClass::Repair.index()])
        .sum();
    assert!(repair_busy > 0.0, "survivor reads ride the Repair lane");
    let cap = QosConfig::default().share(TrafficClass::Repair);
    for shard in &table {
        assert!(shard.observed_share(TrafficClass::Repair) <= cap + 1e-9);
    }
}

#[test]
fn cap_of_one_reproduces_pre_qos_frontiers_exactly() {
    // raising every share to 1.0 IS the unthrottled engine — the whole
    // mixed workload (repair + foreground writes) lands on the same
    // bits, frontiers included
    let extents: Vec<(u64, u64)> = vec![(0, 8), (16, 4), (3, 6)];
    let cap_one =
        QosConfig { repair_share: 1.0, migration_share: 1.0, work_conserving: false };
    let a = run_mixed(cap_one, &extents, 4, 2);
    let b = run_mixed(QosConfig::unlimited(), &extents, 4, 2);
    assert_eq!(a.completed_bits, b.completed_bits);
    assert_eq!(a.frontier_bits, b.frontier_bits);
    assert_eq!(a.bytes_rebuilt, b.bytes_rebuilt);
    assert_eq!(
        a.repair_completed.to_bits(),
        b.repair_completed.to_bits(),
        "cap = 1.0 is bit-identical, not merely close"
    );
}
