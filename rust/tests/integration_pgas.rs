//! Integration: PGAS storage windows + the figure-shape assertions at
//! reduced scale (full-scale runs live in rust/benches/).

use sage::apps::{dht, hacc, stream};
use sage::config::Testbed;
use sage::pgas::{PgasSim, StorageTarget, WindowKind};

#[test]
fn fig3a_shape_small() {
    let tb = Testbed::blackdog();
    let mem = stream::run(&tb, WindowKind::Memory, 50, 2).unwrap();
    let sto =
        stream::run(&tb, WindowKind::Storage(StorageTarget::Hdd), 50, 2).unwrap();
    for (m, s) in mem.iter().zip(sto.iter()) {
        let deg = 1.0 - s.bandwidth / m.bandwidth;
        assert!(
            (0.0..0.35).contains(&deg),
            "{}: {deg:.3} — storage windows stay DRAM-class on Blackdog",
            m.kernel
        );
    }
}

#[test]
fn fig3b_shape_asymmetry() {
    let tb = Testbed::tegner();
    let (r, w) = stream::rw_asymmetry(&tb, StorageTarget::Pfs, 2 << 30).unwrap();
    let ratio = r / w;
    assert!(
        (4.0..14.0).contains(&ratio),
        "Lustre rd/wr asymmetry ~9x expected, got {ratio:.1} ({r:.0}/{w:.0})"
    );
}

#[test]
fn fig3c_shape_collapse() {
    let tb = Testbed::tegner();
    let mem = stream::run(&tb, WindowKind::Memory, 100, 1).unwrap();
    let sto =
        stream::run(&tb, WindowKind::Storage(StorageTarget::Pfs), 100, 1).unwrap();
    let deg = 1.0 - sto[0].bandwidth / mem[0].bandwidth;
    assert!(deg > 0.8, "Tegner storage STREAM collapses (got {deg:.2})");
}

#[test]
fn fig5_shape_both_testbeds() {
    // Tegner: windows beat MPI-IO at scale
    let tegner = Testbed::tegner();
    let t_io = hacc::run(&tegner, hacc::HaccImpl::MpiIo, 96, 50_000_000).unwrap();
    let t_win = hacc::run(
        &tegner,
        hacc::HaccImpl::StorageWindows(StorageTarget::Pfs),
        96,
        50_000_000,
    )
    .unwrap();
    assert!(t_win < t_io, "windows {t_win} vs mpiio {t_io}");

    // Blackdog: comparable (MPI-IO can be slightly ahead)
    let bd = Testbed::blackdog();
    let t_io = hacc::run(&bd, hacc::HaccImpl::MpiIo, 8, 10_000_000).unwrap();
    let t_win = hacc::run(
        &bd,
        hacc::HaccImpl::StorageWindows(StorageTarget::Hdd),
        8,
        10_000_000,
    )
    .unwrap();
    let ratio = t_win / t_io;
    assert!((0.3..3.0).contains(&ratio), "comparable on Blackdog: {ratio:.2}");
}

#[test]
fn dht_overflow_and_volume_windows_consistent() {
    let tb = Testbed::blackdog();
    let cfg = dht::DhtConfig {
        ranks: 4,
        local_volume: 10_000,
        ops_per_rank: 5_000,
        sync_interval: u64::MAX,
    };
    let t = dht::run(&tb, WindowKind::Storage(StorageTarget::Ssd), &cfg).unwrap();
    assert!(t > 0.0 && t.is_finite());
}

#[test]
fn window_warm_makes_reads_hits() {
    let tb = Testbed::blackdog();
    let mut sim = PgasSim::new(tb, 1);
    let w = sim.alloc_window(WindowKind::Storage(StorageTarget::Hdd), 1 << 24);
    // cold read pays device time
    sim.get(w, 0, 0, 0, 1 << 24, false).unwrap();
    let cold = sim.elapsed();
    sim.reset_clocks();
    sim.get(w, 0, 0, 0, 1 << 24, false).unwrap();
    let warm = sim.elapsed();
    assert!(cold > 10.0 * warm, "cold {cold} vs warm {warm}");
}

#[test]
fn multi_rank_clock_independence() {
    let tb = Testbed::tegner();
    let mut sim = PgasSim::new(tb, 48);
    let w = sim.alloc_window(WindowKind::Memory, 1 << 20);
    sim.put(w, 7, 7, 0, 1 << 20, false).unwrap();
    assert!(sim.clocks.now(7) > 0.0);
    assert_eq!(sim.clocks.now(8), 0.0, "other ranks unaffected");
    sim.fence(w).unwrap();
    assert_eq!(sim.clocks.now(8), sim.clocks.now(7));
}
