//! Property-based invariants on the coordinator (in-tree harness,
//! rust/src/proptest.rs — offline substitute for the proptest crate).
//!
//! Invariants:
//! * SNS: write→read round-trip for arbitrary sizes/geometries;
//!   reconstruction after any single-device loss
//! * KV: NEXT is strictly increasing and consistent with scan order
//! * DTM: committed state == redo-log replay (atomicity w.r.t. crash)
//! * HSM: migration preserves bytes for arbitrary payloads
//! * Layout: overhead ≥ 1 and validated layouts map every offset
//! * PageCache: resident ≤ capacity; hit+miss == bytes requested

use sage::config::Testbed;
use sage::mero::{Layout, MeroStore};
use sage::proptest::prop_check;
use sage::sim::cache::PageCache;
use sage::sim::device::DeviceKind;
use sage::sim::rng::SimRng;

fn store() -> MeroStore {
    MeroStore::new(Testbed::sage_prototype().build_cluster())
}

#[test]
fn prop_sns_roundtrip_arbitrary_geometry() {
    prop_check(
        "sns-roundtrip",
        40,
        |r| {
            let k = 2 + r.gen_range(6); // 2..8 data units
            let blocks = 1 + r.gen_range(24); // 4K..100K payload
            let seed = r.next_u64();
            vec![k, blocks, seed]
        },
        |v| {
            let (k, blocks, seed) = (v[0] as u32, v[1], v[2]);
            let mut s = store();
            let id = s
                .create_object(
                    4096,
                    Layout::Raid { data: k, parity: 1, unit: 16384, tier: DeviceKind::Ssd },
                )
                .unwrap();
            let mut data = vec![0u8; (blocks * 4096) as usize];
            SimRng::new(seed).fill_bytes(&mut data);
            let t = s.write_object(id, 0, &data, 0.0, None).unwrap();
            let (back, _) = s.read_object(id, 0, data.len() as u64, t).unwrap();
            back == data
        },
    );
}

#[test]
fn prop_sns_single_failure_reconstructs() {
    prop_check(
        "sns-degraded",
        25,
        |r| {
            let k = 2 + r.gen_range(6);
            let lost_unit = r.gen_range(k); // any data unit
            let seed = r.next_u64();
            vec![k, lost_unit, seed]
        },
        |v| {
            let (k, lost, seed) = (v[0] as u32, v[1] as u32, v[2]);
            let mut s = store();
            let id = s
                .create_object(
                    4096,
                    Layout::Raid { data: k, parity: 1, unit: 16384, tier: DeviceKind::Ssd },
                )
                .unwrap();
            let mut data = vec![0u8; (k as usize) * 16384];
            SimRng::new(seed).fill_bytes(&mut data);
            s.write_object(id, 0, &data, 0.0, None).unwrap();
            let dev = s.object(id).unwrap().placement(0, lost).unwrap().device;
            s.cluster.fail_device(dev);
            match s.read_object(id, 0, data.len() as u64, 1.0) {
                Ok((back, _)) => back == data,
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_kv_next_strictly_increasing() {
    prop_check(
        "kv-next-order",
        50,
        |r| {
            let n = 1 + r.gen_range(100);
            (0..n).map(|_| r.gen_range(10_000)).collect::<Vec<u64>>()
        },
        |keys| {
            let mut s = store();
            let idx = s.create_index();
            for k in keys {
                s.index_mut(idx)
                    .unwrap()
                    .put(k.to_be_bytes().to_vec(), vec![1]);
            }
            // walk via NEXT from the beginning; must visit keys in
            // strictly ascending unique order, same as scan
            let scan: Vec<Vec<u8>> = s
                .index(idx)
                .unwrap()
                .scan(b"", usize::MAX)
                .into_iter()
                .map(|(k, _)| k)
                .collect();
            let mut walked = Vec::new();
            let mut cur = vec![0u8; 0];
            while let Some((k, _)) =
                s.index(idx).unwrap().next_batch(&[cur.clone()])[0].clone()
            {
                if !walked.is_empty() && k <= *walked.last().unwrap() {
                    return false;
                }
                walked.push(k.clone());
                cur = k;
            }
            walked == scan
        },
    );
}

#[test]
fn prop_dtm_crash_recovery_equals_live_state() {
    prop_check(
        "dtm-atomicity",
        50,
        |r| {
            // sequence of (key, value, commit?) triples
            let n = 1 + r.gen_range(40);
            (0..n)
                .map(|_| {
                    (r.gen_range(10), (r.gen_range(100), r.gen_range(2)))
                })
                .collect::<Vec<(u64, (u64, u64))>>()
        },
        |ops| {
            let mut m = sage::mero::dtm::DtmManager::new();
            for (key, (val, commit)) in ops {
                let tx = m.begin();
                m.write(tx, key.to_be_bytes().to_vec(), val.to_be_bytes().to_vec())
                    .unwrap();
                if *commit == 1 {
                    let _ = m.commit(tx, 0.0);
                } else {
                    m.abort(tx).unwrap();
                }
            }
            // crash-replay must equal live state exactly
            let replay = m.recover();
            replay.iter().all(|(k, v)| m.get(k) == Some(v))
                && m.committed as usize >= replay.len().min(1)
        },
    );
}

#[test]
fn prop_hsm_migration_preserves_bytes() {
    prop_check(
        "hsm-no-loss",
        15,
        |r| {
            let blocks = 1 + r.gen_range(32);
            let hops = 1 + r.gen_range(3);
            let seed = r.next_u64();
            vec![blocks, hops, seed]
        },
        |v| {
            let (blocks, hops, seed) = (v[0], v[1], v[2]);
            let mut s = store();
            let id = s.create_object(4096, Layout::default()).unwrap();
            let mut data = vec![0u8; (blocks * 4096) as usize];
            SimRng::new(seed).fill_bytes(&mut data);
            s.write_object(id, 0, &data, 0.0, None).unwrap();
            let mut hsm = sage::hsm::Hsm::new(sage::hsm::TieringPolicy::HeatWeighted);
            let ladder = [DeviceKind::Nvram, DeviceKind::Hdd, DeviceKind::Ssd];
            let mut from = DeviceKind::Ssd;
            for h in 0..hops {
                let to = ladder[(h % 3) as usize];
                if to == from {
                    continue;
                }
                let plan = vec![sage::hsm::Migration { obj: id, from, to }];
                if hsm.migrate(&mut s, &plan, 1.0).is_err() {
                    return false;
                }
                from = to;
            }
            let (back, _) = s.read_object(id, 0, data.len() as u64, 9.0).unwrap();
            back == data
        },
    );
}

#[test]
fn prop_page_cache_conservation() {
    prop_check(
        "cache-conservation",
        60,
        |r| {
            let cap_pages = 4 + r.gen_range(60);
            let n_ops = 1 + r.gen_range(120);
            let seed = r.next_u64();
            vec![cap_pages, n_ops, seed]
        },
        |v| {
            let (cap_pages, n_ops, seed) = (v[0], v[1], v[2]);
            let mut rng = SimRng::new(seed);
            let mut c = PageCache::new(cap_pages * 4096, 4096);
            for _ in 0..n_ops {
                let off = rng.gen_range(cap_pages * 8) * 4096;
                let len = 1 + rng.gen_range(3 * 4096);
                let out = if rng.gen_f64() < 0.5 {
                    c.read(off, len)
                } else {
                    c.write(off, len)
                };
                // conservation: every requested byte is hit or missed
                if out.hit + out.miss != len {
                    return false;
                }
                // capacity bound
                if c.resident() > (cap_pages + 1) * 4096 {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_layout_overhead_at_least_one() {
    prop_check(
        "layout-overhead",
        40,
        |r| {
            let data = 1 + r.gen_range(10) as u64;
            let parity = r.gen_range(3) as u64;
            let copies = 1 + r.gen_range(4) as u64;
            vec![data, parity, copies]
        },
        |v| {
            let raid = Layout::Raid {
                data: v[0] as u32,
                parity: (v[1] as u32).min(2),
                unit: 4096,
                tier: DeviceKind::Ssd,
            };
            let mirror = Layout::Mirror { copies: v[2] as u32, tier: DeviceKind::Hdd };
            raid.overhead() >= 1.0 && mirror.overhead() >= 1.0
        },
    );
}

#[test]
fn prop_stream_elements_conserved() {
    prop_check(
        "stream-conservation",
        20,
        |r| {
            let bursts = 1 + r.gen_range(20);
            let per_burst = 1 + r.gen_range(200);
            vec![bursts, per_burst]
        },
        |v| {
            use sage::streams::{StreamConfig, StreamElement, StreamSim};
            let tb = Testbed::beskow();
            let mut s = StreamSim::new(&tb, StreamConfig::paper_ratio(15));
            let batch: Vec<StreamElement> = (0..v[1])
                .map(|i| StreamElement {
                    x: 0.0, y: 0.0, z: 0.0,
                    u: 1.0, v: 0.0, w: 0.0,
                    q: 1.0, id: i as f32,
                })
                .collect();
            let mut sent = 0;
            for _ in 0..v[0] {
                s.push_real(0, &batch, 64).unwrap();
                sent += batch.len();
            }
            s.drain();
            s.collect(0).len() == sent
        },
    );
}
