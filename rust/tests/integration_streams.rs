//! Integration: MPI streams + mini-iPIC3D + collective baseline
//! (Fig 6/7 machinery at reduced scale).

use sage::apps::ipic3d::{self, Simulation};
use sage::config::Testbed;
use sage::streams::collective::CollectiveIo;
use sage::streams::{StreamConfig, StreamElement, StreamSim};

#[test]
fn fig7_shape_reduced() {
    let tb = Testbed::beskow();
    let small = ipic3d::run_scaling(&tb, 64, 10);
    let large = ipic3d::run_scaling(&tb, 1024, 10);
    assert!(small.improvement > 0.7, "comparable at small scale: {}", small.improvement);
    assert!(
        large.improvement > small.improvement,
        "advantage grows: {} -> {}",
        small.improvement,
        large.improvement
    );
}

#[test]
fn streamed_pipeline_preserves_every_hot_particle() {
    let tb = Testbed::beskow();
    let mut sim = Simulation::new(3000, 0.1, 5);
    let mut streams = StreamSim::new(&tb, StreamConfig::paper_ratio(15));
    let mut sent = 0u64;
    let mut received = 0u64;
    for _ in 0..25 {
        sim.step();
        let hot = sim.hot_particles(1.5);
        sent += hot.len() as u64;
        if !hot.is_empty() {
            streams
                .push_real(0, &hot, hot.len() as u64 * StreamElement::BYTES)
                .unwrap();
            received += streams.collect(0).len() as u64;
        }
    }
    assert!(sent > 0);
    assert_eq!(sent, received, "no stream element lost or duplicated");
    assert_eq!(streams.elements_streamed, sent);
}

#[test]
fn consumer_energy_computation_matches_producer() {
    let tb = Testbed::beskow();
    let mut sim = Simulation::new(1000, 0.2, 6);
    for _ in 0..40 {
        sim.step();
    }
    let hot = sim.hot_particles(1.0);
    let mut streams = StreamSim::new(&tb, StreamConfig::paper_ratio(15));
    streams.push_real(0, &hot, 0).unwrap();
    let delivered = streams.collect(0);
    // consumer recomputes energies from the rows (the kernel's formula)
    for (p, d) in hot.iter().zip(delivered.iter()) {
        assert_eq!(p.energy(), d.energy());
        assert!(d.energy() > 1.0);
    }
}

#[test]
fn collective_baseline_blocks_everyone_uniformly() {
    let tb = Testbed::beskow();
    let mut c = CollectiveIo::new(&tb, 32);
    c.step(0.02, 1 << 20);
    c.step(0.02, 1 << 20);
    let t = c.elapsed();
    assert!(t > 0.04, "at least the compute time");
}

#[test]
fn vtk_output_from_streamed_data() {
    let tb = Testbed::beskow();
    let dir = std::env::temp_dir().join("sage_it_vtk");
    std::fs::create_dir_all(&dir).unwrap();
    let (hot, files) =
        ipic3d::run_real_pipeline(&tb, None, 4000, 20, 1.2, Some(&dir)).unwrap();
    assert!(hot > 0);
    assert!(files > 0);
    // every produced file parses as VTK polydata with energies
    let mut checked = 0;
    for e in std::fs::read_dir(&dir).unwrap() {
        let text = std::fs::read_to_string(e.unwrap().path()).unwrap();
        assert!(text.starts_with("# vtk DataFile"));
        assert!(text.contains("SCALARS energy"));
        checked += 1;
    }
    assert_eq!(checked as u64, files);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn backpressure_bounds_memory_not_correctness() {
    let tb = Testbed::beskow();
    let cfg = StreamConfig {
        producers: 2,
        consumers: 1,
        queue_depth: 2,
        consume_bw: 1e7,
    };
    let mut s = StreamSim::new(&tb, cfg);
    let batch: Vec<StreamElement> = (0..50)
        .map(|i| StreamElement {
            x: 0.0, y: 0.0, z: 0.0,
            u: 1.0, v: 0.0, w: 0.0,
            q: 1.0, id: i as f32,
        })
        .collect();
    for _ in 0..10 {
        s.push_real(0, &batch, 0).unwrap();
        s.push_real(1, &batch, 0).unwrap();
    }
    s.drain();
    assert_eq!(s.collect(0).len(), 20 * 50);
}
