//! Integration: HA failure handling + HSM tiering over live stores —
//! failure injection, event analysis, repair, migration, no data loss.

use sage::cluster::failure::{FailureEvent, FailureKind, FailureSchedule};
use sage::clovis::Client;
use sage::config::Testbed;
use sage::hsm::{Hsm, Migration, TieringPolicy};
use sage::mero::ha::RepairAction;
use sage::mero::sns;
use sage::sim::device::DeviceKind;
use sage::sim::rng::SimRng;

#[test]
fn failure_storm_no_data_loss() {
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut objs = Vec::new();
    let mut datas = Vec::new();
    for i in 0..8u64 {
        let o = c.create_object(4096).unwrap();
        let mut d = vec![0u8; 4 * 65536];
        SimRng::new(i).fill_bytes(&mut d);
        c.write_object(&o, 0, &d).unwrap();
        objs.push(o);
        datas.push(d);
    }
    let ssds = c
        .store
        .cluster
        .devices_where(|d| d.profile.kind == DeviceKind::Ssd);
    let mut rng = SimRng::new(99);
    let mut sched = FailureSchedule::sampled(&ssds, 200.0, 400.0, 0.3, &mut rng);
    let mut t = 0.0;
    while t < 400.0 {
        t += 20.0;
        for ev in sched.due(t) {
            if let FailureKind::Device(d) = ev.kind {
                c.store.cluster.fail_device(d);
            }
            let nodes: Vec<Option<usize>> = (0..c.store.cluster.devices.len())
                .map(|d| c.store.cluster.node_of(d))
                .collect();
            if let RepairAction::RebuildDevice(d) =
                c.store.ha.observe(ev, |x| nodes[x])
            {
                // the recovery plane: repair as one batched op group on
                // a sharded scheduler; repair_done carries the group's
                // wait_all completion; the device returns to service
                c.now = c.now.max(t);
                c.repair_with(&objs, d).unwrap();
            }
        }
    }
    assert_eq!(
        c.store.ha.repair_log.len() as u64,
        c.store.ha.repairs_started,
        "every engaged repair was completed and stamped"
    );
    for (o, d) in objs.iter().zip(datas.iter()) {
        let back = c.read_object(o, 0, d.len() as u64).unwrap();
        assert_eq!(&back, d, "object survived the storm");
    }
}

#[test]
fn ha_ignores_transient_noise_but_catches_patterns() {
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut none = 0;
    let mut drains = 0;
    // scattered transients on different devices: no action
    for d in 0..8usize {
        match c.store.ha.observe(
            FailureEvent { at: d as f64, kind: FailureKind::Transient(d) },
            |_| Some(0),
        ) {
            RepairAction::None => none += 1,
            RepairAction::NodeAlert { .. } => {} // correlation alert ok
            a => panic!("unexpected {a:?}"),
        }
    }
    assert!(none >= 7);
    // hammering one device: proactive drain
    for i in 0..3 {
        if let RepairAction::ProactiveDrain(_) = c.store.ha.observe(
            FailureEvent { at: 100.0 + i as f64, kind: FailureKind::Transient(42) },
            |_| Some(1),
        ) {
            drains += 1;
        }
    }
    assert_eq!(drains, 1);
}

#[test]
fn decided_proactive_drain_executes_and_preempts_rebuild_work() {
    // the full ProactiveDrain story: transients accumulate → the HA
    // subsystem decides a drain → the recovery plane executes it as a
    // session (Client::drain_with) → when the device finally
    // hard-fails there is NOTHING left to rebuild from it
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut objs = Vec::new();
    let mut datas = Vec::new();
    for i in 0..6u64 {
        let o = c.create_object(4096).unwrap();
        let mut d = vec![0u8; 4 * 65536];
        SimRng::new(500 + i).fill_bytes(&mut d);
        c.write_object(&o, 0, &d).unwrap();
        objs.push(o);
        datas.push(d);
    }
    let dev = c.store.object(objs[0]).unwrap().placement(0, 0).unwrap().device;
    let mut decided = None;
    for i in 0..3u32 {
        let a = c.store.ha.observe(
            FailureEvent {
                at: c.now + i as f64,
                kind: FailureKind::Transient(dev),
            },
            |_| Some(0),
        );
        if let RepairAction::ProactiveDrain(d) = a {
            decided = Some(d);
        }
    }
    let d = decided.expect("three transients inside the window decide a drain");
    assert_eq!(d, dev);
    let (bytes, t_drain) = c.drain_with(&objs, d).unwrap();
    assert!(bytes > 0, "resident units moved off the degrading device");
    assert!(c.store.ha.repairing().is_empty());
    assert_eq!(c.store.ha.repair_log.len(), 1, "drain stamped in the log");
    assert!(c.store.ha.mean_repair_time() > 0.0);
    // the drained device eventually hard-fails: the rebuild finds no
    // units on it, and every object still reads back intact
    c.store.cluster.fail_device(d);
    c.now = c.now.max(t_drain + 10.0);
    let at = c.now;
    c.store.ha.observe(
        FailureEvent { at, kind: FailureKind::Device(d) },
        |_| Some(0),
    );
    let (rebuilt, _) = c.repair_with(&objs, d).unwrap();
    assert_eq!(rebuilt, 0, "nothing left to rebuild after the drain");
    assert_eq!(c.store.ha.repair_log.len(), 2, "the rebuild is stamped too");
    for (o, data) in objs.iter().zip(datas.iter()) {
        let back = c.read_object(o, 0, data.len() as u64).unwrap();
        assert_eq!(&back, data, "no data loss across drain + failure");
    }
}

#[test]
fn failure_feed_consumer_recovers_without_test_side_calls() {
    // the closed loop (ISSUE 5 satellite): events flow from the
    // failure feed through the HA decision rules into recovery-plane
    // sessions — the test never calls drain_with/repair_with (or even
    // fail_device) itself
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut objs = Vec::new();
    let mut datas = Vec::new();
    for i in 0..5u64 {
        let o = c.create_object(4096).unwrap();
        let mut d = vec![0u8; 2 * 4 * 65536];
        SimRng::new(900 + i).fill_bytes(&mut d);
        c.write_object(&o, 0, &d).unwrap();
        objs.push(o);
        datas.push(d);
    }
    let dev = c.store.object(objs[0]).unwrap().placement(0, 0).unwrap().device;
    let t0 = c.now;
    // a degrading device: three transients inside the HA window
    let mut feed = FailureSchedule::scripted(vec![
        FailureEvent { at: t0 + 1.0, kind: FailureKind::Transient(dev) },
        FailureEvent { at: t0 + 2.0, kind: FailureKind::Transient(dev) },
        FailureEvent { at: t0 + 3.0, kind: FailureKind::Transient(dev) },
    ]);
    assert_eq!(feed.next_at(), Some(t0 + 1.0));
    c.now = t0 + 5.0;
    let outcomes = c.consume_failure_feed(&mut feed, &objs);
    assert_eq!(outcomes.len(), 3);
    assert!(outcomes.iter().all(|o| o.error.is_none()), "no failed recovery");
    let drained = outcomes
        .iter()
        .find(|o| matches!(o.action, RepairAction::ProactiveDrain(d) if d == dev))
        .expect("the third transient decides a proactive drain");
    assert!(drained.bytes > 0, "the consumer executed the drain itself");
    assert!(drained.completed_at.unwrap() > t0 + 3.0);
    assert!(
        c.store
            .object(objs[0])
            .unwrap()
            .placed_units()
            .all(|u| u.device != dev),
        "units moved off the degrading device"
    );
    assert!(!c.store.cluster.devices[dev].failed, "device stays in service");
    assert_eq!(c.store.ha.repair_log.len(), 1, "drain stamped in the log");

    // later, a HARD failure arrives on the feed: the consumer takes
    // the device out of service AND rebuilds it, again with no
    // test-side call
    let dev2 = c.store.object(objs[1]).unwrap().placement(0, 1).unwrap().device;
    feed.inject(FailureEvent {
        at: c.now + 10.0,
        kind: FailureKind::Device(dev2),
    });
    c.now += 20.0;
    let outcomes = c.consume_failure_feed(&mut feed, &objs);
    assert_eq!(outcomes.len(), 1);
    assert!(outcomes[0].error.is_none());
    assert!(matches!(
        outcomes[0].action,
        RepairAction::RebuildDevice(d) if d == dev2
    ));
    assert!(outcomes[0].bytes > 0, "units rebuilt off the failed device");
    assert!(!c.store.cluster.devices[dev2].failed, "device replaced");
    assert_eq!(c.store.ha.repair_log.len(), 2);
    assert_eq!(feed.remaining(), 0);
    assert_eq!(feed.next_at(), None);
    // no data loss through the whole automated cycle
    for (o, d) in objs.iter().zip(datas.iter()) {
        let back = c.read_object(o, 0, d.len() as u64).unwrap();
        assert_eq!(&back, d, "object intact after feed-driven recovery");
    }
}

#[test]
fn hsm_policies_differ_in_migration_volume() {
    let tb = Testbed::sage_prototype();
    let mk = || {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let mut objs = Vec::new();
        for _ in 0..10 {
            let o = c.create_object(4096).unwrap();
            c.write_object(&o, 0, &vec![1u8; 4 * 65536]).unwrap();
            objs.push(o);
        }
        // skewed access
        for round in 0..100u64 {
            let pick = (round % 3) as usize; // 3 hot objects
            c.read_object(&objs[pick], 0, 65536).unwrap();
        }
        c
    };
    let _ = tb;
    let mut plans = Vec::new();
    for policy in [
        TieringPolicy::HeatWeighted,
        TieringPolicy::Fifo,
        TieringPolicy::Static,
    ] {
        let mut c = mk();
        let mut hsm = Hsm::new(policy);
        let recs = c.fdmi.drain();
        hsm.observe(&recs, &c.store);
        plans.push(hsm.plan(c.now).len());
    }
    assert_eq!(plans[2], 0, "static never migrates");
    assert!(plans[0] > 0, "heat policy acts on skew");
}

#[test]
fn migration_to_failed_tier_errors_cleanly() {
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let o = c.create_object(4096).unwrap();
    c.write_object(&o, 0, &vec![5u8; 4 * 65536]).unwrap();
    // fail ALL nvram devices
    for d in c
        .store
        .cluster
        .devices_where(|d| d.profile.kind == DeviceKind::Nvram)
    {
        c.store.cluster.fail_device(d);
    }
    let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
    let plan = vec![Migration { obj: o, from: DeviceKind::Ssd, to: DeviceKind::Nvram }];
    let res = hsm.migrate(&mut c.store, &plan, 1.0);
    assert!(res.is_err(), "no space on a fully-failed tier");
}

#[test]
fn repaired_device_rearms_and_survives_second_failure() {
    // full recovery-plane cycle: fail → repair_with (batched, sharded)
    // → replace → re-arm via FailureSchedule::inject → fail again →
    // repair again; no data loss, both repairs stamped
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut objs = Vec::new();
    let mut datas = Vec::new();
    for i in 0..4u64 {
        let o = c.create_object(4096).unwrap();
        let mut d = vec![0u8; 4 * 65536];
        SimRng::new(100 + i).fill_bytes(&mut d);
        c.write_object(&o, 0, &d).unwrap();
        objs.push(o);
        datas.push(d);
    }
    let dev = c.store.object(objs[0]).unwrap().placement(0, 0).unwrap().device;
    let mut sched = FailureSchedule::scripted(vec![FailureEvent {
        at: 10.0,
        kind: FailureKind::Device(dev),
    }]);
    let mut completed = Vec::new();
    let mut t = 0.0;
    while t < 100.0 {
        t += 10.0;
        for ev in sched.due(t) {
            let d = ev.kind.device();
            c.store.cluster.fail_device(d);
            if let RepairAction::RebuildDevice(d) =
                c.store.ha.observe(ev, |_| Some(0))
            {
                c.now = c.now.max(ev.at);
                let (_, t_done) = c.repair_with(&objs, d).unwrap();
                completed.push(t_done);
                // the repaired device rejoins the failure population
                if completed.len() == 1 {
                    sched.inject(FailureEvent {
                        at: t_done + 20.0,
                        kind: FailureKind::Device(d),
                    });
                }
            }
        }
    }
    assert_eq!(completed.len(), 2, "the re-armed failure was repaired too");
    assert_eq!(c.store.ha.repair_log.len(), 2);
    assert!(c.store.ha.mean_repair_time() >= 0.0);
    for (o, d) in objs.iter().zip(datas.iter()) {
        let back = c.read_object(o, 0, d.len() as u64).unwrap();
        assert_eq!(&back, d, "no data loss across the re-armed cycle");
    }
}

#[test]
fn repair_throughput_accounted_in_virtual_time() {
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut objs = Vec::new();
    for i in 0..4u64 {
        let o = c.create_object(4096).unwrap();
        let mut d = vec![0u8; 8 * 65536];
        SimRng::new(i).fill_bytes(&mut d);
        c.write_object(&o, 0, &d).unwrap();
        objs.push(o);
    }
    let dev = c.store.object(objs[0]).unwrap().placement(0, 0).unwrap().device;
    c.store.cluster.fail_device(dev);
    let (bytes, t_done) = sns::repair(&mut c.store, &objs, dev, 10.0).unwrap();
    assert!(bytes > 0);
    assert!(t_done > 10.0, "rebuild takes real virtual time");
}
