//! Property tests for sharded op execution (ISSUE 2 tentpole):
//!
//! 1. **Determinism** — the sharded scheduler produces byte-identical
//!    reads AND bit-identical `wait_all` virtual times across repeated
//!    runs with the same seed.
//! 2. **Byte-equivalence** — batched writes/reads through the sharded
//!    engine store and return the same bytes as the preserved
//!    serial-fold oracle (`sage::mero::sns_serial`), healthy and
//!    degraded.
//! 3. **No-slower** — sharded completion <= serial-fold completion on
//!    EVERY sampled geometry (a slow device only delays the stripes
//!    that touch it; the fold delays everything behind it).

use sage::bench::testkit::{self, span, Geometry, BS};
use sage::clovis::{Client, Extent};
use sage::config::Testbed;
use sage::mero::{sns_serial, Layout, MeroStore, ObjectId};
use sage::proptest::prop_check;

/// This suite's historical sampling family (see `bench::testkit`).
const GEO: Geometry = Geometry::SCHED;

fn layout(k: u32, p: u32) -> Layout {
    testkit::raid(k, p)
}

/// Deterministic payload for extent (idx, len_blocks).
fn bytes_for(idx: u64, len_blocks: u64) -> Vec<u8> {
    GEO.bytes_for(idx, len_blocks)
}

fn gen_extents(r: &mut sage::sim::rng::SimRng) -> Vec<(u64, u64)> {
    GEO.gen_extents(r)
}

/// Serial-fold store with the extents applied as one chained batch.
/// Returns (store, object, batch completion time).
fn serial_store(
    k: u32,
    p: u32,
    extents: &[(u64, u64)],
) -> (MeroStore, ObjectId, f64) {
    let mut s = MeroStore::new(Testbed::sage_prototype().build_cluster());
    let id = s.create_object(BS, layout(k, p)).unwrap();
    let datas: Vec<Vec<u8>> = extents
        .iter()
        .map(|(idx, lenb)| bytes_for(*idx, *lenb))
        .collect();
    let refs: Vec<(u64, &[u8])> = extents
        .iter()
        .zip(datas.iter())
        .filter(|(_, d)| !d.is_empty())
        .map(|((idx, _), d)| (idx * BS, d.as_slice()))
        .collect();
    let t = sns_serial::writev(&mut s, id, &refs, 0.0, None).unwrap();
    (s, id, t)
}

/// Sharded client with the extents applied as ONE batched writev.
/// Returns (client, object, group completion time).
fn sharded_client(
    k: u32,
    p: u32,
    extents: &[(u64, u64)],
) -> (Client, ObjectId, f64) {
    let mut c = testkit::sage_client();
    let obj = c.create_object_with(BS, layout(k, p)).unwrap();
    let datas: Vec<Vec<u8>> = extents
        .iter()
        .map(|(idx, lenb)| bytes_for(*idx, *lenb))
        .collect();
    let refs: Vec<(u64, &[u8])> = extents
        .iter()
        .zip(datas.iter())
        .filter(|(_, d)| !d.is_empty())
        .map(|((idx, _), d)| (idx * BS, d.as_slice()))
        .collect();
    let t = c.writev(&obj, &refs).unwrap();
    (c, obj, t)
}

#[test]
fn prop_sharded_execution_is_deterministic() {
    for (k, p) in [(4u32, 1u32), (3, 2)] {
        prop_check(
            &format!("sched-deterministic-{k}+{p}"),
            20,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                if total == 0 {
                    return true;
                }
                let run = || {
                    let (mut c, obj, t_batch) = sharded_client(k, p, extents);
                    let mut buf = vec![0x5Au8; total as usize];
                    c.read_object_into(&obj, 0, &mut buf).unwrap();
                    (buf, t_batch.to_bits(), c.now.to_bits())
                };
                run() == run()
            },
        );
    }
}

#[test]
fn prop_sharded_bytes_match_serial_oracle() {
    for (k, p) in [(2u32, 1u32), (4, 1), (3, 2), (4, 2), (4, 0)] {
        prop_check(
            &format!("sched-bytes-{k}+{p}"),
            20,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                if total == 0 {
                    return true;
                }
                let (mut ser, ids, _) = serial_store(k, p, extents);
                let (mut cli, obj, _) = sharded_client(k, p, extents);
                let (want, _) =
                    sns_serial::read(&mut ser, ids, 0, total, 100.0).unwrap();
                let mut got = vec![0xA5u8; total as usize];
                cli.read_object_into(&obj, 0, &mut got).unwrap();
                let got2 = cli.read_object(&obj, 0, total).unwrap();
                want == got && want == got2
            },
        );
    }
}

#[test]
fn prop_sharded_degraded_reads_match_serial_oracle() {
    for (k, p) in [(2u32, 1u32), (4, 1), (3, 2)] {
        prop_check(
            &format!("sched-degraded-{k}+{p}"),
            15,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                if total == 0 {
                    return true;
                }
                let (mut ser, ids, _) = serial_store(k, p, extents);
                let (mut cli, obj, _) = sharded_client(k, p, extents);
                // fail the device of the same LOGICAL unit in each store
                let unit = if k > 1 { 1 } else { 0 };
                let ds = ser.object(ids).unwrap().placement(0, unit).copied();
                let dc =
                    cli.store.object(obj).unwrap().placement(0, unit).copied();
                match (ds, dc) {
                    (Some(us), Some(uc)) => {
                        ser.cluster.fail_device(us.device);
                        cli.store.cluster.fail_device(uc.device);
                    }
                    // stripe 0 untouched by the extents: nothing to fail
                    (None, None) => return true,
                    _ => return false, // placement maps must agree
                }
                let want = sns_serial::read(&mut ser, ids, 0, total, 100.0)
                    .map(|(d, _)| d);
                let mut buf = vec![0x3Cu8; total as usize];
                let got = cli
                    .read_object_into(&obj, 0, &mut buf)
                    .map(|_| buf.clone());
                match (want, got) {
                    (Ok(a), Ok(b)) => a == b,
                    // both engines must agree that data is unavailable
                    (Err(_), Err(_)) => true,
                    _ => false,
                }
            },
        );
    }
}

#[test]
fn prop_sharded_completion_leq_serial_fold() {
    // the acceptance property: on every sampled geometry — including
    // parity-heavy and parity-free — dispatching the batch to
    // per-device shards never finishes later than the serial fold
    for (k, p) in [(2u32, 1u32), (4, 1), (3, 2), (4, 2), (4, 0)] {
        prop_check(
            &format!("sched-leq-serial-{k}+{p}"),
            20,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                if total == 0 {
                    return true;
                }
                // write batch
                let (mut ser, ids, t_ser_w) = serial_store(k, p, extents);
                let (mut cli, obj, t_sh_w) = sharded_client(k, p, extents);
                if t_sh_w > t_ser_w * (1.0 + 1e-9) + 1e-12 {
                    return false;
                }
                // read batch over the same extents, from the write
                // completion of each engine
                let r_exts: Vec<(u64, u64)> = extents
                    .iter()
                    .filter(|(_, l)| *l > 0)
                    .map(|(i, l)| (i * BS, l * BS))
                    .collect();
                let (_, t_ser_r) =
                    sns_serial::readv(&mut ser, ids, &r_exts, t_ser_w).unwrap();
                cli.now = t_sh_w;
                let clovis_exts: Vec<Extent> = r_exts
                    .iter()
                    .map(|(o, l)| Extent::new(*o, *l))
                    .collect();
                cli.readv(&obj, &clovis_exts).unwrap();
                let t_sh_r = cli.now;
                t_sh_r <= t_ser_r * (1.0 + 1e-9) + 1e-12
            },
        );
    }
}
