//! Property tests for the storm-hardened recovery plane (ISSUE 6):
//!
//! 1. **No event dropped** — a consumer pass over a multi-event batch
//!    (storms included, beyond parity tolerance included) returns one
//!    [`RecoveryOutcome`] per event consumed: erroring recoveries
//!    surface per-event (`error` + typed verdict) and later events of
//!    the same batch are still consumed and accounted.
//! 2. **Bit-identical determinism** — two clients fed the same
//!    schedule produce identical outcomes: completion times compare
//!    equal via `f64::to_bits`, verdicts and byte counts match, and
//!    the surviving objects read back byte-identical.
//! 3. **No-storm runs reproduce the pre-storm consumer bit-exactly** —
//!    when hard failures are well separated (every due batch carries
//!    at most one), the storm-hardened consumer's recovery schedule is
//!    bit-identical to the legacy observe→repair/drain loop it
//!    replaced (PR-5 semantics pinned).
//! 4. **Rebalance placement equivalence** — an elastic expansion moves
//!    units onto the newcomer, but every object the plan does not
//!    touch keeps its placement map exactly; a second rebalance is a
//!    no-op (the plan converges).

use sage::bench::testkit::populated;
use sage::clovis::{Client, RecoveryVerdict};
use sage::cluster::failure::{FailureEvent, FailureKind, FailureSchedule};
use sage::mero::ha::RepairAction;
use sage::mero::ObjectId;
use sage::proptest::prop_check;
use sage::sim::device::DeviceKind;
use sage::sim::rng::SimRng;

/// One encoded failure event: `(selector, at_millis)`. The selector
/// picks the device (within the SSD tier) and whether the event is a
/// hard failure or a transient; millis keep time shrinkable as an
/// integer.
type EventCode = (usize, u64);

fn decode(codes: &[EventCode], ssds: &[usize], base: f64, spread: f64) -> Vec<FailureEvent> {
    codes
        .iter()
        .map(|&(sel, ms)| {
            let d = ssds[(sel / 2) % ssds.len()];
            let kind = if sel % 2 == 0 {
                FailureKind::Device(d)
            } else {
                FailureKind::Transient(d)
            };
            FailureEvent { at: base + (ms % 5000) as f64 / 5000.0 * spread, kind }
        })
        .collect()
}

fn gen_codes(r: &mut SimRng) -> Vec<EventCode> {
    let n = 1 + r.gen_index(6);
    (0..n)
        .map(|_| (r.gen_index(32), r.gen_range(5000)))
        .collect()
}

#[test]
fn prop_no_event_dropped_even_past_parity() {
    prop_check("storm-no-event-dropped", 16, gen_codes, |codes: &Vec<EventCode>| {
        let (mut c, objs) = populated(3, 0xA11CE);
        let ssds = c
            .store
            .cluster
            .devices_where(|d| d.profile.kind == DeviceKind::Ssd);
        // everything lands in one due batch — storms of any width,
        // beyond parity tolerance included
        let events = decode(codes, &ssds, 1.0, 1.0);
        let n_events = events.len();
        let mut feed = FailureSchedule::scripted(events);
        c.now = 10.0;
        let ids: Vec<ObjectId> = objs.iter().map(|(id, _)| *id).collect();
        let outcomes = c.consume_failure_feed(&mut feed, &ids);
        // one outcome per event, feed fully drained
        if outcomes.len() != n_events || feed.remaining() != 0 {
            return false;
        }
        // per-event error surfacing: every Failed/DataLoss outcome
        // carries its error, and events AFTER the first error are
        // still consumed (they have outcomes — checked by the length
        // equality above) with verdicts of their own
        for out in &outcomes {
            let is_err = matches!(
                out.verdict,
                RecoveryVerdict::Failed | RecoveryVerdict::DataLoss { .. }
            );
            if is_err != out.error.is_some() {
                return false;
            }
        }
        // accounting: lost objects error on read, everything else is
        // byte-exact (possibly degraded-read reconstructed)
        let lost: Vec<ObjectId> = outcomes
            .iter()
            .filter_map(|o| match &o.verdict {
                RecoveryVerdict::DataLoss { objects } => Some(objects.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        for (id, data) in &objs {
            let r = c.read_object(id, 0, data.len() as u64);
            if lost.contains(id) {
                if r.is_ok() {
                    return false;
                }
            } else {
                match r {
                    Ok(got) if &got == data => {}
                    _ => return false,
                }
            }
        }
        true
    });
}

#[test]
fn prop_consumer_is_bit_deterministic() {
    prop_check("storm-bit-determinism", 12, gen_codes, |codes: &Vec<EventCode>| {
        let run = |codes: &[EventCode]| {
            let (mut c, objs) = populated(3, 0xB0B);
            let ssds = c
                .store
                .cluster
                .devices_where(|d| d.profile.kind == DeviceKind::Ssd);
            let mut feed =
                FailureSchedule::scripted(decode(codes, &ssds, 1.0, 4.0));
            c.now = 10.0;
            let ids: Vec<ObjectId> = objs.iter().map(|(id, _)| *id).collect();
            let outcomes = c.consume_failure_feed(&mut feed, &ids);
            let reads: Vec<Option<Vec<u8>>> = objs
                .iter()
                .map(|(id, d)| c.read_object(id, 0, d.len() as u64).ok())
                .collect();
            (outcomes, reads, c.now)
        };
        let (oa, ra, na) = run(codes);
        let (ob, rb, nb) = run(codes);
        if oa.len() != ob.len() || ra != rb || na.to_bits() != nb.to_bits() {
            return false;
        }
        oa.iter().zip(ob.iter()).all(|(a, b)| {
            a.verdict == b.verdict
                && a.bytes == b.bytes
                && a.event.at.to_bits() == b.event.at.to_bits()
                && match (a.completed_at, b.completed_at) {
                    (Some(x), Some(y)) => x.to_bits() == y.to_bits(),
                    (None, None) => true,
                    _ => false,
                }
        })
    });
}

#[test]
fn prop_no_storm_passes_match_the_prestorm_consumer_bit_exactly() {
    // hard failures spaced 100 virtual seconds apart: every due batch
    // carries at most one, which is exactly the regime the pre-storm
    // consumer handled — the hardened consumer must reproduce its
    // schedule bit-for-bit (the legacy loop is inlined here as the
    // oracle: fail → observe → repair_with/drain_with)
    prop_check("no-storm-prestorm-bitexact", 10, gen_codes, |codes: &Vec<EventCode>| {
        let (mut a, objs_a) = populated(3, 0xCAFE);
        let (mut b, objs_b) = populated(3, 0xCAFE);
        let ssds = a
            .store
            .cluster
            .devices_where(|d| d.profile.kind == DeviceKind::Ssd);
        let ids_a: Vec<ObjectId> = objs_a.iter().map(|(id, _)| *id).collect();
        let ids_b: Vec<ObjectId> = objs_b.iter().map(|(id, _)| *id).collect();
        // one event per 100s slot — repairs of these tiny objects
        // complete in well under a slot, so no window ever overlaps
        let mut events: Vec<FailureEvent> = Vec::new();
        for (i, &(sel, _ms)) in codes.iter().enumerate() {
            let d = ssds[(sel / 2) % ssds.len()];
            let kind = if sel % 2 == 0 {
                FailureKind::Device(d)
            } else {
                FailureKind::Transient(d)
            };
            events.push(FailureEvent { at: 100.0 * (i + 1) as f64, kind });
        }
        let mut feed = FailureSchedule::scripted(events.clone());
        let n_devs = b.store.cluster.devices.len();
        let nodes: Vec<Option<usize>> =
            (0..n_devs).map(|d| b.store.cluster.node_of(d)).collect();
        for event in events {
            // hardened consumer: one pass per event
            a.now = a.now.max(event.at);
            let outcomes = a.consume_failure_feed(&mut feed, &ids_a);
            if outcomes.len() != 1 {
                return false;
            }
            // legacy PR-5 loop on the paired client
            b.now = b.now.max(event.at);
            if let FailureKind::Device(d) = event.kind {
                if !b.store.cluster.devices[d].failed {
                    b.store.cluster.fail_device(d);
                }
            }
            let action = b.store.ha.observe(event, |d| nodes[d]);
            let legacy = match action {
                RepairAction::RebuildDevice(d) => {
                    Some(b.repair_with(&ids_b, d).unwrap())
                }
                RepairAction::ProactiveDrain(d) => {
                    Some(b.drain_with(&ids_b, d).unwrap())
                }
                _ => None,
            };
            // schedules must agree bit-for-bit
            let out = &outcomes[0];
            match (legacy, out.completed_at) {
                (Some((bytes, t)), Some(tc)) => {
                    if bytes != out.bytes || t.to_bits() != tc.to_bits() {
                        return false;
                    }
                }
                (None, None) => {}
                _ => return false,
            }
            if a.now.to_bits() != b.now.to_bits() {
                return false;
            }
        }
        // end state: identical HA ledgers and identical bytes
        if a.store.ha.repair_log != b.store.ha.repair_log {
            return false;
        }
        objs_a.iter().zip(objs_b.iter()).all(|((ia, da), (ib, _))| {
            a.read_object(ia, 0, da.len() as u64).unwrap()
                == b.read_object(ib, 0, da.len() as u64).unwrap()
        })
    });
}

#[test]
fn prop_rebalance_leaves_untouched_objects_placed_identically() {
    prop_check(
        "rebalance-placement-equivalence",
        10,
        |r| (1 + r.gen_index(4), 1 + r.gen_range(3)),
        |&(n_moved, stripes): &(usize, u64)| {
            // population: `n_moved` objects offered to the rebalance,
            // plus 2 bystanders that are NOT in the rebalance set
            let (mut c, _) = populated(0, 0);
            let mut offered = Vec::new();
            let mut bystanders = Vec::new();
            for i in 0..(n_moved + 2) {
                let id = c.create_object(4096).unwrap();
                let data = vec![i as u8 + 1; (stripes * 4 * 65536) as usize];
                c.write_object(&id, 0, &data).unwrap();
                if i < n_moved {
                    offered.push((id, data));
                } else {
                    bystanders.push((id, data));
                }
            }
            let placements = |c: &Client, id: ObjectId| {
                c.store
                    .object(id)
                    .unwrap()
                    .placed_units()
                    .copied()
                    .collect::<Vec<_>>()
            };
            let before: Vec<_> = bystanders
                .iter()
                .map(|(id, _)| placements(&c, *id))
                .collect();
            let src = c.store.object(offered[0].0).unwrap().placement(0, 0).unwrap().device;
            let profile = c.store.cluster.devices[src].profile.clone();
            let ids: Vec<ObjectId> = offered.iter().map(|(id, _)| *id).collect();
            let (dev, bytes, _) = c.expand_pool(1, profile, &ids).unwrap();
            if bytes == 0 {
                return false; // a loaded pool must shed onto the newcomer
            }
            // untouched objects keep their placement maps exactly
            for ((id, _), want) in bystanders.iter().zip(before.iter()) {
                if &placements(&c, *id) != want {
                    return false;
                }
            }
            // every byte still reads back, moved and unmoved alike
            for (id, data) in offered.iter().chain(bystanders.iter()) {
                if c.read_object(id, 0, data.len() as u64).unwrap() != *data {
                    return false;
                }
            }
            // the plan converges: an immediate second rebalance onto
            // the same device moves nothing
            let mut s = c.session();
            let h = s.rebalance(&ids, dev);
            let rep = s.run().unwrap();
            matches!(rep.output(h), sage::clovis::OpOutput::Rebalance { bytes: 0 })
        },
    );
}
