//! Integration: Mero object store across layout types, tiers, failure
//! and repair — multiple modules composing (pool + layout + sns + ha).

use sage::cluster::failure::{FailureEvent, FailureKind};
use sage::config::Testbed;
use sage::mero::ha::RepairAction;
use sage::mero::{sns, Layout, MeroStore};
use sage::sim::device::DeviceKind;
use sage::sim::rng::SimRng;

fn store() -> MeroStore {
    MeroStore::new(Testbed::sage_prototype().build_cluster())
}

fn blob(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = SimRng::new(seed);
    let mut v = vec![0u8; n];
    rng.fill_bytes(&mut v);
    v
}

#[test]
fn all_layouts_roundtrip() {
    let mut s = store();
    let layouts = vec![
        Layout::Raid { data: 4, parity: 1, unit: 65536, tier: DeviceKind::Ssd },
        Layout::Raid { data: 8, parity: 1, unit: 16384, tier: DeviceKind::Hdd },
        Layout::Raid { data: 2, parity: 0, unit: 4096, tier: DeviceKind::Nvram },
        Layout::Mirror { copies: 2, tier: DeviceKind::Ssd },
        Layout::Compressed {
            inner: Box::new(Layout::Raid {
                data: 4,
                parity: 1,
                unit: 65536,
                tier: DeviceKind::Smr,
            }),
        },
    ];
    for (i, layout) in layouts.into_iter().enumerate() {
        let id = s.create_object(4096, layout).unwrap();
        let data = blob(256 * 1024, i as u64);
        let t = s.write_object(id, 0, &data, 0.0, None).unwrap();
        let (back, _) = s.read_object(id, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data, "layout #{i}");
    }
}

#[test]
fn tier_placement_follows_layout() {
    let mut s = store();
    let id = s
        .create_object(
            4096,
            Layout::Raid { data: 2, parity: 1, unit: 16384, tier: DeviceKind::Nvram },
        )
        .unwrap();
    s.write_object(id, 0, &blob(64 * 1024, 9), 0.0, None).unwrap();
    for u in s.object(id).unwrap().placed_units() {
        assert_eq!(
            s.cluster.devices[u.device].profile.kind,
            DeviceKind::Nvram
        );
    }
}

#[test]
fn failure_repair_cycle_via_ha() {
    let mut s = store();
    let mut objs = Vec::new();
    let mut datas = Vec::new();
    for i in 0..6u64 {
        let id = s.create_object(4096, Layout::default()).unwrap();
        let d = blob(4 * 65536, i);
        s.write_object(id, 0, &d, 0.0, None).unwrap();
        objs.push(id);
        datas.push(d);
    }
    // hard-fail the device holding the first object's first unit
    let dev = s.object(objs[0]).unwrap().placement(0, 0).unwrap().device;
    s.cluster.fail_device(dev);
    let nodes: Vec<Option<usize>> =
        (0..s.cluster.devices.len()).map(|d| s.cluster.node_of(d)).collect();
    let action = s.ha.observe(
        FailureEvent { at: 1.0, kind: FailureKind::Device(dev) },
        |d| nodes[d],
    );
    assert_eq!(action, RepairAction::RebuildDevice(dev));
    let (rebuilt, t_repair) = sns::repair(&mut s, &objs, dev, 1.0).unwrap();
    assert!(rebuilt > 0);
    s.cluster.replace_device(dev);
    s.ha.repair_done(dev, t_repair);
    assert_eq!(s.ha.repair_log.len(), 1, "completion stamped in the log");
    // everything still reads back
    for (id, d) in objs.iter().zip(datas.iter()) {
        let (back, _) = s.read_object(*id, 0, d.len() as u64, 2.0).unwrap();
        assert_eq!(&back, d);
    }
}

#[test]
fn composite_layout_spans_tiers() {
    let mut s = store();
    let layout = Layout::Composite {
        extents: vec![
            (
                0,
                128 * 1024,
                Layout::Raid { data: 2, parity: 1, unit: 16384, tier: DeviceKind::Nvram },
            ),
            (
                128 * 1024,
                1 << 30,
                Layout::Raid { data: 4, parity: 1, unit: 65536, tier: DeviceKind::Hdd },
            ),
        ],
    };
    let id = s.create_object(4096, layout).unwrap();
    // write into the second extent
    let d = blob(4 * 65536, 3);
    s.write_object(id, 1 << 20, &d, 0.0, None).unwrap();
    let (back, _) = s.read_object(id, 1 << 20, d.len() as u64, 1.0).unwrap();
    assert_eq!(back, d);
    for u in s.object(id).unwrap().placed_units() {
        assert_eq!(s.cluster.devices[u.device].profile.kind, DeviceKind::Hdd);
    }
}

#[test]
fn space_accounting_balances() {
    let mut s = store();
    let free0 = s.pools.free_bytes(&s.cluster, DeviceKind::Ssd);
    let id = s.create_object(4096, Layout::default()).unwrap();
    s.write_object(id, 0, &blob(4 * 65536, 4), 0.0, None).unwrap();
    assert!(s.pools.free_bytes(&s.cluster, DeviceKind::Ssd) < free0);
    s.delete_object(id).unwrap();
    assert_eq!(s.pools.free_bytes(&s.cluster, DeviceKind::Ssd), free0);
}

#[test]
fn io_time_ordering_nvram_faster_than_smr() {
    let mut s = store();
    let mk = |s: &mut MeroStore, tier| {
        s.create_object(
            4096,
            Layout::Raid { data: 2, parity: 1, unit: 65536, tier },
        )
        .unwrap()
    };
    let nv = mk(&mut s, DeviceKind::Nvram);
    let sm = mk(&mut s, DeviceKind::Smr);
    let d = blob(2 * 65536, 5);
    let t_nv = s.write_object(nv, 0, &d, 0.0, None).unwrap();
    // measure SMR from t=0-equivalent by subtracting the NVRAM finish
    let t_sm = s.write_object(sm, 0, &d, 0.0, None).unwrap();
    assert!(t_nv < t_sm, "nvram {t_nv} vs smr {t_sm}");
}

#[test]
fn sparse_reads_return_zeros_without_io() {
    let mut s = store();
    let id = s.create_object(4096, Layout::default()).unwrap();
    s.write_object(id, 0, &blob(4 * 65536, 6), 0.0, None).unwrap();
    // far-away never-written extent: zeros
    let (back, _) = s.read_object(id, 40 * 65536, 4096, 1.0).unwrap();
    assert!(back.iter().all(|&b| b == 0));
}
