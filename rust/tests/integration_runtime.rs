//! Integration: the PJRT runtime — AOT artifacts loaded from
//! `artifacts/` and executed from rust, with results checked against
//! CPU references. Skipped gracefully when artifacts are missing
//! (run `make artifacts` first).

use sage::mero::sns;
use sage::runtime::Executor;
use sage::sim::rng::SimRng;

fn executor() -> Option<Executor> {
    // tests run from the workspace root
    match Executor::load_default() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn artifacts_manifest_covers_expected_variants() {
    let Some(e) = executor() else { return };
    for name in [
        "parity_k4",
        "parity_k8",
        "postprocess_16k",
        "postprocess_64k",
        "alf_histogram_64k",
        "integrity_16x4k",
    ] {
        assert!(e.has(name), "missing artifact {name}");
        let info = e.info(name).unwrap();
        assert!(info.num_outputs >= 1);
    }
}

#[test]
fn kernel_parity_equals_cpu_parity() {
    let Some(e) = executor() else { return };
    let mut rng = SimRng::new(0xBEEF);
    for k in [4usize, 8] {
        let units: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let mut v = vec![0u8; 65536];
                rng.fill_bytes(&mut v);
                v
            })
            .collect();
        let kernel = e.parity(&units).unwrap().expect("variant exists");
        let cpu = sns::cpu_parity(&units);
        assert_eq!(kernel, cpu, "k={k}: Pallas parity == CPU XOR");
    }
}

#[test]
fn kernel_parity_partial_unit_padding() {
    let Some(e) = executor() else { return };
    let mut rng = SimRng::new(3);
    // units smaller than the artifact lane count: zero-padded
    let units: Vec<Vec<u8>> = (0..4)
        .map(|_| {
            let mut v = vec![0u8; 1000];
            rng.fill_bytes(&mut v);
            v
        })
        .collect();
    let kernel = e.parity(&units).unwrap().expect("padded path");
    assert_eq!(kernel.len(), 1000);
    assert_eq!(kernel, sns::cpu_parity(&units));
}

#[test]
fn kernel_postprocess_counts_and_energies() {
    let Some(e) = executor() else { return };
    let n = 10_000;
    let hot = 321;
    let mut rows = Vec::with_capacity(n * 8);
    for i in 0..n {
        let speed = if i < hot { 4.0f32 } else { 0.1 };
        rows.extend_from_slice(&[0.0, 0.0, 0.0, speed, 0.0, 0.0, 2.0, i as f32]);
    }
    let out = e.postprocess(&rows, 1.0).unwrap().expect("16k variant");
    assert_eq!(out.selected, hot);
    assert_eq!(out.energies.len(), n);
    // E = 0.5*|q|*v^2 = 0.5*2*16 = 16 for hot particles
    assert!((out.energies[0] - 16.0).abs() < 1e-4);
    assert_eq!(out.mask[hot - 1], 1.0);
    assert_eq!(out.mask[hot], 0.0);
}

#[test]
fn kernel_histogram_matches_manual_binning() {
    let Some(e) = executor() else { return };
    let mut rng = SimRng::new(77);
    let vals: Vec<f32> = (0..100_000)
        .map(|_| rng.gen_uniform(0.0, 64.0) as f32)
        .collect();
    let counts = e.histogram(&vals, 0.0, 64.0).unwrap().expect("variant");
    let mut manual = vec![0f32; 64];
    for &v in &vals {
        manual[(v as usize).min(63)] += 1.0;
    }
    assert_eq!(counts.iter().sum::<f32>(), 100_000.0);
    for (a, b) in counts.iter().zip(manual.iter()) {
        assert!((a - b).abs() < 0.5, "{a} vs {b}");
    }
}

#[test]
fn kernel_integrity_stable_and_sensitive() {
    let Some(e) = executor() else { return };
    let mut rng = SimRng::new(5);
    let blocks: Vec<i32> =
        (0..16 * 4096).map(|_| rng.next_u64() as i32).collect();
    let d1 = e.integrity(&blocks).unwrap().expect("variant");
    let d2 = e.integrity(&blocks).unwrap().unwrap();
    assert_eq!(d1, d2, "digests deterministic");
    let mut corrupted = blocks.clone();
    corrupted[5 * 4096 + 17] ^= 1;
    let d3 = e.integrity(&corrupted).unwrap().unwrap();
    assert_ne!(d1[5], d3[5], "corruption detected in block 5");
    assert_eq!(d1[4], d3[4], "other blocks unaffected");
}

#[test]
fn sns_write_path_uses_kernel_when_available() {
    let Some(e) = executor() else { return };
    use sage::config::Testbed;
    use sage::mero::{Layout, MeroStore};
    use sage::sim::device::DeviceKind;
    let mut s = MeroStore::new(Testbed::sage_prototype().build_cluster());
    let id = s
        .create_object(
            4096,
            Layout::Raid { data: 4, parity: 1, unit: 65536, tier: DeviceKind::Ssd },
        )
        .unwrap();
    let mut data = vec![0u8; 4 * 65536];
    SimRng::new(1).fill_bytes(&mut data);
    // write THROUGH the executor (kernel parity on the write path)
    s.write_object(id, 0, &data, 0.0, Some(&e)).unwrap();
    // degraded read must reconstruct with the kernel-computed parity
    let dev = s.object(id).unwrap().placement(0, 2).unwrap().device;
    s.cluster.fail_device(dev);
    let (back, _) = s.read_object(id, 0, data.len() as u64, 1.0).unwrap();
    assert_eq!(back, data, "kernel parity reconstructs exactly");
}
