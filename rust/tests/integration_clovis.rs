//! Integration: the Clovis API surface — client ops, transactions,
//! function shipping, FDMI → HSM wiring, ADDB accounting.

use sage::clovis::fdmi::{FdmiRecord, FdmiPlugin};
use sage::clovis::{Client, FnOutput, FunctionKind};
use sage::config::Testbed;
use sage::hsm::{Hsm, TieringPolicy};
use sage::sim::device::DeviceKind;

fn client() -> Client {
    Client::new_sim(Testbed::sage_prototype())
}

#[test]
fn end_to_end_object_workflow() {
    let mut c = client();
    let cont = c.create_container("workflow", Some(DeviceKind::Ssd));
    let mut objs = Vec::new();
    for i in 0..4u8 {
        let o = c.create_object(4096).unwrap();
        c.write_object(&o, 0, &vec![i; 4 * 65536]).unwrap();
        c.container_add(cont, o).unwrap();
        objs.push(o);
    }
    // one-shot container scrub (§3.2.1)
    let results = c.ship_to_container(cont, FunctionKind::IntegrityCheck).unwrap();
    assert_eq!(results.len(), 4);
    for r in &results {
        assert!(matches!(r.output, FnOutput::Digests(_)));
        assert!(r.net_bytes < r.net_bytes_moved);
    }
    // time advanced monotonically through the workflow
    assert!(c.now > 0.0);
}

#[test]
fn transactions_isolate_and_conflict() {
    let mut c = client();
    let t1 = c.tx_begin();
    let t2 = c.tx_begin();
    assert_eq!(c.tx_get(t1, b"counter").unwrap(), None);
    c.tx_put(t2, b"counter".to_vec(), b"1".to_vec()).unwrap();
    c.tx_commit(t2).unwrap();
    // t1 read "counter" before t2's commit -> conflict on commit
    c.tx_put(t1, b"derived".to_vec(), b"x".to_vec()).unwrap();
    assert!(c.tx_commit(t1).is_err());
    // retry succeeds
    let t3 = c.tx_begin();
    let v = c.tx_get(t3, b"counter").unwrap().unwrap();
    assert_eq!(v, b"1");
    c.tx_put(t3, b"derived".to_vec(), b"from-1".to_vec()).unwrap();
    c.tx_commit(t3).unwrap();
}

#[test]
fn kv_gateway_namespace() {
    // pNFS-style namespace over the KVS (§3.2.3 Parallel File System
    // Access): paths are keys, object ids are values
    let mut c = client();
    let ns = c.create_index();
    let o1 = c.create_object(4096).unwrap();
    let o2 = c.create_object(4096).unwrap();
    c.idx_put(ns, vec![
        (b"/sim/out/step1.h5".to_vec(), format!("{}", o1.0).into_bytes()),
        (b"/sim/out/step2.h5".to_vec(), format!("{}", o2.0).into_bytes()),
    ])
    .unwrap();
    // directory listing = ordered scan
    let entries = c.store.index(ns).unwrap().scan(b"/sim/out/", 10);
    assert_eq!(entries.len(), 2);
    assert!(entries[0].0 < entries[1].0);
    // NEXT walks the namespace
    let nx = c.idx_next(ns, &[b"/sim/out/step1.h5".to_vec()]).unwrap();
    assert_eq!(nx[0].as_ref().unwrap().0, b"/sim/out/step2.h5".to_vec());
}

struct Indexer {
    seen: std::sync::Arc<std::sync::Mutex<Vec<u64>>>,
}

impl FdmiPlugin for Indexer {
    fn name(&self) -> &str {
        "indexer"
    }
    fn filter(&self, rec: &FdmiRecord) -> bool {
        matches!(rec, FdmiRecord::ObjectWritten { .. })
    }
    fn deliver(&mut self, rec: &FdmiRecord) {
        self.seen.lock().unwrap().push(rec.object().0);
    }
}

#[test]
fn fdmi_plugin_receives_writes_and_hsm_consumes() {
    let mut c = client();
    let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    c.fdmi.register(Box::new(Indexer { seen: seen.clone() }));

    let o = c.create_object(4096).unwrap();
    c.write_object(&o, 0, &vec![1u8; 4 * 65536]).unwrap();
    c.read_object(&o, 0, 65536).unwrap();
    assert_eq!(seen.lock().unwrap().as_slice(), &[o.0]);

    // HSM consumes the same bus via drain
    let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
    let recs = c.fdmi.drain();
    assert!(recs.len() >= 3); // create + write + read
    hsm.observe(&recs, &c.store);
    assert_eq!(hsm.tracked(), 1);
    assert!(hsm.score(o, c.now) > 0.0);
}

#[test]
fn addb_telemetry_aggregates_workflow() {
    let mut c = client();
    let o = c.create_object(4096).unwrap();
    for _ in 0..5 {
        c.write_object(&o, 0, &vec![9u8; 4 * 65536]).unwrap();
    }
    assert_eq!(c.addb.total("clovis", "obj_write_bytes"), 5.0 * 4.0 * 65536.0);
    let report = c.addb.report();
    assert!(report.contains("clovis.obj_write_bytes"));
}

#[test]
fn shipped_particle_filter_matches_cpu_reference() {
    let mut c = client();
    let obj = c.create_object(4096).unwrap();
    // 2048 particles, 100 hot (speed 10)
    let mut bytes = Vec::new();
    for i in 0..2048 {
        let speed = if i < 100 { 10.0f32 } else { 0.01 };
        for v in [0.0f32, 0.0, 0.0, speed, 0.0, 0.0, 1.0, i as f32] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    bytes.resize(4 * 65536, 0);
    c.write_object(&obj, 0, &bytes).unwrap();
    let r = c
        .ship_to_object(obj, FunctionKind::ParticleFilter { threshold: 1.0 })
        .unwrap();
    match r.output {
        FnOutput::Particles { selected, stats } => {
            assert_eq!(selected, 100);
            assert_eq!(stats[0], 100.0);
            assert!((stats[1] - 100.0 * 50.0).abs() < 1.0); // E = 0.5*1*100
        }
        other => panic!("unexpected {other:?}"),
    }
}
