//! Property tests pinning the ordered-container migration (ISSUE 9,
//! satellite a): every sim-visible map/set that used to be a
//! `HashMap`/`HashSet` is now a BTree container, so iteration order —
//! and everything derived from it — is a function of the *keys*, never
//! of insertion order or of `RandomState` hash seeding. These
//! properties would have been flaky (or silently seed-dependent) on
//! the hashed containers; on the ordered ones they must hold for every
//! sampled input:
//!
//! 1. **HA repair ledger is key-ordered** — `HaSubsystem::repairing()`
//!    returns device IDs sorted ascending, and a subsystem fed the
//!    same event list in reversed order still engages every
//!    hard-failed device (`mero::ha::in_repair` is a `BTreeMap`).
//! 2. **DTM validation is replay-stable** — the same transaction
//!    script against two fresh managers yields bit-identical results:
//!    same commit stamps, same read results, and byte-identical
//!    conflict messages (the read set is a `BTreeSet`, so the
//!    validation scan order — and hence *which* conflicting key is
//!    reported — is pinned).
//! 3. **Redo-log recovery equals the live store** — `recover()`'s
//!    sorted replay agrees with `get()` for every committed key, no
//!    matter the order writes were issued in.
//! 4. **Page-cache replay is bit-exact** — a generated op sequence
//!    replayed on a twin cache produces identical `CacheOutcome`s and
//!    identical dirty/resident/sync footprints (the page table is a
//!    `BTreeMap`, so eviction scans are ordered).

use sage::cluster::failure::{FailureEvent, FailureKind};
use sage::mero::dtm::DtmManager;
use sage::mero::ha::HaSubsystem;
use sage::proptest::prop_check;
use sage::sim::cache::PageCache;
use sage::sim::rng::SimRng;

/// One encoded event: the selector picks device and hard/transient,
/// the `u64` is virtual time in milliseconds (integers shrink well).
type Code = (usize, u64);

fn decode_ha(codes: &[Code]) -> Vec<FailureEvent> {
    codes
        .iter()
        .map(|&(sel, ms)| FailureEvent {
            at: ms as f64 / 1000.0,
            kind: if sel % 2 == 0 {
                FailureKind::Device((sel / 2) % 16)
            } else {
                FailureKind::Transient((sel / 2) % 16)
            },
        })
        .collect()
}

fn gen_codes(rng: &mut SimRng, n: usize, sel_bound: u64, v_bound: u64) -> Vec<Code> {
    (0..n)
        .map(|_| (rng.gen_range(sel_bound) as usize, rng.gen_range(v_bound)))
        .collect()
}

/// Feed events into a fresh subsystem and return its repair ledger.
fn ledger(events: &[FailureEvent]) -> Vec<usize> {
    let mut ha = HaSubsystem::new();
    for &ev in events {
        let _ = ha.observe(ev, |d| Some(d / 4));
    }
    ha.repairing()
}

#[test]
fn prop_ha_repairing_is_sorted_and_insertion_order_free() {
    prop_check(
        "ha_repairing_sorted",
        96,
        |rng| gen_codes(rng, 24, 64, 3_600_000),
        |codes| {
            let events = decode_ha(codes);
            let base = ledger(&events);
            // sorted ascending, no duplicates
            if !base.windows(2).all(|w| w[0] < w[1]) {
                return false;
            }
            // reversed arrival order: hard failures always engage on
            // first sight, so every hard-failed device must be in both
            // ledgers (transient *escalation* is window-dependent and
            // legitimately order-sensitive, so only hard ones compare).
            let mut rev = events.clone();
            rev.reverse();
            let rev_ledger = ledger(&rev);
            let mut hard: Vec<usize> = events
                .iter()
                .filter_map(|e| match e.kind {
                    FailureKind::Device(d) => Some(d),
                    FailureKind::Transient(_) => None,
                })
                .collect();
            hard.sort_unstable();
            hard.dedup();
            hard.iter()
                .all(|d| base.contains(d) && rev_ledger.contains(d))
                && rev_ledger.windows(2).all(|w| w[0] < w[1])
        },
    );
}

/// Run a two-transaction interleaving script and summarize every
/// observable: commit stamps (as bits), read results, and the exact
/// error strings of any abort. The selector encodes op kind and key;
/// the value field becomes the written byte.
fn run_tx_script(codes: &[Code]) -> (Vec<u64>, Vec<Vec<u8>>, Vec<String>, Vec<Vec<u8>>) {
    let mut dtm = DtmManager::new();
    let ta = dtm.begin();
    let tb = dtm.begin();
    let mut stamps = Vec::new();
    let mut reads = Vec::new();
    let mut errs = Vec::new();
    let mut now = 0.0;
    for (i, &(sel, val)) in codes.iter().enumerate() {
        let tx = if i % 2 == 0 { ta } else { tb };
        let key = vec![b'k', (sel / 4 % 6) as u8];
        now += 0.25;
        match sel % 4 {
            0 => match dtm.read(tx, &key) {
                Ok(v) => reads.push(v.unwrap_or_default()),
                Err(e) => errs.push(e.to_string()),
            },
            1 | 2 => {
                if let Err(e) = dtm.write(tx, key, vec![val as u8]) {
                    errs.push(e.to_string());
                }
            }
            _ => match dtm.commit(tx, now) {
                Ok(t) => stamps.push(t.to_bits()),
                Err(e) => errs.push(e.to_string()),
            },
        }
    }
    // final state via the sorted redo-log replay
    let state: Vec<Vec<u8>> = dtm.recover().into_values().collect();
    (stamps, reads, errs, state)
}

#[test]
fn prop_dtm_script_replay_is_bit_identical() {
    prop_check(
        "dtm_replay_stable",
        96,
        |rng| gen_codes(rng, 20, 1 << 16, 256),
        |codes| run_tx_script(codes) == run_tx_script(codes),
    );
}

#[test]
fn prop_dtm_recover_matches_store_any_write_order() {
    prop_check(
        "dtm_recover_sorted",
        64,
        |rng| gen_codes(rng, 12, 6, 256),
        |codes| {
            let mut dtm = DtmManager::new();
            let tx = dtm.begin();
            for &(keysel, val) in codes {
                if dtm.write(tx, vec![b'k', keysel as u8], vec![val as u8]).is_err() {
                    return false;
                }
            }
            if dtm.commit(tx, 1.0).is_err() {
                return false;
            }
            let rec = dtm.recover();
            // recovery replay equals the live store, key by key
            rec.iter().all(|(k, v)| dtm.get(k) == Some(v))
        },
    );
}

#[test]
fn prop_cache_replay_is_bit_exact() {
    const PAGE: u64 = 4096;
    prop_check(
        "cache_replay_exact",
        96,
        |rng| gen_codes(rng, 48, 128, 4),
        |codes| {
            let mut a = PageCache::new(16 * PAGE, PAGE);
            let mut b = PageCache::new(16 * PAGE, PAGE);
            for &(sel, len) in codes {
                let off = (sel as u64 / 2) * PAGE;
                let bytes = (len + 1) * PAGE;
                let (oa, ob) = if sel % 2 == 0 {
                    (a.write(off, bytes), b.write(off, bytes))
                } else {
                    (a.read(off, bytes), b.read(off, bytes))
                };
                if oa != ob {
                    return false;
                }
            }
            a.dirty() == b.dirty()
                && a.resident() == b.resident()
                && a.sync() == b.sync()
        },
    );
}
