//! Allocation-budget regression pin (§Perf, ISSUE 8): this binary
//! installs [`sage::util::alloc::CountingAlloc`] as its global
//! allocator and runs ONE quick-profile soak cycle, asserting the
//! heap-allocation count stays under a fixed budget.
//!
//! The budget is deliberately generous (~10× the expected count for
//! the dense sim-core): it is not a micro-benchmark, it is a tripwire
//! for *catastrophic* allocation regressions — a per-block or
//! per-byte allocation slipping back into the object/scheduler hot
//! paths multiplies the count by orders of magnitude and trips this
//! long before it shows up as wall-clock noise in CI.
//!
//! Kept to a single `#[test]` on purpose: the counters are
//! process-global, so a second concurrent test in this binary would
//! inflate the measured window.

use sage::tools::soak::{run, SoakConfig};
use sage::util::alloc::CountingAlloc;

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

/// Upper bound on heap allocations for one `SoakConfig::quick` cycle.
const QUICK_SOAK_ALLOC_BUDGET: u64 = 8_000_000;

#[test]
fn quick_soak_cycle_stays_under_allocation_budget() {
    let (a0, _) = sage::util::alloc::counts();
    let report = run(&SoakConfig::quick(42)).expect("quick soak");
    let (a1, _) = sage::util::alloc::counts();
    let allocs = a1 - a0;

    // the allocator is installed here, so the run must have observed
    // a real, non-trivial count — and the soak's own diag snapshot
    // must agree with ours (same counters, same window)
    assert!(allocs > 1_000, "counting allocator is live ({allocs} allocs)");
    assert!(report.diag.allocs > 1_000);
    assert!(report.diag.allocs <= allocs);
    assert!(report.diag.alloc_bytes > 0);

    assert!(
        allocs <= QUICK_SOAK_ALLOC_BUDGET,
        "quick soak cycle allocated {allocs} times \
         (budget {QUICK_SOAK_ALLOC_BUDGET}) — a per-block or per-unit \
         allocation has crept back into a sim-core hot path"
    );

    // the run itself must still be a real soak (not vacuously cheap)
    assert!(report.events_consumed > 0);
    assert!(report.writes > 0);
}
