//! Differential test plane for the work-conserving QoS overhaul
//! (ISSUE 10): the borrow/reclaim scheduler against the preserved
//! static-throttle oracle (`sim::qos_static_oracle`), plus the
//! QoS→placement feedback loop at session level.
//!
//! 1. **Never slower than static** — for EVERY class, on every
//!    sampled testkit geometry, a work-conserving replay completes
//!    each ticket no later than the static oracle's replay of the
//!    identical submission stream, and every per-class frontier is no
//!    later. Runs where no borrow occurs use unchanged arithmetic, so
//!    plain `f64` comparison is exact; borrowed runs win by the
//!    macroscopic `1/share − 1` stretch they skip.
//! 2. **Static engine preserved verbatim** — the live scheduler with
//!    `work_conserving == false` reproduces the frozen oracle
//!    bit-for-bit (`to_bits`), tenants active or not.
//! 3. **Zero background is bit-identical** — a foreground-only stream
//!    under the conserving config lands on exactly the pre-change
//!    bits (the borrow plane never touches the foreground path), at
//!    scheduler and at session level.
//! 4. **Repair-only shard runs at full device rate** — idle
//!    foreground means the cap is pure waste; conserving completions
//!    equal the raw `n × service_time` schedule bit-for-bit.
//! 5. **Reclaim bound** — any capped run submitted behind a committed
//!    foreground frontier pays the full static stretch: bit-identical
//!    to the oracle, and `observed_share` stays within the cap.
//! 6. **N-tenant determinism under borrowing** — repeated contended
//!    multi-tenant conserving replays are bit-identical, and still
//!    never slower than the static oracle.
//! 7. **Placement feedback** — back-to-back sessions leave an empty
//!    congestion view and bit-identical placements; an overlapped
//!    session steers new-write units and rebuild targets away from
//!    the deepest-backlog device; bytes and crc32 are engine-
//!    independent throughout.

use sage::bench::testkit::{self, placements, span, Geometry, BS, UNIT};
use sage::mero::ObjectId;
use sage::proptest::prop_check;
use sage::sim::device::{Access, Device, DeviceKind, DeviceProfile, IoOp};
use sage::sim::qos_static_oracle::StaticQosScheduler;
use sage::sim::sched::{
    IoScheduler, QosConfig, TenantId, TenantShares, TrafficClass, N_CLASSES,
};

/// This suite's sampling family (see `bench::testkit`).
const GEO: Geometry = Geometry::CONSERVE;

const CLASSES: [TrafficClass; 3] =
    [TrafficClass::Foreground, TrafficClass::Repair, TrafficClass::Migration];

/// One scheduler-level submission: `(device, at, size, class, tenant)`.
type Op = (usize, f64, u64, TrafficClass, TenantId);

/// The replay fleet: mixed service times so borrowing, contention and
/// frontier carry-over all show up.
fn fleet() -> Vec<Device> {
    vec![
        Device::new(DeviceProfile::ssd(1 << 40)),
        Device::new(DeviceProfile::ssd(1 << 40)),
        Device::new(DeviceProfile::hdd(1 << 42)),
        Device::new(DeviceProfile::smr(1 << 42)),
    ]
}

/// Derive a deterministic mixed-class stream from a sampled extent
/// list: device, class and tenant are pure functions of the extent
/// coordinates, submit times are a strictly increasing ladder.
fn stream(extents: &[(u64, u64)], tenants: usize) -> Vec<Op> {
    extents
        .iter()
        .enumerate()
        .map(|(j, &(i, l))| {
            (
                (i % 4) as usize,
                j as f64 * 2.0e-5,
                (1 + l % 8) * BS,
                CLASSES[((i + l) % 3) as usize],
                j % tenants.max(1),
            )
        })
        .collect()
}

/// Fingerprint of one replay: per-ticket completions plus every
/// shard's per-class frontiers, in device order.
struct Replay {
    completions: Vec<f64>,
    class_frontiers: Vec<[f64; N_CLASSES]>,
    wait_all: f64,
}

impl Replay {
    fn bits(&self) -> Vec<u64> {
        let mut bits: Vec<u64> =
            self.completions.iter().map(|t| t.to_bits()).collect();
        for cf in &self.class_frontiers {
            bits.extend(cf.iter().map(|f| f.to_bits()));
        }
        bits.push(self.wait_all.to_bits());
        bits
    }
}

/// Replay `waves` (one `begin_epoch` + submit batch + drain each)
/// through the LIVE scheduler under `qos`.
fn live(qos: QosConfig, shares: Option<&TenantShares>, waves: &[Vec<Op>]) -> Replay {
    let mut devs = fleet();
    let mut s = IoScheduler::with_qos(qos);
    if let Some(t) = shares {
        s.set_tenants(t.clone());
    }
    let mut completions = Vec::new();
    for (w, ops) in waves.iter().enumerate() {
        let t0 = w as f64 * 0.01;
        s.begin_epoch(t0);
        let tickets: Vec<_> = ops
            .iter()
            .map(|&(dev, at, size, class, tenant)| {
                s.set_class(class);
                s.set_tenant(tenant);
                s.submit(dev, t0 + at, size, IoOp::Write, Access::Seq)
            })
            .collect();
        s.drain(&mut devs);
        completions.extend(tickets.into_iter().map(|t| s.completion(t)));
    }
    let rows = s.qos_report_all();
    let class_frontiers = (0..devs.len())
        .map(|d| {
            rows.iter()
                .find(|r| r.device == d)
                .map_or([0.0; N_CLASSES], |r| r.class_frontier)
        })
        .collect();
    Replay { completions, class_frontiers, wait_all: s.wait_all() }
}

/// The same replay through the preserved static-throttle oracle.
fn oracle(qos: QosConfig, shares: Option<&TenantShares>, waves: &[Vec<Op>]) -> Replay {
    let mut devs = fleet();
    let mut s = StaticQosScheduler::with_qos(qos);
    if let Some(t) = shares {
        s.set_tenants(t.clone());
    }
    let mut completions = Vec::new();
    for (w, ops) in waves.iter().enumerate() {
        let t0 = w as f64 * 0.01;
        s.begin_epoch(t0);
        let tickets: Vec<_> = ops
            .iter()
            .map(|&(dev, at, size, class, tenant)| {
                s.set_class(class);
                s.set_tenant(tenant);
                s.submit(dev, t0 + at, size, IoOp::Write, Access::Seq)
            })
            .collect();
        s.drain(&mut devs);
        completions.extend(tickets.into_iter().map(|t| s.completion(t)));
    }
    let class_frontiers = (0..devs.len())
        .map(|d| {
            let mut cf = [0.0; N_CLASSES];
            for c in CLASSES {
                cf[c.index()] = s.class_frontier(d, c);
            }
            cf
        })
        .collect();
    Replay { completions, class_frontiers, wait_all: s.wait_all() }
}

/// `a` never later than `b`, ticket by ticket and frontier by
/// frontier. Exact `<=` — see the module docs for why no tolerance is
/// needed.
fn never_later(a: &Replay, b: &Replay) -> bool {
    a.completions.iter().zip(&b.completions).all(|(x, y)| x <= y)
        && a.class_frontiers
            .iter()
            .zip(&b.class_frontiers)
            .all(|(x, y)| x.iter().zip(y.iter()).all(|(f, g)| f <= g))
        && a.wait_all <= b.wait_all
}

#[test]
fn prop_conserving_never_later_than_static_for_every_class() {
    // the ROADMAP-stated oracle, on EVERY sampled testkit geometry
    for (gi, geo) in [
        Geometry::SCHED,
        Geometry::QOS,
        Geometry::REPAIR,
        Geometry::TENANT,
        Geometry::CONSERVE,
    ]
    .into_iter()
    .enumerate()
    {
        prop_check(
            &format!("conserve-never-slower-geo{gi}"),
            6,
            move |r| (geo.gen_extents(r), geo.gen_extents(r)),
            |case: &(Vec<(u64, u64)>, Vec<(u64, u64)>)| {
                let waves = [stream(&case.0, 1), stream(&case.1, 1)];
                let qos = QosConfig::conserving();
                let cons = live(qos, None, &waves);
                let stat = oracle(qos, None, &waves);
                never_later(&cons, &stat)
            },
        );
    }
}

#[test]
fn prop_static_engine_is_bit_identical_to_the_preserved_oracle() {
    // `work_conserving == false` IS the oracle, bit-for-bit — with
    // the per-class lanes and with the tenant plane active
    prop_check(
        "conserve-static-pin",
        8,
        |r| (GEO.gen_extents(r), GEO.gen_extents(r)),
        |case: &(Vec<(u64, u64)>, Vec<(u64, u64)>)| {
            let qos = QosConfig::default();
            assert!(!qos.work_conserving, "default stays static");
            let waves = [stream(&case.0, 1), stream(&case.1, 1)];
            if live(qos, None, &waves).bits() != oracle(qos, None, &waves).bits() {
                return false;
            }
            let mut shares = TenantShares::single();
            shares.register(3.0);
            let waves_t = [stream(&case.0, 2), stream(&case.1, 2)];
            live(qos, Some(&shares), &waves_t).bits()
                == oracle(qos, Some(&shares), &waves_t).bits()
        },
    );
}

#[test]
fn prop_zero_background_conserving_is_bit_identical_to_static() {
    // foreground-only streams: the borrow plane must not move a bit
    prop_check(
        "conserve-zero-background",
        8,
        |r| GEO.gen_extents(r),
        |extents: &Vec<(u64, u64)>| {
            let fg_only: Vec<Op> = stream(extents, 1)
                .into_iter()
                .map(|(d, at, sz, _, t)| (d, at, sz, TrafficClass::Foreground, t))
                .collect();
            let waves = [fg_only];
            let cons = live(QosConfig::conserving(), None, &waves).bits();
            let stat = live(QosConfig::default(), None, &waves).bits();
            let frozen = oracle(QosConfig::default(), None, &waves).bits();
            cons == stat && cons == frozen
        },
    );
}

#[test]
fn repair_only_shard_runs_at_the_full_device_rate() {
    // idle foreground: every completion is exactly (i+1) × svc — the
    // 1/share stretch is gone, bit-for-bit
    let mut devs = vec![Device::new(DeviceProfile::ssd(1 << 40))];
    let svc = devs[0].profile.service_time(4 * BS, IoOp::Write, Access::Seq);
    let mut s = IoScheduler::with_qos(QosConfig::conserving());
    s.set_class(TrafficClass::Repair);
    let tickets: Vec<_> = (0..6)
        .map(|_| s.submit(0, 0.0, 4 * BS, IoOp::Write, Access::Seq))
        .collect();
    s.drain(&mut devs);
    for (i, t) in tickets.iter().enumerate() {
        assert_eq!(
            s.completion(*t).to_bits(),
            ((i + 1) as f64 * svc).to_bits(),
            "ticket {i} must land at the raw device rate"
        );
    }
    // the static oracle stretches the same stream by 1/share
    let mut devs_o = vec![Device::new(DeviceProfile::ssd(1 << 40))];
    let mut o = StaticQosScheduler::with_qos(QosConfig::conserving());
    o.set_class(TrafficClass::Repair);
    let to: Vec<_> = (0..6)
        .map(|_| o.submit(0, 0.0, 4 * BS, IoOp::Write, Access::Seq))
        .collect();
    o.drain(&mut devs_o);
    let share = QosConfig::conserving().share(TrafficClass::Repair);
    for (i, (t, tt)) in tickets.iter().zip(&to).enumerate() {
        assert!(s.completion(*t) < o.completion(*tt));
        assert_eq!(
            o.completion(*tt).to_bits(),
            ((i + 1) as f64 * (svc / share)).to_bits(),
            "oracle ticket {i} must pay the exact 1/share stretch"
        );
    }
    // and the lent-headroom ledger accounts for the skipped stretch
    let rows = s.qos_report_all();
    let lent = rows[0].lent_headroom(TrafficClass::Repair);
    assert_eq!(lent.to_bits(), (6.0 * svc / share - 6.0 * svc).to_bits());
}

#[test]
fn prop_reclaim_bound_holds_the_instant_foreground_arrives() {
    // every capped run submitted behind a committed foreground
    // frontier pays the full static stretch: the whole schedule is
    // bit-identical to the oracle, and the cap bound survives
    prop_check(
        "conserve-reclaim-bound",
        8,
        |r| GEO.gen_extents(r),
        |extents: &Vec<(u64, u64)>| {
            // a foreground run leads on every device, all at t = 0, so
            // each shard's fg frontier is committed before any capped
            // run (also submitted at 0) drains behind it
            let mut ops: Vec<Op> = (0..4)
                .map(|d| (d, 0.0, 8 * BS, TrafficClass::Foreground, 0))
                .collect();
            ops.extend(stream(extents, 1).into_iter().map(
                |(d, _, sz, class, t)| {
                    let class = if class == TrafficClass::Foreground {
                        TrafficClass::Repair
                    } else {
                        class
                    };
                    (d, 0.0, sz, class, t)
                },
            ));
            let qos = QosConfig::conserving();
            let waves = [ops];
            let cons = live(qos, None, &waves);
            let stat = oracle(qos, None, &waves);
            if cons.bits() != stat.bits() {
                return false;
            }
            // observed shares stay within the caps even with the
            // borrow plane armed
            let mut devs = fleet();
            let mut s = IoScheduler::with_qos(qos);
            s.begin_epoch(0.0);
            for &(d, at, sz, class, tenant) in &waves[0] {
                s.set_class(class);
                s.set_tenant(tenant);
                s.submit(d, at, sz, IoOp::Write, Access::Seq);
            }
            s.drain(&mut devs);
            s.qos_report().iter().all(|row| {
                row.observed_share(TrafficClass::Repair)
                    <= qos.share(TrafficClass::Repair) + 1e-9
                    && row.observed_share(TrafficClass::Migration)
                        <= qos.share(TrafficClass::Migration) + 1e-9
            })
        },
    );
}

#[test]
fn prop_n_tenant_borrowing_is_deterministic_and_never_slower() {
    prop_check(
        "conserve-tenant-determinism",
        6,
        |r| (GEO.gen_extents(r), (1 + r.gen_range(8), 1 + r.gen_range(8))),
        |case: &(Vec<(u64, u64)>, (u64, u64))| {
            let (extents, (wa, wb)) = case;
            let mut shares = TenantShares::single();
            shares.set_weight(0, *wa as f64);
            shares.register(*wb as f64);
            shares.register(2.0);
            let waves = [stream(extents, 3)];
            let qos = QosConfig::conserving();
            let a = live(qos, Some(&shares), &waves);
            let b = live(qos, Some(&shares), &waves);
            a.bits() == b.bits()
                && never_later(&a, &oracle(qos, Some(&shares), &waves))
        },
    );
}

// ----------------------------------------------------- session level

fn layout() -> sage::mero::Layout {
    testkit::raid(4, 1)
}

/// Write `extents` through one session; returns the object plus every
/// schedule-visible bit.
fn write_session(
    c: &mut sage::clovis::Client,
    extents: &[(u64, u64)],
) -> (ObjectId, Vec<u64>) {
    let obj = c.create_object_with(BS, layout()).unwrap();
    let datas: Vec<Vec<u8>> =
        extents.iter().map(|(i, l)| GEO.bytes_for(*i, *l)).collect();
    let refs: Vec<(u64, &[u8])> = extents
        .iter()
        .zip(datas.iter())
        .map(|((i, _), d)| (i * BS, d.as_slice()))
        .collect();
    let mut s = c.session();
    s.write(&obj, &refs);
    let rep = s.run().unwrap();
    let mut bits: Vec<u64> = rep.completed.iter().map(|t| t.to_bits()).collect();
    bits.push(rep.completed_at.to_bits());
    (obj, bits)
}

#[test]
fn prop_back_to_back_sessions_keep_placement_bit_identical() {
    // the no-feedback baseline: sequential sessions drain past the
    // clock, the view built at adoption is empty, and the conserving
    // engine's placement and bytes match the static engine exactly
    prop_check(
        "conserve-placement-baseline",
        8,
        |r| (GEO.gen_extents(r), GEO.gen_extents(r)),
        |case: &(Vec<(u64, u64)>, Vec<(u64, u64)>)| {
            if span(&case.0) == 0 || span(&case.1) == 0 {
                return true;
            }
            let run = |qos: QosConfig| {
                let mut c = testkit::sage_client();
                c.store.cluster.qos = qos;
                let (o1, bits1) = write_session(&mut c, &case.0);
                // the view's lifetime is exactly one session
                assert!(c.store.pools.congestion().is_empty());
                let (o2, bits2) = write_session(&mut c, &case.1);
                let p = (placements(&c, o1), placements(&c, o2));
                let crc = (
                    crc32fast::hash(&c.read_object(&o1, 0, span(&case.0)).unwrap()),
                    crc32fast::hash(&c.read_object(&o2, 0, span(&case.1)).unwrap()),
                );
                (p, crc, bits1, bits2)
            };
            run(QosConfig::conserving()) == run(QosConfig::default())
        },
    );
}

/// Park committed foreground backlog on one SSD shard by driving the
/// cluster scheduler directly, WITHOUT advancing the client clock —
/// the next session then adopts with that shard's frontier ahead of
/// `now` and a non-empty congestion view.
fn backlog_on(c: &mut sage::clovis::Client, dev: usize) {
    let now = c.now;
    for _ in 0..64 {
        c.sched.submit(dev, now, 1 << 22, IoOp::Write, Access::Seq);
    }
    c.sched.drain(&mut c.store.cluster.devices);
    assert!(
        c.store.cluster.devices[dev].busy_until > now,
        "the shard must carry committed backlog"
    );
}

#[test]
fn overlapped_session_steers_new_writes_off_the_backlogged_shard() {
    let extents: Vec<(u64, u64)> = (0..8).map(|i| (i * 8, 8)).collect();
    let units_on = |c: &sage::clovis::Client, obj: ObjectId, dev: usize| {
        placements(c, obj).iter().filter(|(_, _, d)| *d == dev).count()
    };
    // baseline: no backlog anywhere; self-calibrate the probe to the
    // SSD that receives the most units, so the steering comparison
    // can't be defeated by tie-break adjacency
    let mut base = testkit::sage_client();
    base.store.cluster.qos = QosConfig::conserving();
    let ssds = base.store.pools.devices(DeviceKind::Ssd).to_vec();
    let (obj_b, _) = write_session(&mut base, &extents);
    let target = ssds
        .iter()
        .copied()
        .max_by_key(|&d| units_on(&base, obj_b, d))
        .unwrap();
    let baseline_units = units_on(&base, obj_b, target);
    assert!(baseline_units > 0, "the probe device must matter at baseline");
    // identical client, but the target shard is backlogged when the
    // session adopts — the view steers its units elsewhere
    let mut c = testkit::sage_client();
    c.store.cluster.qos = QosConfig::conserving();
    backlog_on(&mut c, target);
    let (obj, _) = write_session(&mut c, &extents);
    let steered_units = units_on(&c, obj, target);
    assert!(
        steered_units < baseline_units,
        "congested shard must receive strictly fewer units \
         ({steered_units} vs {baseline_units})"
    );
    // steering never touches bytes
    for (i, l) in &extents {
        let got = c.read_object(&obj, i * BS, l * BS).unwrap();
        assert_eq!(got, GEO.bytes_for(*i, *l));
    }
}

#[test]
fn rebuild_targets_avoid_the_deepest_backlog_device() {
    let build = || {
        let mut c = testkit::sage_client();
        c.store.cluster.qos = QosConfig::conserving();
        let mut objs = Vec::new();
        for i in 0..4u64 {
            let o = c.create_object_with(BS, layout()).unwrap();
            let data = GEO.bytes_for(i, 2 * 4 * UNIT / BS);
            c.write_object(&o, 0, &data).unwrap();
            objs.push((o, data));
        }
        let dev =
            c.store.object(objs[0].0).unwrap().placement(0, 0).unwrap().device;
        c.store.cluster.fail_device(dev);
        (c, objs, dev)
    };
    let units_per_dev = |c: &sage::clovis::Client,
                         objs: &[(ObjectId, Vec<u8>)]| {
        let mut counts = std::collections::BTreeMap::new();
        for (o, _) in objs {
            for (_, _, d) in placements(c, *o) {
                *counts.entry(d).or_insert(0usize) += 1;
            }
        }
        counts
    };
    // baseline rebuild with no backlog; self-calibrate the probe to
    // the survivor that gains the most re-homed units
    let (mut base, objs_b, failed_b) = build();
    let before_b = units_per_dev(&base, &objs_b);
    let ids_b: Vec<ObjectId> = objs_b.iter().map(|(o, _)| *o).collect();
    base.repair_with(&ids_b, failed_b).unwrap();
    let after_b = units_per_dev(&base, &objs_b);
    let rehomed =
        |before: &std::collections::BTreeMap<usize, usize>,
         after: &std::collections::BTreeMap<usize, usize>,
         dev: usize| {
            after.get(&dev).copied().unwrap_or(0)
                - before.get(&dev).copied().unwrap_or(0)
        };
    let probe = *after_b
        .keys()
        .filter(|&&d| d != failed_b)
        .max_by_key(|&&d| rehomed(&before_b, &after_b, d))
        .unwrap();
    let baseline_units = rehomed(&before_b, &after_b, probe);
    assert!(baseline_units > 0, "the rebuild re-homed units somewhere");
    // same cluster, but the probe shard is the deepest backlog when
    // the repair session adopts — re-homed units avoid it
    let (mut c, objs, failed) = build();
    assert_eq!(failed, failed_b, "identical builds fail the same device");
    let before = units_per_dev(&c, &objs);
    backlog_on(&mut c, probe);
    let ids: Vec<ObjectId> = objs.iter().map(|(o, _)| *o).collect();
    c.repair_with(&ids, failed).unwrap();
    let after = units_per_dev(&c, &objs);
    let steered_units = rehomed(&before, &after, probe);
    assert!(
        steered_units < baseline_units,
        "rebuild must avoid the deepest-backlog device \
         ({steered_units} vs {baseline_units})"
    );
    // the rebuilt bytes are intact either way
    for (o, want) in &objs {
        let got = c.read_object(o, 0, want.len() as u64).unwrap();
        assert_eq!(&got, want);
    }
}

#[test]
fn prop_conserving_mixed_session_preserves_bytes_placement_and_crc() {
    // the client-level differential: repair staged next to foreground
    // writes, conserving vs static — WHAT is stored never moves, WHEN
    // only ever improves
    prop_check(
        "conserve-bytes-crc",
        6,
        |r| GEO.gen_extents(r),
        |extents: &Vec<(u64, u64)>| {
            let run = |qos: QosConfig| {
                let mut c = testkit::sage_client();
                c.store.cluster.qos = qos;
                let mut objs = Vec::new();
                for i in 0..3u64 {
                    let o = c.create_object_with(BS, layout()).unwrap();
                    let data = GEO.bytes_for(i, 2 * 4 * UNIT / BS);
                    c.write_object(&o, 0, &data).unwrap();
                    objs.push((o, data));
                }
                let dev = c
                    .store
                    .object(objs[0].0)
                    .unwrap()
                    .placement(0, 0)
                    .unwrap()
                    .device;
                c.store.cluster.fail_device(dev);
                let fg = c.create_object_with(BS, layout()).unwrap();
                let datas: Vec<Vec<u8>> = extents
                    .iter()
                    .map(|(i, l)| GEO.bytes_for(100 + i, *l))
                    .collect();
                let refs: Vec<(u64, &[u8])> = extents
                    .iter()
                    .zip(datas.iter())
                    .map(|((i, _), d)| (i * BS, d.as_slice()))
                    .collect();
                let ids: Vec<ObjectId> = objs.iter().map(|(o, _)| *o).collect();
                let mut s = c.session();
                let r = s.repair(&ids, dev);
                let w = s.write(&fg, &refs);
                let rep = s.run().unwrap();
                let mut crcs = Vec::new();
                let mut placement = Vec::new();
                for (o, data) in &objs {
                    crcs.push(crc32fast::hash(
                        &c.read_object(o, 0, data.len() as u64).unwrap(),
                    ));
                    placement.push(placements(&c, *o));
                }
                if span(extents) > 0 {
                    crcs.push(crc32fast::hash(
                        &c.read_object(&fg, 0, span(extents)).unwrap(),
                    ));
                }
                placement.push(placements(&c, fg));
                (crcs, placement, rep.completed[r.index()], rep.completed[w.index()])
            };
            let (crc_c, place_c, repair_c, fg_c) = run(QosConfig::conserving());
            let (crc_s, place_s, repair_s, fg_s) = run(QosConfig::default());
            crc_c == crc_s
                && place_c == place_s
                && repair_c <= repair_s
                && fg_c <= fg_s
        },
    );
}
