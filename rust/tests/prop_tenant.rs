//! Property tests for the multi-tenant concurrency plane (ISSUE 7):
//! ONE cluster-wide scheduler shared by every session, N contending
//! tenants on weighted fair shares.
//!
//! 1. **Private-scheduler equivalence** — a single tenant running
//!    sessions on the shared scheduler reproduces the pre-PR
//!    private-scheduler schedules bit-exactly: same bytes, same
//!    placements, completion times and frontiers equal via
//!    `f64::to_bits`. The oracle resets `Client::sched` to a fresh
//!    instance before every session — exactly the one-group-one-
//!    scheduler world this PR replaced.
//! 2. **N-tenant determinism** — repeated contended multi-tenant runs
//!    produce bit-identical completions and per-tenant frontier
//!    tables.
//! 3. **Weighted share bound** — on every shard, each tenant's
//!    observed device-time share never exceeds its
//!    `TenantShares::share` weight fraction.
//! 4. **No starvation** — under arbitrarily skewed weights every
//!    tenant's session completes at a finite time and its frontier
//!    advances past the shard base wherever it ran.

use sage::bench::testkit::{self, span, Geometry, BS, UNIT};
use sage::clovis::{Client, OpOutput};
use sage::mero::ObjectId;
use sage::proptest::prop_check;
use sage::sim::rng::SimRng;
use sage::sim::sched::{IoScheduler, TenantId, DEFAULT_TENANT};

/// This suite's sampling family (see `bench::testkit`).
const GEO: Geometry = Geometry::TENANT;

fn gen_extents(r: &mut SimRng) -> Vec<(u64, u64)> {
    GEO.gen_extents(r)
}

/// 2–3 session batches per case, each its own sampled extent list.
fn gen_batches(r: &mut SimRng) -> Vec<Vec<(u64, u64)>> {
    let n = 2 + r.gen_index(2);
    (0..n).map(|_| GEO.gen_extents(r)).collect()
}

/// Run one session per batch (write chained to a read-back) and
/// fingerprint every schedule-visible time as bits. With `reset` the
/// client's shared scheduler is replaced by a fresh instance before
/// each session — the pre-PR private-scheduler oracle.
fn run_sessions(
    reset: bool,
    batches: &[Vec<(u64, u64)>],
) -> (Client, Vec<ObjectId>, Vec<u64>) {
    let mut c = testkit::sage_client();
    let mut objs = Vec::new();
    let mut bits = Vec::new();
    for (si, extents) in batches.iter().enumerate() {
        if reset {
            c.sched = IoScheduler::new();
        }
        let obj = c.create_object_with(BS, testkit::raid(4, 2)).unwrap();
        let datas: Vec<Vec<u8>> = extents
            .iter()
            .map(|(i, l)| GEO.bytes_for(i + 10 * si as u64, *l))
            .collect();
        let refs: Vec<(u64, &[u8])> = extents
            .iter()
            .zip(datas.iter())
            .map(|((i, _), d)| (i * BS, d.as_slice()))
            .collect();
        let total = span(extents);
        let mut s = c.session();
        let w = s.write(&obj, &refs);
        let r = s.read(&obj, &[sage::clovis::Extent::new(0, total)]);
        s.after(r, w).unwrap();
        let rep = s.run().unwrap();
        bits.extend(rep.completed.iter().map(|t| t.to_bits()));
        bits.push(rep.completed_at.to_bits());
        for &(d, f) in &rep.frontiers {
            bits.push(d as u64);
            bits.push(f.to_bits());
        }
        bits.push(c.now.to_bits());
        objs.push(obj);
    }
    (c, objs, bits)
}

#[test]
fn prop_single_tenant_shared_scheduler_matches_private_oracle() {
    // the tentpole pin: hoisting the scheduler to the client must not
    // move a single completion for sequential single-tenant sessions
    prop_check(
        "tenant-private-oracle",
        12,
        gen_batches,
        |batches: &Vec<Vec<(u64, u64)>>| {
            if batches.iter().any(|b| span(b) == 0) {
                return true;
            }
            let (mut shared, objs_s, bits_s) = run_sessions(false, batches);
            let (mut oracle, objs_o, bits_o) = run_sessions(true, batches);
            if bits_s != bits_o {
                return false;
            }
            // same placements and same stored bytes, object by object
            for (a, b) in objs_s.iter().zip(objs_o.iter()) {
                if testkit::placements(&shared, *a)
                    != testkit::placements(&oracle, *b)
                {
                    return false;
                }
            }
            for ((a, b), extents) in
                objs_s.iter().zip(objs_o.iter()).zip(batches.iter())
            {
                let total = span(extents);
                let x = shared.read_object(a, 0, total).unwrap();
                let y = oracle.read_object(b, 0, total).unwrap();
                if x != y {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn shared_scheduler_mixed_repair_session_matches_private_oracle_bit_exactly() {
    // the cap-template workload from prop_qos (repair staged next to a
    // foreground write, default split active), shared vs private
    let run = |reset: bool| {
        let mut c = testkit::sage_client();
        let mut objs = Vec::new();
        for i in 0..3u64 {
            if reset {
                c.sched = IoScheduler::new();
            }
            let o = c.create_object_with(BS, testkit::raid(4, 2)).unwrap();
            let data = GEO.bytes_for(i, 2 * 4 * UNIT / BS);
            let mut s = c.session();
            s.write(&o, &[(0, data.as_slice())]);
            s.run().unwrap();
            objs.push((o, data));
        }
        let dev =
            c.store.object(objs[0].0).unwrap().placement(0, 0).unwrap().device;
        c.store.cluster.fail_device(dev);
        if reset {
            c.sched = IoScheduler::new();
        }
        let ids: Vec<ObjectId> = objs.iter().map(|(o, _)| *o).collect();
        let fg = c.create_object_with(BS, testkit::raid(4, 2)).unwrap();
        let fg_data = GEO.bytes_for(99, 8);
        let mut s = c.session();
        let r = s.repair(&ids, dev);
        let w = s.write(&fg, &[(0, fg_data.as_slice())]);
        let rep = s.run().unwrap();
        let rebuilt = match rep.output(r) {
            OpOutput::Repair { bytes } => *bytes,
            other => panic!("repair output expected, got {other:?}"),
        };
        let mut bits: Vec<u64> =
            rep.completed.iter().map(|t| t.to_bits()).collect();
        bits.push(rep.completed[w.index()].to_bits());
        bits.push(rep.completed_at.to_bits());
        for &(d, f) in &rep.frontiers {
            bits.push(d as u64);
            bits.push(f.to_bits());
        }
        let mut reads = vec![c.read_object(&fg, 0, fg_data.len() as u64).unwrap()];
        for (o, data) in &objs {
            reads.push(c.read_object(o, 0, data.len() as u64).unwrap());
        }
        (rebuilt, bits, reads)
    };
    let (rebuilt_s, bits_s, reads_s) = run(false);
    let (rebuilt_o, bits_o, reads_o) = run(true);
    assert!(rebuilt_s > 0, "the failed device held units");
    assert_eq!(rebuilt_s, rebuilt_o, "identical rebuild work");
    assert_eq!(bits_s, bits_o, "bit-identical mixed-session schedule");
    assert_eq!(reads_s, reads_o, "byte-identical stores");
}

/// One contended multi-tenant round: every tenant writes its own
/// object through a session dispatched at the SAME virtual instant
/// (the clock is rewound between sessions), so the sessions overlap
/// on the shared scheduler's busy shards instead of re-seeding.
struct TenantRun {
    tenant: TenantId,
    obj: ObjectId,
    datas: Vec<Vec<u8>>,
    completed_bits: Vec<u64>,
    completed_at: f64,
    tenants_table: Vec<sage::sim::sched::TenantShardReport>,
}

fn contend(
    c: &mut Client,
    tenants: &[TenantId],
    extents: &[(u64, u64)],
) -> Vec<TenantRun> {
    let t0 = c.now;
    let mut runs = Vec::new();
    for &tid in tenants {
        c.now = t0;
        let obj = c.create_object_with(BS, testkit::raid(4, 1)).unwrap();
        let datas: Vec<Vec<u8>> = extents
            .iter()
            .map(|(i, l)| GEO.bytes_for(i + 1000 * tid as u64, *l))
            .collect();
        let refs: Vec<(u64, &[u8])> = extents
            .iter()
            .zip(datas.iter())
            .map(|((i, _), d)| (i * BS, d.as_slice()))
            .collect();
        let mut s = c.session_as(tid).unwrap();
        s.write(&obj, &refs);
        let rep = s.run().unwrap();
        runs.push(TenantRun {
            tenant: tid,
            obj,
            datas,
            completed_bits: rep.completed.iter().map(|t| t.to_bits()).collect(),
            completed_at: rep.completed_at,
            tenants_table: rep.tenants,
        });
    }
    runs
}

/// Check a tenant's object against its write set (later extents win on
/// overlap; holes are left unchecked).
fn bytes_intact(c: &mut Client, run: &TenantRun, extents: &[(u64, u64)]) -> bool {
    let total = span(extents);
    let mut expect: Vec<Option<u8>> = vec![None; total as usize];
    for ((i, _), d) in extents.iter().zip(run.datas.iter()) {
        let off = (i * BS) as usize;
        for (e, &b) in expect[off..off + d.len()].iter_mut().zip(d.iter()) {
            *e = Some(b);
        }
    }
    let got = c.read_object(&run.obj, 0, total).unwrap();
    got.iter()
        .zip(expect.iter())
        .all(|(g, e)| match e {
            Some(w) => g == w,
            None => true,
        })
}

#[test]
fn prop_n_tenant_schedules_are_bit_deterministic() {
    prop_check(
        "tenant-n-determinism",
        8,
        |r| (gen_extents(r), (1 + r.gen_range(8), 1 + r.gen_range(8))),
        |case: &(Vec<(u64, u64)>, (u64, u64))| {
            let (extents, (wa, wb)) = case;
            let run = || {
                let mut c = testkit::sage_client();
                c.store
                    .cluster
                    .tenants
                    .set_weight(DEFAULT_TENANT, *wa as f64);
                let t2 = c.register_tenant(*wb as f64);
                let runs = contend(&mut c, &[DEFAULT_TENANT, t2], extents);
                let mut bits = Vec::new();
                for run in &runs {
                    bits.extend(run.completed_bits.iter().copied());
                    bits.push(run.completed_at.to_bits());
                    for shard in &run.tenants_table {
                        bits.push(shard.device as u64);
                        bits.push(shard.base.to_bits());
                        for lane in &shard.lanes {
                            bits.push(lane.tenant as u64);
                            bits.push(lane.busy.to_bits());
                            bits.push(lane.frontier.to_bits());
                        }
                    }
                }
                bits
            };
            run() == run()
        },
    );
}

#[test]
fn prop_weighted_share_bound_holds_on_every_shard() {
    prop_check(
        "tenant-share-bound",
        10,
        |r| (gen_extents(r), (1 + r.gen_range(8), 1 + r.gen_range(8))),
        |case: &(Vec<(u64, u64)>, (u64, u64))| {
            let (extents, (wa, wb)) = case;
            let mut c = testkit::sage_client();
            c.store.cluster.tenants.set_weight(DEFAULT_TENANT, *wa as f64);
            let t2 = c.register_tenant(*wb as f64);
            let runs = contend(&mut c, &[DEFAULT_TENANT, t2], extents);
            // on every shard either tenant touched, its observed
            // device-time share stays within its weight fraction
            let caps = [
                (DEFAULT_TENANT, c.store.cluster.tenants.share(DEFAULT_TENANT)),
                (t2, c.store.cluster.tenants.share(t2)),
            ];
            for shard in c.sched.tenant_report_all() {
                for &(t, cap) in &caps {
                    if shard.observed_share(t) > cap + 1e-9 {
                        return false;
                    }
                }
            }
            // the split never touches bytes
            let mut ok = true;
            for run in &runs {
                ok &= bytes_intact(&mut c, run, extents);
            }
            ok
        },
    );
}

#[test]
fn prop_no_tenant_starves_under_skewed_weights() {
    prop_check(
        "tenant-no-starvation",
        10,
        |r| {
            let n = 2 + r.gen_index(2);
            let weights: Vec<u64> =
                (0..n).map(|_| 1 + r.gen_range(16)).collect();
            (GEO.gen_extents(r), weights)
        },
        |case: &(Vec<(u64, u64)>, Vec<u64>)| {
            let (extents, weights) = case;
            if weights.len() < 2 {
                return true; // shrunk below the multi-tenant regime
            }
            let mut c = testkit::sage_client();
            c.store
                .cluster
                .tenants
                .set_weight(DEFAULT_TENANT, weights[0] as f64);
            let mut tenants = vec![DEFAULT_TENANT];
            for &w in &weights[1..] {
                tenants.push(c.register_tenant(w as f64));
            }
            let runs = contend(&mut c, &tenants, extents);
            for run in &runs {
                // finite completion: the weighted lanes never block on
                // another tenant's lane, so no session can hang
                if !run.completed_at.is_finite() || run.completed_at <= 0.0 {
                    return false;
                }
                // and the tenant made real progress wherever it ran
                let advanced = run.tenants_table.iter().any(|shard| {
                    shard.tenant_frontier(run.tenant) > shard.base
                });
                if !advanced {
                    return false;
                }
            }
            let mut ok = true;
            for run in &runs {
                ok &= bytes_intact(&mut c, run, extents);
            }
            ok
        },
    );
}
