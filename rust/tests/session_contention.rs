//! Integration test (ISSUE 7 satellite): a foreground writer session
//! and a repair session CONTENDING on the same shards of the ONE
//! cluster-wide scheduler.
//!
//! Two clients start from identical pre-state (population + one failed
//! device). The *serial* client runs the repair session to completion
//! and only then the foreground write. The *contended* client runs the
//! same repair, then rewinds its clock to the repair's start so the
//! foreground session dispatches INTO the rebuild window — overlapping
//! epochs on busy shards. Pinned:
//!
//! * the interleaving really differs — the contended foreground lands
//!   strictly earlier than the serial one (it rides the ≥ 70 %
//!   foreground share through the rebuild instead of queueing behind
//!   the whole committed backlog), and its frontier table is not the
//!   serial one;
//! * bytes are identical everywhere — contention changes WHEN, never
//!   WHAT;
//! * the QoS split still bounds repair on the shared scheduler: every
//!   shard's observed repair share stays within `repair_share`;
//! * the legacy `repair_with` wrapper rides the same shared scheduler:
//!   bit-identical repair completion to the explicit session.
//!
//! ISSUE 10 closes the latent idle-shard gap: a Repair-class session
//! on an otherwise-idle shard finishes strictly faster under the
//! work-conserving split than under the static cap, while the
//! `observed_share` accounting stays exhaustive on both reports.

use sage::bench::testkit::{self, Geometry, BS, UNIT};
use sage::clovis::{Client, OpOutput};
use sage::mero::ObjectId;
use sage::sim::sched::{QosConfig, TrafficClass};

const GEO: Geometry = Geometry::TENANT;

/// Identical pre-state: 4 populated objects, first unit's device
/// failed. Returns the client, the population, and the failed device.
fn prestate() -> (Client, Vec<(ObjectId, Vec<u8>)>, usize) {
    let mut c = testkit::sage_client();
    let mut objs = Vec::new();
    for i in 0..4u64 {
        let o = c.create_object_with(BS, testkit::raid(4, 2)).unwrap();
        let data = GEO.bytes_for(i, 3 * 4 * UNIT / BS);
        c.write_object(&o, 0, &data).unwrap();
        objs.push((o, data));
    }
    let dev =
        c.store.object(objs[0].0).unwrap().placement(0, 0).unwrap().device;
    c.store.cluster.fail_device(dev);
    (c, objs, dev)
}

struct Outcome {
    c: Client,
    objs: Vec<(ObjectId, Vec<u8>)>,
    fg_obj: ObjectId,
    fg_data: Vec<u8>,
    bytes_rebuilt: u64,
    repair_t: f64,
    /// Foreground completion relative to its own dispatch instant.
    fg_rel: f64,
    /// Foreground completion in absolute virtual time.
    fg_abs: f64,
    fg_frontier_bits: Vec<(usize, u64)>,
    max_repair_share: f64,
}

/// Run repair then a foreground full-stripe write. `contend` rewinds
/// the clock so the write dispatches at the repair's start instead of
/// after its completion.
fn run(contend: bool) -> Outcome {
    let (mut c, objs, dev) = prestate();
    let t0 = c.now;
    let ids: Vec<ObjectId> = objs.iter().map(|(o, _)| *o).collect();
    let mut s = c.session();
    let r = s.repair(&ids, dev);
    let rep = s.run().unwrap();
    let bytes_rebuilt = match rep.output(r) {
        OpOutput::Repair { bytes } => *bytes,
        other => panic!("repair output expected, got {other:?}"),
    };
    let repair_t = rep.completed[r.index()];
    let mut max_repair_share = 0.0f64;
    for shard in &rep.qos {
        max_repair_share =
            max_repair_share.max(shard.observed_share(TrafficClass::Repair));
    }
    if contend {
        c.now = t0; // dispatch the writer INTO the rebuild window
    }
    let t_fg0 = c.now;
    let fg_obj = c.create_object_with(BS, testkit::raid(4, 2)).unwrap();
    let fg_data = GEO.bytes_for(50, 2 * 4 * UNIT / BS);
    let mut s = c.session();
    let w = s.write(&fg_obj, &[(0, fg_data.as_slice())]);
    let rep = s.run().unwrap();
    Outcome {
        fg_rel: rep.completed[w.index()] - t_fg0,
        fg_abs: rep.completed[w.index()],
        fg_frontier_bits: rep
            .frontiers
            .iter()
            .map(|&(d, f)| (d, f.to_bits()))
            .collect(),
        c,
        objs,
        fg_obj,
        fg_data,
        bytes_rebuilt,
        repair_t,
        max_repair_share,
    }
}

#[test]
fn contended_foreground_overlaps_the_rebuild_and_bytes_survive() {
    let mut serial = run(false);
    let mut contended = run(true);

    // identical pre-state produced identical repairs
    assert!(serial.bytes_rebuilt > 0, "the failed device held units");
    assert_eq!(serial.bytes_rebuilt, contended.bytes_rebuilt);
    assert_eq!(serial.repair_t.to_bits(), contended.repair_t.to_bits());

    // the interleaving differs: dispatched into the rebuild window the
    // writer completes later than an uncontended write would relative
    // to its dispatch — but strictly earlier in absolute virtual time
    // than queueing behind the whole rebuild
    assert_ne!(
        serial.fg_frontier_bits, contended.fg_frontier_bits,
        "overlapped epochs must not reproduce the serial frontiers"
    );
    assert!(
        contended.fg_rel >= serial.fg_rel * (1.0 - 1e-9),
        "contention cannot beat an idle pool ({} vs {})",
        contended.fg_rel,
        serial.fg_rel
    );
    assert!(
        contended.fg_abs < serial.fg_abs,
        "the split lets the writer ride through the rebuild window \
         ({} vs {} serialized)",
        contended.fg_abs,
        serial.fg_abs
    );

    // the cap still bounds repair on the shared scheduler
    let cap = QosConfig::default().share(TrafficClass::Repair);
    assert!(serial.max_repair_share > 0.0, "repair really ran");
    assert!(serial.max_repair_share <= cap + 1e-9);
    assert!(contended.max_repair_share <= cap + 1e-9);

    // contention changes WHEN, never WHAT
    for out in [&mut serial, &mut contended] {
        let fg_obj = out.fg_obj;
        let fg_want = out.fg_data.clone();
        let got = out.c.read_object(&fg_obj, 0, fg_want.len() as u64).unwrap();
        assert_eq!(got, fg_want, "foreground bytes intact");
        for (o, want) in out.objs.clone() {
            let got = out.c.read_object(&o, 0, want.len() as u64).unwrap();
            assert_eq!(got, want, "repaired bytes intact");
        }
    }
    let a: Vec<Vec<u8>> = serial
        .objs
        .iter()
        .map(|(o, d)| serial.c.read_object(o, 0, d.len() as u64).unwrap())
        .collect();
    let b: Vec<Vec<u8>> = contended
        .objs
        .iter()
        .map(|(o, d)| contended.c.read_object(o, 0, d.len() as u64).unwrap())
        .collect();
    assert_eq!(a, b, "cross-client byte identity");
}

#[test]
fn idle_shard_repair_borrows_the_foreground_headroom() {
    // Static cap: repair stretches at `1/repair_share` even though no
    // foreground work is committed anywhere on the cluster.
    let (mut c_s, objs_s, dev_s) = prestate();
    let ids_s: Vec<ObjectId> = objs_s.iter().map(|(o, _)| *o).collect();
    let mut s = c_s.session();
    let r = s.repair(&ids_s, dev_s);
    let rep_s = s.run().unwrap();
    let t_static = rep_s.completed[r.index()];
    let bytes_static = match rep_s.output(r) {
        OpOutput::Repair { bytes } => *bytes,
        other => panic!("repair output expected, got {other:?}"),
    };

    // Work-conserving split: identical pre-state, identical session —
    // the capped class borrows the idle foreground headroom.
    let (mut c_w, objs_w, dev_w) = prestate();
    assert_eq!(dev_s, dev_w, "identical pre-state");
    c_w.store.cluster.qos = QosConfig::conserving();
    let ids_w: Vec<ObjectId> = objs_w.iter().map(|(o, _)| *o).collect();
    let mut s = c_w.session();
    let r = s.repair(&ids_w, dev_w);
    let rep_w = s.run().unwrap();
    let t_conserving = rep_w.completed[r.index()];
    let bytes_conserving = match rep_w.output(r) {
        OpOutput::Repair { bytes } => *bytes,
        other => panic!("repair output expected, got {other:?}"),
    };

    assert!(bytes_static > 0, "the failed device held units");
    assert_eq!(bytes_static, bytes_conserving, "same rebuild either way");
    assert!(
        t_conserving < t_static,
        "an idle-foreground shard lets repair run at the device rate \
         ({t_conserving} vs {t_static} under the static cap)"
    );

    // `observed_share` accounting stays exhaustive on BOTH reports:
    // every reported shard drained real work, the per-class busy
    // seconds fit inside the shard's active window, and every share
    // sits in [0, 1].
    let classes = [
        TrafficClass::Foreground,
        TrafficClass::Repair,
        TrafficClass::Migration,
    ];
    for rep in [&rep_s, &rep_w] {
        assert!(!rep.qos.is_empty(), "repair really ran");
        for shard in &rep.qos {
            let window = shard.frontier - shard.base;
            let busy: f64 = shard.class_busy.iter().sum();
            assert!(busy > 0.0, "reported shards really drained work");
            assert!(busy <= window + 1e-9, "busy seconds fit the window");
            for class in classes {
                let share = shard.observed_share(class);
                assert!(
                    (0.0..=1.0 + 1e-9).contains(&share),
                    "share out of range: {share}"
                );
            }
        }
    }

    // Static run: the cap bounds every shard and nothing was lent.
    let cap = QosConfig::default().share(TrafficClass::Repair);
    for shard in &rep_s.qos {
        assert!(shard.observed_share(TrafficClass::Repair) <= cap + 1e-9);
        for class in classes {
            assert_eq!(
                shard.lent_headroom(class),
                0.0,
                "the static split never lends headroom"
            );
        }
    }
    // Conserving run: at least one shard escaped the cap by borrowing,
    // and the report accounts for the headroom it was lent.
    let escaped = rep_w
        .qos
        .iter()
        .any(|s| s.observed_share(TrafficClass::Repair) > cap + 1e-9);
    assert!(escaped, "borrowing shows up in the observed share");
    let lent: f64 = rep_w
        .qos
        .iter()
        .map(|s| s.lent_headroom(TrafficClass::Repair))
        .sum();
    assert!(lent > 0.0, "the lent headroom is accounted, not hidden");

    // Borrowing changes WHEN, never WHAT.
    for (c, objs) in [(&mut c_s, &objs_s), (&mut c_w, &objs_w)] {
        for (o, want) in objs.iter() {
            let got = c.read_object(o, 0, want.len() as u64).unwrap();
            assert_eq!(&got, want, "repaired bytes intact");
        }
    }
}

#[test]
fn legacy_repair_with_rides_the_shared_scheduler_bit_exactly() {
    // the wrapper and an explicit one-op session must be the same
    // schedule on the same shared scheduler
    let (mut c1, _objs1, dev1) = prestate();
    let ids1: Vec<ObjectId> = _objs1.iter().map(|(o, _)| *o).collect();
    let mut s = c1.session();
    let r = s.repair(&ids1, dev1);
    let rep = s.run().unwrap();
    let t_session = rep.completed[r.index()];
    let bytes_session = match rep.output(r) {
        OpOutput::Repair { bytes } => *bytes,
        other => panic!("repair output expected, got {other:?}"),
    };

    let (mut c2, _objs2, dev2) = prestate();
    let ids2: Vec<ObjectId> = _objs2.iter().map(|(o, _)| *o).collect();
    assert_eq!(dev1, dev2, "identical pre-state");
    let (bytes_legacy, t_legacy) = c2.repair_with(&ids2, dev2).unwrap();

    assert_eq!(bytes_session, bytes_legacy);
    assert_eq!(t_session.to_bits(), t_legacy.to_bits());
    assert_eq!(c1.now.to_bits(), c2.now.to_bits());
}
