//! Property tests for the scheduler-driven recovery plane (ISSUE 3):
//!
//! 1. **Repair equivalence** — sharded `sns::repair` rebuilds
//!    byte-identical state to the `sns_serial::repair` serial-fold
//!    oracle (identical placements, identical post-repair reads) and
//!    completes no later, on every sampled geometry.
//! 2. **Degraded-read equivalence under double failure** — on 4+2,
//!    with TWO failed devices, sharded degraded reads return the same
//!    bytes as the serial oracle, or both engines agree the data is
//!    unavailable (XOR parity tolerates one lost data unit per
//!    stripe).
//! 3. **Batched migration** — `Hsm::migrate` over ONE scheduler
//!    preserves bytes exactly and completes no later than the
//!    one-migration-at-a-time serial fold.

use sage::bench::testkit::{self, span, Geometry, BS};
use sage::config::Testbed;
use sage::hsm::{Hsm, Migration, TieringPolicy};
use sage::mero::{sns, sns_serial, Layout, MeroStore, ObjectId};
use sage::proptest::prop_check;
use sage::sim::device::DeviceKind;

/// This suite's historical sampling family (see `bench::testkit`).
const GEO: Geometry = Geometry::REPAIR;

fn layout(k: u32, p: u32) -> Layout {
    testkit::raid(k, p)
}

/// Deterministic payload for extent (idx, len_blocks).
fn bytes_for(idx: u64, len_blocks: u64) -> Vec<u8> {
    GEO.bytes_for(idx, len_blocks)
}

fn gen_extents(r: &mut sage::sim::rng::SimRng) -> Vec<(u64, u64)> {
    GEO.gen_extents(r)
}

/// Two stores with the extents applied through each engine — identical
/// write order, so placements agree; only scheduling differs.
fn paired_stores(
    k: u32,
    p: u32,
    extents: &[(u64, u64)],
) -> (MeroStore, ObjectId, MeroStore, ObjectId) {
    let mut ser = MeroStore::new(Testbed::sage_prototype().build_cluster());
    let mut sh = MeroStore::new(Testbed::sage_prototype().build_cluster());
    let ids = ser.create_object(BS, layout(k, p)).unwrap();
    let idh = sh.create_object(BS, layout(k, p)).unwrap();
    let mut t_ser = 0.0;
    let mut t_sh = 0.0;
    for (idx, lenb) in extents {
        let data = bytes_for(*idx, *lenb);
        if data.is_empty() {
            continue;
        }
        t_ser = sns_serial::write(&mut ser, ids, idx * BS, &data, t_ser, None)
            .unwrap();
        t_sh = sh.write_object(idh, idx * BS, &data, t_sh, None).unwrap();
    }
    (ser, ids, sh, idh)
}

#[test]
fn prop_sharded_repair_matches_serial_oracle() {
    for (k, p) in [(4u32, 2u32), (4, 1), (3, 2)] {
        prop_check(
            &format!("repair-{k}+{p}"),
            12,
            gen_extents,
            |extents: &Vec<(u64, u64)>| {
                let total = span(extents);
                if total == 0 {
                    return true;
                }
                let (mut ser, ids, mut sh, idh) = paired_stores(k, p, extents);
                // fail the device of the same LOGICAL unit in each store
                let unit = 1.min(k - 1);
                let a = ser.object(ids).unwrap().placement(0, unit).copied();
                let b = sh.object(idh).unwrap().placement(0, unit).copied();
                let (da, db) = match (a, b) {
                    (Some(ua), Some(ub)) => (ua.device, ub.device),
                    // stripe 0 untouched by the extents: nothing to fail
                    (None, None) => return true,
                    _ => return false, // placement maps must agree
                };
                if da != db {
                    return false; // identical write order => same homes
                }
                ser.cluster.fail_device(da);
                sh.cluster.fail_device(db);
                let now = 1000.0;
                let (b_ser, t_ser) =
                    sns_serial::repair(&mut ser, &[ids], da, now).unwrap();
                let (b_sh, t_sh) =
                    sns::repair(&mut sh, &[idh], db, now).unwrap();
                if b_ser != b_sh {
                    return false; // same units rebuilt
                }
                if t_sh > t_ser * (1.0 + 1e-9) + 1e-12 {
                    return false; // sharded repair never completes later
                }
                // post-repair state is byte-identical (the failed
                // device is still down, its units re-homed)
                let (want, _) =
                    sns_serial::read(&mut ser, ids, 0, total, 2.0 * now)
                        .unwrap();
                let (got, _) =
                    sns::read(&mut sh, idh, 0, total, 2.0 * now).unwrap();
                want == got
            },
        );
    }
}

#[test]
fn prop_degraded_reads_and_repair_match_oracle_double_failure() {
    let (k, p) = (4u32, 2u32);
    prop_check(
        "recovery-double-4+2",
        12,
        gen_extents,
        |extents: &Vec<(u64, u64)>| {
            let total = span(extents);
            if total == 0 {
                return true;
            }
            let (mut ser, ids, mut sh, idh) = paired_stores(k, p, extents);
            // fail the devices of logical units 1 and 2 of stripe 0
            let mut failed = Vec::new();
            for unit in [1u32, 2] {
                let a = ser.object(ids).unwrap().placement(0, unit).copied();
                let b = sh.object(idh).unwrap().placement(0, unit).copied();
                match (a, b) {
                    (Some(ua), Some(ub)) => {
                        if ua.device != ub.device {
                            return false;
                        }
                        ser.cluster.fail_device(ua.device);
                        sh.cluster.fail_device(ub.device);
                        failed.push(ua.device);
                    }
                    (None, None) => return true,
                    _ => return false,
                }
            }
            // degraded reads: identical bytes, or both unavailable
            // (two lost data units in one stripe are beyond XOR)
            let want = sns_serial::read(&mut ser, ids, 0, total, 100.0)
                .map(|(d, _)| d);
            let got =
                sns::read(&mut sh, idh, 0, total, 100.0).map(|(d, _)| d);
            let reads_agree = match (want, got) {
                (Ok(a), Ok(b)) => a == b,
                (Err(_), Err(_)) => true,
                _ => false,
            };
            if !reads_agree {
                return false;
            }
            // repair of one device with the other still down: both
            // engines agree on success (and bytes) or on unavailability
            let r_ser = sns_serial::repair(&mut ser, &[ids], failed[0], 500.0);
            let r_sh = sns::repair(&mut sh, &[idh], failed[0], 500.0);
            match (r_ser, r_sh) {
                (Ok((ba, ta)), Ok((bb, tb))) => {
                    ba == bb && tb <= ta * (1.0 + 1e-9) + 1e-12
                }
                (Err(_), Err(_)) => true,
                _ => false,
            }
        },
    );
}

#[test]
fn prop_batched_migrate_preserves_bytes_and_leq_serial_fold() {
    // batched Hsm::migrate (ONE scheduler for the whole plan) vs the
    // one-migration-at-a-time serial fold: bytes preserved everywhere,
    // and the batch completes no later on every sampled population
    prop_check(
        "hsm-migrate-batched",
        10,
        gen_extents,
        |extents: &Vec<(u64, u64)>| {
            let mk = || {
                let mut s =
                    MeroStore::new(Testbed::sage_prototype().build_cluster());
                let mut objs = Vec::new();
                for (round, (idx, lenb)) in extents.iter().enumerate() {
                    let id = s.create_object(BS, layout(4, 1)).unwrap();
                    let data = bytes_for(*idx + round as u64, *lenb + 1);
                    s.write_object(id, 0, &data, 0.0, None).unwrap();
                    objs.push((id, data));
                }
                (s, objs)
            };
            let (mut sa, objs_a) = mk();
            let (mut sb, objs_b) = mk();
            // same creation order => same object ids in both stores
            assert_eq!(
                objs_a.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
                objs_b.iter().map(|(id, _)| *id).collect::<Vec<_>>()
            );
            // alternate promotions and demotions in one plan
            let tiers = [DeviceKind::Nvram, DeviceKind::Hdd];
            let plan: Vec<Migration> = objs_a
                .iter()
                .enumerate()
                .map(|(i, (id, _))| Migration {
                    obj: *id,
                    from: DeviceKind::Ssd,
                    to: tiers[i % 2],
                })
                .collect();
            let mut hsm_a = Hsm::new(TieringPolicy::HeatWeighted);
            let t_batch = hsm_a.migrate(&mut sa, &plan, 10.0).unwrap();
            let mut hsm_b = Hsm::new(TieringPolicy::HeatWeighted);
            let mut t_serial = 10.0;
            for m in &plan {
                t_serial = hsm_b
                    .migrate(&mut sb, std::slice::from_ref(m), t_serial)
                    .unwrap();
            }
            if t_batch > t_serial * (1.0 + 1e-9) + 1e-12 {
                return false;
            }
            // bytes preserved in the batched store, tiers retargeted
            for (i, (id, data)) in objs_a.iter().enumerate() {
                let (back, _) = sa
                    .read_object(*id, 0, data.len() as u64, t_batch + 1.0)
                    .unwrap();
                if &back != data {
                    return false;
                }
                if sa.object(*id).unwrap().layout.tier() != tiers[i % 2] {
                    return false;
                }
            }
            true
        },
    );
}
