//! Fixture tests for `sage lint` (ISSUE 9, satellite d): every rule
//! gets a violating fixture and a clean one, the suppression window
//! and waiver grammar are pinned, and — most importantly — the CI
//! gate is *proved*: a tree seeded with a violation makes
//! [`run_lint`] report a deny, and the shipped `rust/src` tree lints
//! clean with exactly the waivers the code carries.
//!
//! Single-file rule behavior goes through [`lint_source`] (the `rel`
//! path argument selects module scoping); tree-level behavior
//! (oracle-freeze checksums, sorted walk, JSON rendering) goes
//! through [`run_lint`] over scratch trees under the OS temp dir.

use std::fs;
use std::path::{Path, PathBuf};

use sage::tools::lint::{
    default_src_root, lint_source, run_lint, FileLint, NO_AMBIENT_ENTROPY,
    NO_HASH_ITERATION, NO_PANIC_IN_RECOVERY, NO_WALL_CLOCK, ORACLE_FREEZE,
    RULES, SCHEDULER_DISCIPLINE, WAIVER_SYNTAX,
};

/// Rules fired by a fixture, in report order.
fn fired(fl: &FileLint) -> Vec<&'static str> {
    fl.violations.iter().map(|v| v.rule).collect()
}

// ------------------------------------------------- per-rule fixtures

#[test]
fn no_wall_clock_fires_in_sim_and_not_in_bench() {
    let src = "pub fn t() -> std::time::Instant { Instant::now() }\n";
    let fl = lint_source("sim/foo.rs", src);
    assert_eq!(fired(&fl), [NO_WALL_CLOCK]);
    assert_eq!(fl.violations[0].line, 1);
    // bench/ is exempt: wall clocks are what benches are for
    assert!(lint_source("bench/foo.rs", src).violations.is_empty());
    // SystemTime is flagged anywhere outside bench/
    let fl = lint_source("util/foo.rs", "fn t() { SystemTime::now(); }\n");
    assert_eq!(fired(&fl), [NO_WALL_CLOCK]);
    // naming the type in an import path alone does not fire the
    // `Instant :: now` pattern
    let clean = lint_source("sim/foo.rs", "use std::time::Instant;\n");
    assert!(clean.violations.is_empty());
}

#[test]
fn no_hash_iteration_scopes_to_sim_visible_modules() {
    let src = "use std::collections::HashMap;\n\
               pub fn m() -> HashMap<u32, u32> { HashMap::new() }\n";
    for rel in ["sim/a.rs", "mero/a.rs", "clovis/a.rs", "hsm/a.rs"] {
        let fl = lint_source(rel, src);
        assert!(
            fl.violations.iter().all(|v| v.rule == NO_HASH_ITERATION),
            "{rel}: {:?}",
            fl.violations
        );
        assert_eq!(fl.violations.len(), 3, "{rel}: one hit per mention");
    }
    // outside the sim-visible prefixes the rule is silent
    assert!(lint_source("util/a.rs", src).violations.is_empty());
    // ordered containers are the sanctioned replacement
    let clean = "use std::collections::BTreeMap;\n\
                 pub fn m() -> BTreeMap<u32, u32> { BTreeMap::new() }\n";
    assert!(lint_source("sim/a.rs", clean).violations.is_empty());
    let fl = lint_source("mero/a.rs", "use std::collections::HashSet;\n");
    assert_eq!(fired(&fl), [NO_HASH_ITERATION]);
}

#[test]
fn scheduler_discipline_reserves_direct_io_to_the_scheduler() {
    let src = "fn go(d: &mut Device) {\n\
               let t = d.io(0.0, 4096, IoOp::Read, Access::Seq);\n\
               let u = d.io_run(t, 4, 4096, IoOp::Write, Access::Seq);\n\
               }\n";
    let fl = lint_source("clovis/foo.rs", src);
    assert_eq!(fired(&fl), [SCHEDULER_DISCIPLINE, SCHEDULER_DISCIPLINE]);
    assert_eq!(fl.violations[0].line, 2);
    assert_eq!(fl.violations[1].line, 3);
    // the scheduler itself and the preserved oracles are exempt
    for rel in [
        "sim/sched.rs",
        "sim/sched_oracle.rs",
        "sim/qos_static_oracle.rs",
        "mero/sns_baseline.rs",
        "mero/sns_serial.rs",
    ] {
        assert!(lint_source(rel, src).violations.is_empty(), "{rel}");
    }
    // a multi-line method chain anchors the hit on the `.io(` line, so
    // a waiver comment inserted inside the chain suppresses it
    let chain = "fn go(c: &C) {\n\
                 let t = c\n\
                 .cluster\n\
                 // sage-lint: allow(scheduler-discipline, \"probe\")\n\
                 .io(0.0, 1, IoOp::Read, Access::Seq);\n\
                 }\n";
    let fl = lint_source("clovis/foo.rs", chain);
    assert!(fl.violations.is_empty(), "{:?}", fl.violations);
    assert_eq!(fl.waivers_honored, 1);
}

#[test]
fn no_panic_in_recovery_covers_ha_and_the_recovery_fns() {
    let src = "fn f(x: Option<u32>) -> u32 {\n\
               panic!(\"boom\");\n\
               x.unwrap();\n\
               x.expect(\"y\")\n\
               }\n";
    // the whole HA subsystem is recovery plane
    let fl = lint_source("mero/ha.rs", src);
    assert_eq!(
        fired(&fl),
        [NO_PANIC_IN_RECOVERY, NO_PANIC_IN_RECOVERY, NO_PANIC_IN_RECOVERY]
    );
    // in clovis/mod.rs only the named recovery fns are in scope
    let scoped = "fn consume_event(x: Option<u32>) -> u32 { x.unwrap() }\n\
                  fn helper(x: Option<u32>) -> u32 { x.unwrap() }\n";
    let fl = lint_source("clovis/mod.rs", scoped);
    assert_eq!(fired(&fl), [NO_PANIC_IN_RECOVERY]);
    assert_eq!(fl.violations[0].line, 1);
    // other modules may unwrap (clippy taste aside, not this rule)
    assert!(lint_source("mero/dtm.rs", scoped).violations.is_empty());
    // unwrap_or / strip-prefix style idents never match
    let clean = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n";
    assert!(lint_source("mero/ha.rs", clean).violations.is_empty());
}

#[test]
fn no_ambient_entropy_routes_randomness_through_sim_rng() {
    let fl = lint_source("sim/a.rs", "use rand::Rng;\n");
    assert_eq!(fired(&fl), [NO_AMBIENT_ENTROPY]);
    let fl = lint_source("util/a.rs", "fn f() { let r = thread_rng(); }\n");
    assert_eq!(fired(&fl), [NO_AMBIENT_ENTROPY]);
    // the seeded-stream module itself is the one sanctioned home
    assert!(lint_source("sim/rng.rs", "use rand::Rng;\n")
        .violations
        .is_empty());
    let clean = "use crate::sim::rng::SimRng;\n\
                 fn f() { let mut r = SimRng::new(7); r.next_u64(); }\n";
    assert!(lint_source("sim/a.rs", clean).violations.is_empty());
}

// --------------------------------------- masks, windows and grammar

#[test]
fn cfg_test_blocks_are_masked() {
    let src = "pub struct S;\n\
               #[cfg(test)]\n\
               mod tests {\n\
               use std::collections::HashMap;\n\
               fn t() { let _ = SystemTime::now(); }\n\
               }\n";
    assert!(lint_source("sim/a.rs", src).violations.is_empty());
    // the same code outside the masked block fires both rules
    let live = "use std::collections::HashMap;\n\
                fn t() { let _ = SystemTime::now(); }\n";
    let fl = lint_source("sim/a.rs", live);
    assert_eq!(fired(&fl), [NO_HASH_ITERATION, NO_WALL_CLOCK]);
}

#[test]
fn string_literals_and_doc_comments_are_inert() {
    let src = "const HELP: &str = \"never call Instant::now or HashMap\";\n\
               /// Discusses SystemTime and sage-lint: allow(bogus).\n\
               pub fn f() {}\n";
    let fl = lint_source("sim/a.rs", src);
    assert!(fl.violations.is_empty(), "{:?}", fl.violations);
}

#[test]
fn suppression_window_is_same_line_or_line_above() {
    let above = "// sage-lint: allow(no-wall-clock, \"diag timer\")\n\
                 fn f() { let _ = SystemTime::now(); }\n";
    let fl = lint_source("sim/a.rs", above);
    assert!(fl.violations.is_empty());
    assert_eq!(fl.waivers_honored, 1);

    let trailing = "fn f() { let _ = SystemTime::now(); } \
                    // sage-lint: allow(no-wall-clock, \"diag timer\")\n";
    let fl = lint_source("sim/a.rs", trailing);
    assert!(fl.violations.is_empty());
    assert_eq!(fl.waivers_honored, 1);

    // two lines up is out of the window: the waiver is inert
    let far = "// sage-lint: allow(no-wall-clock, \"too far\")\n\
               \n\
               fn f() { let _ = SystemTime::now(); }\n";
    let fl = lint_source("sim/a.rs", far);
    assert_eq!(fired(&fl), [NO_WALL_CLOCK]);
    assert_eq!(fl.waivers_honored, 0);

    // a waiver for a different rule does not suppress
    let wrong = "// sage-lint: allow(no-hash-iteration, \"wrong rule\")\n\
                 fn f() { let _ = SystemTime::now(); }\n";
    let fl = lint_source("sim/a.rs", wrong);
    assert_eq!(fired(&fl), [NO_WALL_CLOCK]);
    assert_eq!(fl.waivers_honored, 0);
}

#[test]
fn waiver_grammar_requires_known_rule_and_quoted_reason() {
    // missing reason
    let fl = lint_source("sim/a.rs", "// sage-lint: allow(no-wall-clock)\n");
    assert_eq!(fired(&fl), [WAIVER_SYNTAX]);
    // empty reason
    let fl = lint_source(
        "sim/a.rs",
        "// sage-lint: allow(no-wall-clock, \"\")\n",
    );
    assert_eq!(fired(&fl), [WAIVER_SYNTAX]);
    // unknown rule
    let fl = lint_source(
        "sim/a.rs",
        "// sage-lint: allow(no-such-rule, \"reason\")\n",
    );
    assert_eq!(fired(&fl), [WAIVER_SYNTAX]);
    // not the allow(..) shape
    let fl = lint_source("sim/a.rs", "// sage-lint: deny(no-wall-clock)\n");
    assert_eq!(fired(&fl), [WAIVER_SYNTAX]);
    // a well-formed but unused waiver is inert, not an error
    let fl = lint_source(
        "sim/a.rs",
        "// sage-lint: allow(no-wall-clock, \"unused\")\npub fn f() {}\n",
    );
    assert!(fl.violations.is_empty());
    assert_eq!(fl.waivers_honored, 0);
}

// ------------------------------------------------ tree-level checks

fn scratch(name: &str) -> PathBuf {
    let root = std::env::temp_dir()
        .join(format!("sage-lint-fixtures-{}", std::process::id()))
        .join(name);
    let _ = fs::remove_dir_all(&root);
    fs::create_dir_all(&root).unwrap();
    root
}

fn put(root: &Path, rel: &str, src: &str) {
    let p = root.join(rel);
    fs::create_dir_all(p.parent().unwrap()).unwrap();
    fs::write(p, src).unwrap();
}

/// The CI gate, proved: seed a violation into a scratch tree and the
/// run reports a nonzero deny count (this is exactly the condition
/// that makes `sage lint` exit 1 and the CI `lint` job fail).
#[test]
fn seeded_violation_fails_the_run() {
    let root = scratch("seeded");
    put(&root, "lib.rs", "pub mod sim;\n");
    put(
        &root,
        "sim/clock.rs",
        "pub fn now_ms() -> u128 {\n\
         SystemTime::now().duration_since(UNIX_EPOCH).unwrap().as_millis()\n\
         }\n",
    );
    let report = run_lint(&root).unwrap();
    assert!(report.deny_count() > 0);
    let seeded: Vec<_> = report
        .violations
        .iter()
        .filter(|v| v.rule == NO_WALL_CLOCK)
        .collect();
    assert_eq!(seeded.len(), 1);
    assert_eq!(seeded[0].file, "sim/clock.rs");
    assert_eq!(seeded[0].line, 2);
    // the human rendering carries the file:line anchor CI users grep
    assert!(report.render().contains("sim/clock.rs:2 [no-wall-clock]"));
}

#[test]
fn scratch_trees_report_missing_oracles() {
    let root = scratch("no-oracles");
    put(&root, "lib.rs", "pub fn ok() {}\n");
    let report = run_lint(&root).unwrap();
    // all four preserved oracles are absent from this tree
    let missing: Vec<_> = report
        .violations
        .iter()
        .filter(|v| v.rule == ORACLE_FREEZE)
        .collect();
    assert_eq!(missing.len(), 4);
    assert!(missing
        .iter()
        .all(|v| v.message.contains("missing from the tree")));
}

#[test]
fn edited_oracle_needs_an_in_file_waiver() {
    // an "edited" oracle: content that cannot match the pinned CRC
    let root = scratch("oracle-edit");
    put(&root, "mero/sns_baseline.rs", "pub fn edited() {}\n");
    let report = run_lint(&root).unwrap();
    assert!(report
        .violations
        .iter()
        .any(|v| v.rule == ORACLE_FREEZE
            && v.file == "mero/sns_baseline.rs"
            && v.message.contains("preserved oracle edited")));

    // the same edit carrying a file-scoped waiver is accepted
    let root = scratch("oracle-waived");
    put(
        &root,
        "mero/sns_baseline.rs",
        "// sage-lint: allow(oracle-freeze, \"regenerated for new layout\")\n\
         pub fn edited() {}\n",
    );
    let report = run_lint(&root).unwrap();
    assert!(!report
        .violations
        .iter()
        .any(|v| v.rule == ORACLE_FREEZE && v.file == "mero/sns_baseline.rs"));
    assert!(report.waivers_honored >= 1);
}

#[test]
fn json_rendering_is_machine_checkable() {
    let root = scratch("json");
    put(&root, "sim/a.rs", "fn t() { let _ = SystemTime::now(); }\n");
    let report = run_lint(&root).unwrap();
    let j = report.to_json().to_string();
    assert!(j.contains("\"ok\":false"), "{j}");
    assert!(j.contains("\"files_scanned\":1"), "{j}");
    assert!(j.contains("\"rule\":\"no-wall-clock\""), "{j}");
    assert!(j.contains("\"file\":\"sim/a.rs\""), "{j}");
    assert!(j.contains("\"severity\":\"deny\""), "{j}");

    let root = scratch("json-clean");
    put(&root, "util/a.rs", "pub fn ok() {}\n");
    // a clean tree still misses the oracles, so pin only per-file JSON:
    // lint a tree with no violations except the oracle quartet, then
    // check `ok` flips with deny_count
    let report = run_lint(&root).unwrap();
    assert_eq!(report.deny_count(), 4); // the four absent oracles
}

/// The shipped tree is the final fixture: `rust/src` lints clean, and
/// the waiver budget is exactly what the code carries — seven
/// `no-wall-clock` diag timers in `tools/soak.rs`, plus six
/// `scheduler-discipline` sites: the counterfactual probe in
/// `clovis/fshipping.rs`, the retained `Cluster::io` primitive, and
/// the private device pools of the PGAS/MPI-IO/streams models (two in
/// `pgas/mod.rs`, one each in `pgas/mpiio.rs` and `streams/mod.rs`).
/// A new waiver (or a lost one) moves this number and must be
/// reviewed here.
#[test]
fn shipped_tree_lints_clean_with_the_pinned_waiver_budget() {
    let root = default_src_root();
    assert!(
        root.join("lib.rs").is_file(),
        "src root not found from test cwd: {}",
        root.display()
    );
    let report = run_lint(&root).unwrap();
    assert!(
        report.violations.is_empty(),
        "shipped tree must lint clean:\n{}",
        report.render()
    );
    assert_eq!(report.deny_count(), 0);
    assert_eq!(report.waivers_honored, 13, "waiver budget moved");
    assert!(report.files_scanned > 40);
}

#[test]
fn rule_table_is_complete_and_deny_by_default() {
    assert_eq!(RULES.len(), 6);
    for r in RULES {
        assert!(!r.invariant.is_empty());
        assert_eq!(r.severity.as_str(), "deny", "{}", r.name);
    }
}
