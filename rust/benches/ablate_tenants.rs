//! Ablation: the multi-tenant plane (ISSUE 7 tentpole) — weighted
//! per-tenant lanes on the ONE cluster-wide scheduler vs the same
//! merged workload on the inactive plane (every session as
//! `DEFAULT_TENANT`, FIFO contention), on the skewed 4+1 pool (seven
//! healthy SSDs plus ONE SMR-class tier-4 straggler admitted to the
//! flash pool, as in `ablate_sched`/`ablate_qos`).
//!
//! Workload: `tools::tenants` — N tenants with skewed weights, open
//! Poisson arrivals merged deterministically, heavy-tailed Zipf
//! request sizes, every request a session dispatched at its arrival
//! instant so sessions overlap in virtual time and contend shard by
//! shard. A closed-arrival (think-time) run of the same plane rides
//! along for the record. Reported: per-tenant p50/p99/p999 completion
//! latency with the plane on and off, Jain fairness of
//! weight-normalized throughput, makespans, and wall-clock cycle
//! medians ± MAD. Asserted in-bench:
//!
//! * both engines land byte-identical state (`bytes_crc`,
//!   read-back-verified inside the generator) — tenancy changes WHEN,
//!   never WHAT;
//! * on every shard every tenant's observed device-time share stays
//!   within its [`TenantShares::share`] bound, and the lanes really
//!   ran (shares observed > 0).
//!
//! Run: `cargo bench --bench ablate_tenants`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench ablate_tenants`
//! Rows append to `bench_results/ablate_tenants.json`
//! (fields documented in `bench_results/README.md`).

use sage::bench::{record, Bencher};
use sage::clovis::Client;
use sage::cluster::{Cluster, EnclosureCompute};
use sage::metrics::Table;
use sage::sim::device::{DeviceKind, DeviceProfile};
use sage::sim::network::NetworkModel;
use sage::sim::sched::{TenantShares, DEFAULT_TENANT};
use sage::tools::tenants::{run_with, ArrivalModel, TenantsConfig, TenantsReport};

/// The skewed pool: seven healthy SSDs plus ONE SMR-class straggler
/// (tier-4 profile) pooled with the flash devices — the geometry where
/// a queue-blind policy lets one hot tenant camp on the slow shard.
fn skewed_cluster() -> Cluster {
    let mut profiles: Vec<DeviceProfile> =
        (0..7).map(|_| DeviceProfile::ssd(2 << 40)).collect();
    let mut straggler = DeviceProfile::smr(2 << 40);
    straggler.kind = DeviceKind::Ssd; // pooled with the flash devices
    profiles.push(straggler);
    let mut c = Cluster::new(NetworkModel::fdr_infiniband());
    for chunk in profiles.chunks(4) {
        c.add_node(
            chunk.to_vec(),
            EnclosureCompute { cores: 16, flops: 5e10 },
        );
    }
    c
}

fn client() -> Client {
    Client::from_cluster(skewed_cluster())
}

fn cfg(quick: bool, seed: u64, tenancy: bool) -> TenantsConfig {
    let mut c = if quick {
        TenantsConfig::quick(seed)
    } else {
        TenantsConfig::full(seed)
    };
    c.tenancy = tenancy;
    c
}

/// The admission table the generator installs for `weights` — used to
/// recompute each tenant's share bound for the in-bench assert.
fn shares_of(weights: &[f64]) -> TenantShares {
    let mut s = TenantShares::single();
    s.set_weight(DEFAULT_TENANT, weights[0]);
    for &w in &weights[1..] {
        s.register(w);
    }
    s
}

fn fmt_ms(s: f64) -> String {
    format!("{:.1}ms", s * 1e3)
}

fn main() {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let (warm, iters) = if quick { (1, 3) } else { (2, 8) };
    let seed = 42u64;

    // ---- virtual time: plane on vs plane off (same merged arrivals) ---
    let on_cfg = cfg(quick, seed, true);
    let on: TenantsReport = run_with(client(), &on_cfg).unwrap();
    let off: TenantsReport = run_with(client(), &cfg(quick, seed, false)).unwrap();

    // tenancy changes WHEN, never WHAT: the generator read-back-verified
    // every object in both runs, and the final-byte digests agree
    assert_eq!(on.requests, off.requests, "same merged arrival stream");
    assert_eq!(on.total_bytes, off.total_bytes);
    assert_eq!(
        on.bytes_crc, off.bytes_crc,
        "plane on/off must land byte-identical state"
    );

    // the weighted share bound holds on every shard of every session
    let shares = shares_of(&on_cfg.weights);
    for t in &on.per_tenant {
        assert!(
            t.max_observed_share > 0.0,
            "tenant {} lanes really ran",
            t.tenant
        );
        assert!(
            t.max_observed_share <= shares.share(t.tenant) + 1e-9,
            "tenant {} observed share {} exceeds its {} bound",
            t.tenant,
            t.max_observed_share,
            shares.share(t.tenant)
        );
    }

    let mut t = Table::new(
        &format!(
            "Multi-tenant plane on skewed pool ({} tenants x {} open-arrival \
             requests, heavy-tailed sizes)",
            on_cfg.weights.len(),
            on_cfg.requests_per_tenant
        ),
        &["tenant", "weight", "p50 on", "p99 on", "p999 on", "p99 off", "max share", "bound"],
    );
    for (a, b) in on.per_tenant.iter().zip(off.per_tenant.iter()) {
        t.row(vec![
            a.tenant.to_string(),
            format!("{:.1}", a.weight),
            fmt_ms(a.p50),
            fmt_ms(a.p99),
            fmt_ms(a.p999),
            fmt_ms(b.p99),
            format!("{:.3}", a.max_observed_share),
            format!("{:.3}", shares.share(a.tenant)),
        ]);
    }
    print!("{}", t.render());
    println!(
        "jain (bytes/weight): {:.4} on vs {:.4} off; makespan {} on vs {} off\n",
        on.jain,
        off.jain,
        sage::metrics::fmt_secs(on.makespan),
        sage::metrics::fmt_secs(off.makespan)
    );

    // ---- closed arrivals ride along: self-throttled demand ------------
    let mut closed_cfg = cfg(quick, seed, true);
    closed_cfg.arrival = ArrivalModel::Closed { think: 0.3 };
    let closed = run_with(client(), &closed_cfg).unwrap();
    assert_eq!(closed.requests, on.requests, "same request budget");
    println!(
        "closed model: jain {:.4}, p99 heaviest {} / lightest {}\n",
        closed.jain,
        fmt_ms(closed.per_tenant.first().unwrap().p99),
        fmt_ms(closed.per_tenant.last().unwrap().p99)
    );

    // ---- wall-clock cycle ---------------------------------------------
    let m_on = Bencher::new("tenants_plane_on")
        .iters(warm, iters)
        .wall(|| run_with(client(), &cfg(quick, seed, true)).unwrap().makespan);
    let m_off = Bencher::new("tenants_plane_off")
        .iters(warm, iters)
        .wall(|| run_with(client(), &cfg(quick, seed, false)).unwrap().makespan);

    let mut t = Table::new(
        "Wall-clock generator cycle (population + merge + sessions + verify)",
        &["engine", "cycle", "ratio"],
    );
    t.row(vec![
        "plane off".into(),
        sage::metrics::fmt_secs(m_off.median),
        "1.00x".into(),
    ]);
    t.row(vec![
        "plane on".into(),
        sage::metrics::fmt_secs(m_on.median),
        format!("{:.2}x", m_on.median / m_off.median.max(1e-12)),
    ]);
    print!("{}", t.render());

    let heavy_on = &on.per_tenant[0];
    let light_on = on.per_tenant.last().unwrap();
    let heavy_off = &off.per_tenant[0];
    let light_off = off.per_tenant.last().unwrap();
    record("ablate_tenants", &[
        ("n_tenants", on_cfg.weights.len() as f64),
        ("requests_per_tenant", on_cfg.requests_per_tenant as f64),
        ("requests_total", on.requests as f64),
        ("total_bytes", on.total_bytes as f64),
        ("iters", iters as f64),
        ("jain_on", on.jain),
        ("jain_off", off.jain),
        ("jain_closed", closed.jain),
        ("makespan_on_s", on.makespan),
        ("makespan_off_s", off.makespan),
        ("heavy_p50_on_s", heavy_on.p50),
        ("heavy_p99_on_s", heavy_on.p99),
        ("heavy_p999_on_s", heavy_on.p999),
        ("heavy_p99_off_s", heavy_off.p99),
        ("light_p50_on_s", light_on.p50),
        ("light_p99_on_s", light_on.p99),
        ("light_p999_on_s", light_on.p999),
        ("light_p99_off_s", light_off.p99),
        ("heavy_max_share", heavy_on.max_observed_share),
        ("heavy_share_bound", shares.share(heavy_on.tenant)),
        ("light_max_share", light_on.max_observed_share),
        ("light_share_bound", shares.share(light_on.tenant)),
        ("on_cycle_s", m_on.median),
        ("on_mad_s", m_on.mad),
        ("off_cycle_s", m_off.median),
        ("off_mad_s", m_off.mad),
    ]);
}
