//! Bench: Figure 3 — STREAM over MPI windows (all three panels) at the
//! paper's full problem sizes. Prints the same series the paper plots
//! and records rows to bench_results/fig3.json.
//!
//! Run: `cargo bench --bench fig3_stream`

use sage::apps::stream;
use sage::bench::record;
use sage::config::Testbed;
use sage::metrics::Table;
use sage::pgas::{StorageTarget, WindowKind};

fn main() {
    // ---------------- (a) Blackdog: storage ~ memory ------------------
    let tb = Testbed::blackdog();
    let mut t = Table::new(
        "Fig 3(a) STREAM Blackdog (MB/s, all kernels, 1000M elems)",
        &["kernel", "memory", "storage(hdd)", "degradation"],
    );
    let mem = stream::run(&tb, WindowKind::Memory, 1000, 3).unwrap();
    let sto = stream::run(&tb, WindowKind::Storage(StorageTarget::Hdd), 1000, 3).unwrap();
    for (m, s) in mem.iter().zip(sto.iter()) {
        let deg = (1.0 - s.bandwidth / m.bandwidth) * 100.0;
        t.row(vec![
            m.kernel.into(),
            format!("{:.0}", m.bandwidth / 1e6),
            format!("{:.0}", s.bandwidth / 1e6),
            format!("{deg:.1}%"),
        ]);
        record("fig3a", &[
            ("mem_mbs", m.bandwidth / 1e6),
            ("sto_mbs", s.bandwidth / 1e6),
            ("degradation_pct", deg),
        ]);
    }
    print!("{}", t.render());
    println!("paper: ~10% degradation at the largest case\n");

    // problem-size sweep (the x-axis of the paper's panel)
    let mut t = Table::new(
        "Fig 3(a) sweep: triad MB/s by problem size",
        &["Melems", "memory", "storage(hdd)"],
    );
    for m_elems in [10u64, 50, 100, 500, 1000] {
        let mem = stream::run(&tb, WindowKind::Memory, m_elems, 2).unwrap();
        let sto =
            stream::run(&tb, WindowKind::Storage(StorageTarget::Hdd), m_elems, 2)
                .unwrap();
        t.row(vec![
            m_elems.to_string(),
            format!("{:.0}", mem[3].bandwidth / 1e6),
            format!("{:.0}", sto[3].bandwidth / 1e6),
        ]);
    }
    print!("{}", t.render());

    // ---------------- (b) Lustre asymmetry ----------------------------
    let tegner = Testbed::tegner();
    let (r, w) = stream::rw_asymmetry(&tegner, StorageTarget::Pfs, 8 << 30).unwrap();
    println!(
        "\nFig 3(b) Lustre asymmetry: read {:.0} MB/s, write {:.0} MB/s \
         (paper: 12,308 / 1,374)",
        r / 1e6,
        w / 1e6
    );
    record("fig3b", &[("read_mbs", r / 1e6), ("write_mbs", w / 1e6)]);

    // ---------------- (c) Tegner collapse -----------------------------
    let mut t = Table::new(
        "Fig 3(c) STREAM Tegner (MB/s, triad)",
        &["Melems", "memory", "storage(pfs)", "degradation"],
    );
    for m_elems in [10u64, 100, 1000] {
        let mem = stream::run(&tegner, WindowKind::Memory, m_elems, 2).unwrap();
        let sto =
            stream::run(&tegner, WindowKind::Storage(StorageTarget::Pfs), m_elems, 2)
                .unwrap();
        let deg = (1.0 - sto[3].bandwidth / mem[3].bandwidth) * 100.0;
        t.row(vec![
            m_elems.to_string(),
            format!("{:.0}", mem[3].bandwidth / 1e6),
            format!("{:.0}", sto[3].bandwidth / 1e6),
            format!("{deg:.1}%"),
        ]);
        record("fig3c", &[
            ("m_elems", m_elems as f64),
            ("degradation_pct", deg),
        ]);
    }
    print!("{}", t.render());
    println!("paper: ~90% degradation (write-bandwidth limited)");
}
