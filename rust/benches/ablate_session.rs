//! Ablation: ONE mixed-workload Clovis session vs sequential legacy
//! calls (the ISSUE 4 tentpole measurement — the paper's headline
//! scenario of in-storage compute overlapping foreground I/O and
//! background data movement on one set of device queues).
//!
//! Pool: seven healthy SSDs plus ONE SMR-class (tier-4 profile)
//! straggler admitted to the flash pool, plus six HDDs (the demotion
//! target tier). Workload per cycle:
//!
//! * **ship** — `FunctionKind::IntegrityCheck` shipped to each
//!   analytics object (in-storage compute; the node-local read rides
//!   the session's shards),
//! * **write** — a multi-stripe checkpoint batch onto a fresh object,
//! * **migrate** — a cold-object demotion plan (SSD → HDD) through the
//!   recovery plane.
//!
//! Engines:
//! * **sequential legacy** — `ship_to_object` per object, then
//!   `writev`, then `migrate_with`; every call waits for the previous
//!   one (the pre-session programming model: each entry point builds
//!   its own private op group).
//! * **session** — the same ops staged on ONE `client.session()` with
//!   no `.after` edges: everything dispatches at the session clock and
//!   overlaps across per-device shards.
//!
//! Reported: virtual makespan of both engines (`virtual_speedup` =
//! sequential / session, asserted >= 1), the session's per-device
//! frontier table (`straggler_isolation` = straggler frontier /
//! fastest SSD frontier), and the wall-clock cycle median ± MAD via
//! the in-tree `Bencher`. Byte-equivalence is asserted in-bench: both
//! engines' stores read back identical bytes and the migrated objects
//! land on the same tier.
//!
//! Run: `cargo bench --bench ablate_session`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench ablate_session`
//! Rows append to `bench_results/ablate_session.json`.

use sage::bench::{record, Bencher};
use sage::clovis::{Client, FunctionKind};
use sage::cluster::{Cluster, EnclosureCompute};
use sage::hsm::{Hsm, Migration, TieringPolicy};
use sage::mero::{Layout, ObjectId};
use sage::metrics::Table;
use sage::sim::device::{DeviceKind, DeviceProfile};
use sage::sim::network::NetworkModel;
use sage::sim::rng::SimRng;

const UNIT: u64 = 65536;
const K: u32 = 4;
const P: u32 = 2;

fn layout() -> Layout {
    Layout::Raid { data: K, parity: P, unit: UNIT, tier: DeviceKind::Ssd }
}

/// Seven healthy SSDs + one SMR-class straggler pooled with the flash
/// devices (as in `ablate_sched`/`ablate_repair`), plus six HDDs so a
/// 4+2 demotion target exists.
fn mixed_cluster() -> Cluster {
    let mut c = Cluster::new(NetworkModel::fdr_infiniband());
    c.add_node(
        (0..4).map(|_| DeviceProfile::ssd(2 << 40)).collect(),
        EnclosureCompute { cores: 16, flops: 5e10 },
    );
    let mut straggler = DeviceProfile::smr(2 << 40);
    straggler.kind = DeviceKind::Ssd; // pooled with the flash devices
    let mut node_b: Vec<DeviceProfile> =
        (0..3).map(|_| DeviceProfile::ssd(2 << 40)).collect();
    node_b.push(straggler);
    c.add_node(node_b, EnclosureCompute { cores: 16, flops: 5e10 });
    c.add_node(
        (0..6).map(|_| DeviceProfile::hdd(4 << 40)).collect(),
        EnclosureCompute { cores: 4, flops: 1e10 },
    );
    c
}

/// Index of the straggler device in [`mixed_cluster`].
fn straggler_dev(c: &Cluster) -> usize {
    (0..c.devices.len())
        .find(|&d| {
            c.devices[d].profile.kind == DeviceKind::Ssd
                && c.devices[d].profile.write_bw < 100e6
        })
        .expect("straggler present")
}

fn client() -> Client {
    Client::from_cluster(mixed_cluster())
}

struct Prepared {
    c: Client,
    analytics: Vec<ObjectId>,
    cold: Vec<ObjectId>,
    chk: ObjectId,
    cold_data: Vec<Vec<u8>>,
    ana_data: Vec<Vec<u8>>,
}

/// Build identical pre-state for either engine: analytics objects to
/// ship on, cold objects to demote, and a fresh checkpoint object.
fn prepare(n_ship: usize, n_cold: usize) -> Prepared {
    let mut c = client();
    let mut rng = SimRng::new(17);
    let stripe = K as u64 * UNIT;
    let mut analytics = Vec::new();
    let mut ana_data = Vec::new();
    for _ in 0..n_ship {
        let o = c.create_object_with(4096, layout()).unwrap();
        let mut d = vec![0u8; stripe as usize];
        rng.fill_bytes(&mut d);
        c.write_object(&o, 0, &d).unwrap();
        analytics.push(o);
        ana_data.push(d);
    }
    let mut cold = Vec::new();
    let mut cold_data = Vec::new();
    for _ in 0..n_cold {
        let o = c.create_object_with(4096, layout()).unwrap();
        let mut d = vec![0u8; 2 * stripe as usize];
        rng.fill_bytes(&mut d);
        c.write_object(&o, 0, &d).unwrap();
        cold.push(o);
        cold_data.push(d);
    }
    let chk = c.create_object_with(4096, layout()).unwrap();
    // common clock origin for both engines
    c.now = 1.0;
    Prepared { c, analytics, cold, chk, cold_data, ana_data }
}

fn chk_extents(n_stripes: usize) -> Vec<(u64, Vec<u8>)> {
    let stripe = K as u64 * UNIT;
    let mut rng = SimRng::new(23);
    (0..n_stripes)
        .map(|i| {
            let mut d = vec![0u8; stripe as usize];
            rng.fill_bytes(&mut d);
            (i as u64 * stripe, d)
        })
        .collect()
}

fn plan(cold: &[ObjectId]) -> Vec<Migration> {
    cold.iter()
        .map(|&obj| Migration { obj, from: DeviceKind::Ssd, to: DeviceKind::Hdd })
        .collect()
}

struct CycleOutcome {
    p: Prepared,
    makespan: f64,
    io_calls: u64,
    ios: u64,
    frontiers: Vec<(usize, f64)>,
}

/// Sequential legacy engine: each entry point builds its own private
/// op group; the client clock serializes the calls.
fn run_sequential(n_ship: usize, n_cold: usize, n_stripes: usize) -> CycleOutcome {
    let mut p = prepare(n_ship, n_cold);
    let t0 = p.c.now;
    let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
    let analytics = p.analytics.clone();
    for &obj in &analytics {
        p.c.ship_to_object(obj, FunctionKind::IntegrityCheck).unwrap();
    }
    let chk = p.chk;
    p.c.writev_owned(&chk, chk_extents(n_stripes)).unwrap();
    let mig = plan(&p.cold);
    p.c.migrate_with(&mut hsm, &mig).unwrap();
    let makespan = p.c.now - t0;
    CycleOutcome { p, makespan, io_calls: 0, ios: 0, frontiers: Vec::new() }
}

/// Session engine: the same ops staged on ONE scheduler-backed group,
/// no dependency edges — mixed kinds overlap on shared shards.
fn run_session(n_ship: usize, n_cold: usize, n_stripes: usize) -> CycleOutcome {
    let mut p = prepare(n_ship, n_cold);
    let t0 = p.c.now;
    let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
    let mig = plan(&p.cold);
    let chk = p.chk;
    let analytics = p.analytics.clone();
    let extents = chk_extents(n_stripes);
    let mut s = p.c.session();
    for &obj in &analytics {
        s.ship(obj, FunctionKind::IntegrityCheck);
    }
    s.write_owned(&chk, extents);
    s.migrate(&mut hsm, &mig);
    let rep = s.run().unwrap();
    CycleOutcome {
        makespan: rep.completed_at - t0,
        io_calls: rep.io_calls,
        ios: rep.ios,
        frontiers: rep.frontiers,
        p,
    }
}

fn main() {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let (n_ship, n_cold, n_stripes) = if quick { (2, 2, 8) } else { (4, 4, 32) };
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };
    let stripe = K as u64 * UNIT;

    // ---- virtual-time makespan: sequential legacy vs one session ----
    let mut seq = run_sequential(n_ship, n_cold, n_stripes);
    let mut ses = run_session(n_ship, n_cold, n_stripes);
    assert!(
        ses.makespan <= seq.makespan * (1.0 + 1e-9),
        "one session must not exceed the sequential legacy calls \
         ({} vs {})",
        ses.makespan,
        seq.makespan
    );
    let virtual_speedup = seq.makespan / ses.makespan.max(1e-12);

    // byte + placement oracle on the SAME stores: checkpoint, migrated
    // cold objects (now on HDD) and analytics objects read back
    // identical bytes in both engines
    let chk_want = chk_extents(n_stripes);
    for engine in [&mut seq.p, &mut ses.p] {
        let chk = engine.chk;
        for (off, want) in &chk_want {
            let got = engine.c.read_object(&chk, *off, stripe).unwrap();
            assert_eq!(&got, want, "checkpoint bytes intact");
        }
        let cold = engine.cold.clone();
        for (o, want) in cold.iter().zip(engine.cold_data.clone().iter()) {
            assert_eq!(
                engine.c.store.object(*o).unwrap().layout.tier(),
                DeviceKind::Hdd,
                "cold object demoted"
            );
            let got = engine.c.read_object(o, 0, want.len() as u64).unwrap();
            assert_eq!(&got, want, "migrated bytes intact");
        }
        let ana = engine.analytics.clone();
        for (o, want) in ana.iter().zip(engine.ana_data.clone().iter()) {
            let got = engine.c.read_object(o, 0, want.len() as u64).unwrap();
            assert_eq!(&got, want, "analytics bytes intact");
        }
    }

    let mut t = Table::new(
        &format!(
            "Mixed workload (ship x{n_ship} + write x{n_stripes} stripes + \
             migrate x{n_cold}), {K}+{P}, skewed pool"
        ),
        &["engine", "virtual makespan", "io() calls", "unit I/Os"],
    );
    t.row(vec![
        "sequential legacy".into(),
        sage::metrics::fmt_secs(seq.makespan),
        "-".into(),
        "-".into(),
    ]);
    t.row(vec![
        "one session".into(),
        sage::metrics::fmt_secs(ses.makespan),
        ses.io_calls.to_string(),
        ses.ios.to_string(),
    ]);
    t.row(vec![
        "speedup".into(),
        format!("{virtual_speedup:.2}x"),
        "".into(),
        "".into(),
    ]);
    print!("{}", t.render());

    // ---- per-device frontier table (session engine) -----------------
    let probe = mixed_cluster();
    let straggler = straggler_dev(&probe);
    let mut t = Table::new(
        "Per-device completion frontiers (one session)",
        &["device", "profile", "frontier"],
    );
    let mut fast_max = 0.0f64;
    let mut straggler_frontier = 0.0f64;
    for &(d, f) in &ses.frontiers {
        let kind = probe.devices[d].profile.kind;
        if d == straggler {
            straggler_frontier = f;
        } else if kind == DeviceKind::Ssd {
            fast_max = fast_max.max(f);
        }
        t.row(vec![
            format!("dev{d}"),
            if d == straggler {
                "SMR straggler".into()
            } else {
                format!("{kind:?}")
            },
            sage::metrics::fmt_secs(f),
        ]);
    }
    print!("{}", t.render());
    let isolation = straggler_frontier / fast_max.max(1e-12);
    println!(
        "straggler frontier / fastest-SSD frontier = {isolation:.2}x \
         (healthy shards do not wait for the straggler)\n"
    );

    // ---- wall-clock cycle -------------------------------------------
    let m_seq = Bencher::new("mixed_sequential_legacy")
        .iters(warm, iters)
        .wall(|| run_sequential(n_ship, n_cold, n_stripes).makespan);
    let m_ses = Bencher::new("mixed_one_session")
        .iters(warm, iters)
        .wall(|| run_session(n_ship, n_cold, n_stripes).makespan);
    let wall_speedup = m_seq.median / m_ses.median.max(1e-12);

    let mut t = Table::new(
        "Wall-clock mixed-workload cycle (build + run)",
        &["engine", "cycle", "speedup"],
    );
    t.row(vec![
        "sequential legacy".into(),
        sage::metrics::fmt_secs(m_seq.median),
        "1.00x".into(),
    ]);
    t.row(vec![
        "one session".into(),
        sage::metrics::fmt_secs(m_ses.median),
        format!("{wall_speedup:.2}x"),
    ]);
    print!("{}", t.render());

    record("ablate_session", &[
        ("k", K as f64),
        ("p", P as f64),
        ("n_ship", n_ship as f64),
        ("n_cold", n_cold as f64),
        ("n_chk_stripes", n_stripes as f64),
        ("iters", iters as f64),
        ("sequential_virtual_s", seq.makespan),
        ("session_virtual_s", ses.makespan),
        ("virtual_speedup", virtual_speedup),
        ("straggler_isolation", isolation),
        ("session_io_calls", ses.io_calls as f64),
        ("session_unit_ios", ses.ios as f64),
        ("sequential_cycle_s", m_seq.median),
        ("sequential_mad_s", m_seq.mad),
        ("session_cycle_s", m_ses.median),
        ("session_mad_s", m_ses.mad),
        ("wall_speedup", wall_speedup),
    ]);
}
