//! Ablation: the QoS plane (ISSUE 5 tentpole) — scheduler-level
//! repair/foreground bandwidth split vs the unthrottled engine, on the
//! skewed 4+2 pool (seven healthy SSDs plus ONE SMR-class tier-4
//! straggler admitted to the flash pool, as in `ablate_sched`).
//!
//! Workload per cycle: ONE Clovis session staging a whole-device SNS
//! repair (the rebuild of every object that lost units) FIRST, then a
//! batch of foreground full-stripe checkpoint writes — unchained, so
//! everything dispatches at the session clock and contends on shared
//! per-device shards. Engines differ ONLY in the cluster's
//! `QosConfig`:
//!
//! * **unthrottled** — `QosConfig::unlimited()`: the pre-QoS FIFO;
//!   foreground queues behind the entire committed rebuild.
//! * **default split** — `QosConfig::default()`: repair capped at
//!   0.30 of each device; foreground runs at ≥ 0.70 through the
//!   rebuild window.
//! * **conserving** — `QosConfig::conserving()` (ISSUE 10): the same
//!   split, but capped classes borrow unused foreground headroom on
//!   shards with no committed foreground backlog.
//!
//! Reported: foreground p50 and makespan (virtual) with and without
//! the split, the repair completion of both engines (the price of the
//! cap), the per-class frontier table of the split run, and wall-clock
//! cycle medians ± MAD. Asserted in-bench:
//!
//! * both engines store byte-identical state and rebuild identical
//!   byte counts (the split changes WHEN, never WHAT);
//! * with the default split, foreground virtual makespan under
//!   concurrent repair IMPROVES vs the unthrottled engine while the
//!   repair still completes and the device returns to service;
//! * on every shard repair touched, its observed device-time share
//!   stays within `repair_share` (the cap bounds repair's share);
//! * the conserving mode is never slower: its repair makespan is `<=`
//!   the static split's (strictly better on the borrowed shards) while
//!   foreground p50 is bit-unchanged and bytes stay identical.
//!
//! Run: `cargo bench --bench ablate_qos`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench ablate_qos`
//! Rows append to `bench_results/ablate_qos.json`
//! (fields documented in `bench_results/README.md`).

use sage::bench::{record, Bencher};
use sage::clovis::{Client, OpOutput};
use sage::cluster::{Cluster, EnclosureCompute};
use sage::mero::{Layout, ObjectId};
use sage::metrics::{Stats, Table};
use sage::sim::device::{DeviceKind, DeviceProfile};
use sage::sim::network::NetworkModel;
use sage::sim::rng::SimRng;
use sage::sim::sched::{QosConfig, TrafficClass};

const UNIT: u64 = 65536;
const K: u32 = 4;
const P: u32 = 2;

fn layout() -> Layout {
    Layout::Raid { data: K, parity: P, unit: UNIT, tier: DeviceKind::Ssd }
}

/// The skewed 4+2 pool: seven healthy SSDs plus ONE SMR-class
/// straggler (tier-4 profile) pooled with the flash devices, carrying
/// the engine's `QosConfig`.
fn skewed_cluster(qos: QosConfig) -> Cluster {
    let mut profiles: Vec<DeviceProfile> =
        (0..7).map(|_| DeviceProfile::ssd(2 << 40)).collect();
    let mut straggler = DeviceProfile::smr(2 << 40);
    straggler.kind = DeviceKind::Ssd; // pooled with the flash devices
    profiles.push(straggler);
    let mut c = Cluster::new(NetworkModel::fdr_infiniband());
    for chunk in profiles.chunks(4) {
        c.add_node(
            chunk.to_vec(),
            EnclosureCompute { cores: 16, flops: 5e10 },
        );
    }
    c.qos = qos;
    c
}

fn client(qos: QosConfig) -> Client {
    Client::from_cluster(skewed_cluster(qos))
}

/// Median via the in-tree stats substrate (same interpolation the
/// Bencher reports use).
fn p50(v: &[f64]) -> f64 {
    let mut s = Stats::new();
    for &x in v {
        s.push(x);
    }
    s.median()
}

struct CycleOutcome {
    c: Client,
    repair_objs: Vec<(ObjectId, Vec<u8>)>,
    fg_objs: Vec<(ObjectId, Vec<u8>)>,
    failed_dev: usize,
    bytes_rebuilt: u64,
    /// Per-foreground-op completion latencies from the session clock.
    fg_latencies: Vec<f64>,
    fg_makespan: f64,
    fg_p50: f64,
    repair_completion: f64,
    /// Max over shards of repair's observed device-time share.
    max_repair_share: f64,
    /// Total virtual seconds of foreground headroom lent to repair
    /// across shards (0.0 unless `work_conserving`, ISSUE 10).
    lent_repair: f64,
    io_calls: u64,
    ios: u64,
    /// `(device, base, fg frontier, repair frontier, repair share)`.
    frontier_rows: Vec<(usize, f64, f64, f64, f64)>,
}

/// One cycle: prewrite the repair population, fail a device, then ONE
/// session = whole-device repair + `n_fg` foreground full-stripe
/// checkpoint writes, all dispatching at the session clock.
fn run_cycle(qos: QosConfig, n_obj: usize, n_fg: usize) -> CycleOutcome {
    let stripe = K as u64 * UNIT;
    let mut c = client(qos);
    let mut rng = SimRng::new(41);
    let mut repair_objs = Vec::new();
    for _ in 0..n_obj {
        let o = c.create_object_with(4096, layout()).unwrap();
        let mut d = vec![0u8; 2 * stripe as usize];
        rng.fill_bytes(&mut d);
        c.write_object(&o, 0, &d).unwrap();
        repair_objs.push((o, d));
    }
    let failed_dev = c
        .store
        .object(repair_objs[0].0)
        .unwrap()
        .placement(0, 0)
        .unwrap()
        .device;
    c.store.cluster.fail_device(failed_dev);
    let mut fg_payloads = Vec::new();
    for _ in 0..n_fg {
        let o = c.create_object_with(4096, layout()).unwrap();
        let mut d = vec![0u8; stripe as usize];
        rng.fill_bytes(&mut d);
        fg_payloads.push((o, d));
    }
    let t0 = c.now;
    let ids: Vec<ObjectId> = repair_objs.iter().map(|(o, _)| *o).collect();
    let mut s = c.session();
    let r = s.repair(&ids, failed_dev);
    let fg_handles: Vec<_> = fg_payloads
        .iter()
        .map(|(o, d)| s.write_owned(o, vec![(0, d.clone())]))
        .collect();
    let rep = s.run().unwrap();
    let bytes_rebuilt = match rep.output(r) {
        OpOutput::Repair { bytes } => *bytes,
        other => panic!("repair output expected, got {other:?}"),
    };
    let fg_latencies: Vec<f64> = fg_handles
        .iter()
        .map(|h| rep.completed[h.index()] - t0)
        .collect();
    let fg_makespan = fg_latencies.iter().fold(0.0f64, |m, &t| m.max(t));
    let fg_p50 = p50(&fg_latencies);
    let repair_completion = rep.completed[r.index()] - t0;
    let mut max_repair_share = 0.0f64;
    let mut lent_repair = 0.0f64;
    let mut frontier_rows = Vec::new();
    for shard in &rep.qos {
        let share = shard.observed_share(TrafficClass::Repair);
        max_repair_share = max_repair_share.max(share);
        lent_repair += shard.lent_headroom(TrafficClass::Repair);
        frontier_rows.push((
            shard.device,
            shard.base,
            shard.class_frontier[TrafficClass::Foreground.index()],
            shard.class_frontier[TrafficClass::Repair.index()],
            share,
        ));
    }
    CycleOutcome {
        c,
        repair_objs,
        fg_objs: fg_payloads,
        failed_dev,
        bytes_rebuilt,
        fg_latencies,
        fg_makespan,
        fg_p50,
        repair_completion,
        max_repair_share,
        lent_repair,
        io_calls: rep.io_calls,
        ios: rep.ios,
        frontier_rows,
    }
}

/// Byte oracle: every repair object and checkpoint reads back exactly
/// what was written.
fn assert_bytes(out: &mut CycleOutcome, engine: &str) {
    assert!(
        !out.c.store.cluster.devices[out.failed_dev].failed,
        "{engine}: repaired device returned to service"
    );
    let objs: Vec<(ObjectId, Vec<u8>)> = out
        .repair_objs
        .iter()
        .chain(out.fg_objs.iter())
        .cloned()
        .collect();
    for (o, want) in objs {
        let got = out.c.read_object(&o, 0, want.len() as u64).unwrap();
        assert_eq!(got, want, "{engine}: bytes intact");
    }
}

fn main() {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let (n_obj, n_fg) = if quick { (6, 4) } else { (12, 8) };
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };
    let split = QosConfig::default();

    // ---- virtual time: unthrottled vs default split -------------------
    let mut fifo = run_cycle(QosConfig::unlimited(), n_obj, n_fg);
    let mut qos = run_cycle(split, n_obj, n_fg);
    assert_bytes(&mut fifo, "unthrottled");
    assert_bytes(&mut qos, "split");
    assert_eq!(
        fifo.bytes_rebuilt, qos.bytes_rebuilt,
        "identical rebuild work under both engines"
    );
    assert!(fifo.bytes_rebuilt > 0, "the failed device held units");
    // the acceptance bar: foreground improves under the split while
    // the repair still completes…
    assert!(
        qos.fg_makespan < fifo.fg_makespan,
        "split must improve foreground makespan under concurrent repair \
         ({} vs {})",
        qos.fg_makespan,
        fifo.fg_makespan
    );
    assert!(
        qos.repair_completion.is_finite() && qos.repair_completion > 0.0,
        "repair completes under the cap"
    );
    // …and the cap bounds repair's device-time share on every shard
    assert!(
        qos.max_repair_share <= split.share(TrafficClass::Repair) + 1e-9,
        "repair share {} exceeds the {} cap",
        qos.max_repair_share,
        split.share(TrafficClass::Repair)
    );
    let fg_improvement = fifo.fg_makespan / qos.fg_makespan.max(1e-12);
    let repair_slowdown =
        qos.repair_completion / fifo.repair_completion.max(1e-12);

    // ---- conserving mode: static split vs work-conserving split -------
    let mut cons = run_cycle(QosConfig::conserving(), n_obj, n_fg);
    assert_bytes(&mut cons, "conserving");
    assert_eq!(
        qos.bytes_rebuilt, cons.bytes_rebuilt,
        "borrowing changes WHEN, never WHAT"
    );
    // the never-slower bar, exact: borrowing only ever shortens the
    // capped frontiers (tests/prop_qos_conserving.rs pins this per
    // ticket against the frozen static oracle)
    assert!(
        cons.repair_completion <= qos.repair_completion,
        "conserving repair makespan must never exceed the static split \
         ({} vs {})",
        cons.repair_completion,
        qos.repair_completion
    );
    // …and on this pool the straggler shard really borrows
    assert!(
        cons.repair_completion < qos.repair_completion,
        "idle-headroom shards exist here, so borrowing must show up"
    );
    // foreground completes inside the rebuild window either way, so its
    // p50 rides the identical contended-rate arithmetic: bit-unchanged
    assert_eq!(
        cons.fg_p50.to_bits(),
        qos.fg_p50.to_bits(),
        "conserving must not move foreground p50 ({} vs {})",
        cons.fg_p50,
        qos.fg_p50
    );
    // the borrowed headroom is visible and accounted in the report
    assert!(
        cons.max_repair_share > split.share(TrafficClass::Repair) + 1e-9,
        "borrowing shows up in the observed repair share"
    );
    assert!(cons.max_repair_share <= 1.0 + 1e-9);
    assert!(
        cons.lent_repair > 0.0,
        "the lent headroom is accounted, not hidden"
    );
    assert_eq!(qos.lent_repair, 0.0, "the static split never lends");
    let conserving_speedup =
        qos.repair_completion / cons.repair_completion.max(1e-12);

    let mut t = Table::new(
        &format!(
            "Repair/foreground QoS split (repair of {n_obj} objects + \
             {n_fg} checkpoint writes, {K}+{P}, skewed pool)"
        ),
        &["engine", "fg p50", "fg makespan", "repair completion"],
    );
    t.row(vec![
        "unthrottled".into(),
        sage::metrics::fmt_secs(fifo.fg_p50),
        sage::metrics::fmt_secs(fifo.fg_makespan),
        sage::metrics::fmt_secs(fifo.repair_completion),
    ]);
    t.row(vec![
        format!("split (repair {:.2})", split.share(TrafficClass::Repair)),
        sage::metrics::fmt_secs(qos.fg_p50),
        sage::metrics::fmt_secs(qos.fg_makespan),
        sage::metrics::fmt_secs(qos.repair_completion),
    ]);
    t.row(vec![
        "conserving".into(),
        sage::metrics::fmt_secs(cons.fg_p50),
        sage::metrics::fmt_secs(cons.fg_makespan),
        sage::metrics::fmt_secs(cons.repair_completion),
    ]);
    t.row(vec![
        "fg improvement".into(),
        format!(
            "{:.2}x",
            fifo.fg_p50 / qos.fg_p50.max(1e-12)
        ),
        format!("{fg_improvement:.2}x"),
        format!("{repair_slowdown:.2}x repair"),
    ]);
    print!("{}", t.render());
    println!(
        "conserving repair speedup {conserving_speedup:.2}x vs static \
         split; lent headroom {:.3}s; max repair share {:.3}\n",
        cons.lent_repair, cons.max_repair_share
    );

    // ---- the per-class frontier table (split run) ---------------------
    let mut t = Table::new(
        "Per-class frontiers (split run; OPERATIONS.md explains the read)",
        &["device", "base", "fg frontier", "repair frontier", "repair share"],
    );
    for &(d, base, fgf, rf, share) in &qos.frontier_rows {
        t.row(vec![
            format!("dev{d}"),
            sage::metrics::fmt_secs(base),
            sage::metrics::fmt_secs(fgf),
            sage::metrics::fmt_secs(rf),
            if share > 0.0 { format!("{share:.3}") } else { "-".into() },
        ]);
    }
    print!("{}", t.render());
    println!(
        "max repair share {:.3} <= cap {:.2}; fg latencies (split): {:?}\n",
        qos.max_repair_share,
        split.share(TrafficClass::Repair),
        qos.fg_latencies.iter().map(|t| (t * 1e3).round() / 1e3).collect::<Vec<_>>()
    );

    // ---- wall-clock cycle ---------------------------------------------
    let m_fifo = Bencher::new("qos_unthrottled")
        .iters(warm, iters)
        .wall(|| run_cycle(QosConfig::unlimited(), n_obj, n_fg).fg_makespan);
    let m_split = Bencher::new("qos_default_split")
        .iters(warm, iters)
        .wall(|| run_cycle(split, n_obj, n_fg).fg_makespan);
    let m_cons = Bencher::new("qos_conserving")
        .iters(warm, iters)
        .wall(|| run_cycle(QosConfig::conserving(), n_obj, n_fg).fg_makespan);

    let mut t = Table::new(
        "Wall-clock mixed repair+checkpoint cycle (build + run)",
        &["engine", "cycle", "ratio"],
    );
    t.row(vec![
        "unthrottled".into(),
        sage::metrics::fmt_secs(m_fifo.median),
        "1.00x".into(),
    ]);
    t.row(vec![
        "split".into(),
        sage::metrics::fmt_secs(m_split.median),
        format!("{:.2}x", m_fifo.median / m_split.median.max(1e-12)),
    ]);
    t.row(vec![
        "conserving".into(),
        sage::metrics::fmt_secs(m_cons.median),
        format!("{:.2}x", m_fifo.median / m_cons.median.max(1e-12)),
    ]);
    print!("{}", t.render());

    record("ablate_qos", &[
        ("k", K as f64),
        ("p", P as f64),
        ("n_repair_objects", n_obj as f64),
        ("n_fg_writes", n_fg as f64),
        ("iters", iters as f64),
        ("repair_share_cap", split.share(TrafficClass::Repair)),
        ("migration_share_cap", split.share(TrafficClass::Migration)),
        ("bytes_rebuilt", qos.bytes_rebuilt as f64),
        ("fg_p50_unthrottled_s", fifo.fg_p50),
        ("fg_p50_split_s", qos.fg_p50),
        ("fg_makespan_unthrottled_s", fifo.fg_makespan),
        ("fg_makespan_split_s", qos.fg_makespan),
        ("fg_improvement", fg_improvement),
        ("repair_virtual_unthrottled_s", fifo.repair_completion),
        ("repair_virtual_split_s", qos.repair_completion),
        ("repair_slowdown", repair_slowdown),
        ("max_repair_share_observed", qos.max_repair_share),
        ("fg_p50_conserving_s", cons.fg_p50),
        ("fg_makespan_conserving_s", cons.fg_makespan),
        ("repair_virtual_conserving_s", cons.repair_completion),
        ("conserving_repair_speedup", conserving_speedup),
        ("max_repair_share_conserving", cons.max_repair_share),
        ("lent_headroom_repair_s", cons.lent_repair),
        ("session_io_calls", qos.io_calls as f64),
        ("session_unit_ios", qos.ios as f64),
        ("unthrottled_cycle_s", m_fifo.median),
        ("unthrottled_mad_s", m_fifo.mad),
        ("split_cycle_s", m_split.median),
        ("split_mad_s", m_split.mad),
        ("conserving_cycle_s", m_cons.median),
        ("conserving_mad_s", m_cons.mad),
    ]);
}
