//! Ablation: sharded per-device op scheduling vs the preserved
//! serial-fold oracle (`sage::mero::sns_serial`) on a SKEWED 4+2 pool —
//! one SMR-class (tier-4) straggler admitted to the flash pool next to
//! seven healthy SSDs, the geometry from ISSUE 2.
//!
//! Three measurements:
//! * **virtual time** — completion of a batched full-stripe write +
//!   read cycle under the serial fold (op i+1 waits for op i, one
//!   `io()` per unit) vs the sharded scheduler (one dispatch pass to
//!   per-device shards, completion = max over frontiers). The sharded
//!   engine must complete no later on every geometry (also enforced by
//!   `tests/prop_sched.rs`).
//! * **slow-device isolation** — per-device completion frontiers of
//!   the sharded batch: the straggler's shard finishes late, the flash
//!   shards do not wait for it.
//! * **wall clock** — cycle throughput of the two engines (the sharded
//!   path also batches device accounting into device-contiguous runs),
//!   median ± MAD via the in-tree `Bencher`.
//!
//! Run: `cargo bench --bench ablate_sched`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench ablate_sched`
//! Rows append to `bench_results/ablate_sched.json`
//! (`virtual_speedup`, `wall_speedup` = serial / sharded; both >= 1.0
//! is the acceptance bar). Byte-equivalence of the engines is asserted
//! in-bench and property-tested in `tests/prop_sched.rs`.

use sage::bench::{record, Bencher};
use sage::cluster::{Cluster, EnclosureCompute};
use sage::mero::{sns_serial, Layout, MeroStore};
use sage::metrics::Table;
use sage::sim::device::{DeviceKind, DeviceProfile};
use sage::sim::network::NetworkModel;
use sage::sim::rng::SimRng;
use sage::sim::sched::IoScheduler;

const UNIT: u64 = 65536;
const K: u32 = 4;
const P: u32 = 2;

fn layout() -> Layout {
    Layout::Raid { data: K, parity: P, unit: UNIT, tier: DeviceKind::Ssd }
}

/// The skewed 4+2 pool: seven healthy SSDs plus ONE SMR-class
/// straggler (tier-4 bandwidth/latency/seek profile) admitted to the
/// flash pool, so some stripes of every large batch land on it.
fn skewed_cluster() -> Cluster {
    let mut profiles: Vec<DeviceProfile> =
        (0..7).map(|_| DeviceProfile::ssd(2 << 40)).collect();
    let mut straggler = DeviceProfile::smr(2 << 40);
    straggler.kind = DeviceKind::Ssd; // pooled with the flash devices
    profiles.push(straggler);
    let mut c = Cluster::new(NetworkModel::fdr_infiniband());
    for chunk in profiles.chunks(4) {
        c.add_node(
            chunk.to_vec(),
            EnclosureCompute { cores: 16, flops: 5e10 },
        );
    }
    c
}

/// Index of the straggler device in [`skewed_cluster`] (the one SSD
/// whose profile carries SMR write bandwidth).
fn straggler_dev(c: &Cluster) -> usize {
    (0..c.devices.len())
        .find(|&d| c.devices[d].profile.write_bw < 100e6)
        .expect("straggler present")
}

/// Serial-fold cycle: batched write then batched read, one chained
/// timeline (the de-sharded oracle). Returns (bytes read, completion).
fn serial_cycle(data: &[u8], n_extents: usize) -> (Vec<u8>, f64) {
    let stripe = (K as u64) * UNIT;
    let mut s = MeroStore::new(skewed_cluster());
    let id = s.create_object(4096, layout()).unwrap();
    let w_exts: Vec<(u64, &[u8])> = (0..n_extents)
        .map(|i| {
            let off = i as u64 * stripe;
            (off, &data[off as usize..(off + stripe) as usize])
        })
        .collect();
    let t_w = sns_serial::writev(&mut s, id, &w_exts, 0.0, None).unwrap();
    let r_exts: Vec<(u64, u64)> =
        (0..n_extents).map(|i| (i as u64 * stripe, stripe)).collect();
    let (bufs, t_r) = sns_serial::readv(&mut s, id, &r_exts, t_w).unwrap();
    (bufs.concat(), t_r)
}

/// Sharded cycle: the same batch dispatched through per-device shards
/// (one scheduler per op group). Returns (bytes read, completion,
/// accounting calls, logical I/Os).
fn sharded_cycle(data: &[u8], n_extents: usize) -> (Vec<u8>, f64, u64, u64) {
    let stripe = (K as u64) * UNIT;
    let mut s = MeroStore::new(skewed_cluster());
    let id = s.create_object(4096, layout()).unwrap();
    let mut wsched = IoScheduler::new();
    let mut t_w = 0.0f64;
    for i in 0..n_extents {
        let off = i as u64 * stripe;
        let t = s
            .write_object_with(
                id,
                off,
                &data[off as usize..(off + stripe) as usize],
                0.0,
                None,
                &mut wsched,
            )
            .unwrap();
        t_w = t_w.max(t);
    }
    t_w = t_w.max(wsched.wait_all());
    let mut rsched = IoScheduler::new();
    let mut back = vec![0u8; n_extents * stripe as usize];
    let t_r = s
        .read_object_into_with(id, 0, &mut back, t_w, &mut rsched)
        .unwrap();
    (
        back,
        t_r,
        wsched.io_calls() + rsched.io_calls(),
        wsched.ios() + rsched.ios(),
    )
}

fn main() {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let n_extents = if quick { 8 } else { 32 };
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };
    let stripe = (K as u64) * UNIT;
    let total = n_extents as u64 * stripe;

    let mut rng = SimRng::new(7);
    let mut data = vec![0u8; total as usize];
    rng.fill_bytes(&mut data);

    // ---- virtual-time completion: serial fold vs sharded ---------------
    let (serial_bytes, t_serial) = serial_cycle(&data, n_extents);
    let (sharded_bytes, t_sharded, io_calls, ios) =
        sharded_cycle(&data, n_extents);
    assert_eq!(serial_bytes, data, "serial oracle must round-trip");
    assert_eq!(sharded_bytes, data, "sharded engine must round-trip");
    assert!(
        t_sharded <= t_serial * (1.0 + 1e-9),
        "sharded completion must not exceed the serial fold \
         ({t_sharded} vs {t_serial})"
    );
    let virtual_speedup = t_serial / t_sharded.max(1e-12);

    let mut t = Table::new(
        &format!(
            "Sharded vs serial-fold op execution \
             ({n_extents} full stripes, {K}+{P}, skewed pool)"
        ),
        &["engine", "virtual completion", "io() calls", "unit I/Os"],
    );
    // serial: one io() per unit — (k+p) writes + k reads per stripe
    let serial_ios = (n_extents as u64) * (2 * K + P) as u64;
    t.row(vec![
        "serial fold".into(),
        sage::metrics::fmt_secs(t_serial),
        serial_ios.to_string(),
        serial_ios.to_string(),
    ]);
    t.row(vec![
        "sharded".into(),
        sage::metrics::fmt_secs(t_sharded),
        io_calls.to_string(),
        ios.to_string(),
    ]);
    t.row(vec![
        "speedup".into(),
        format!("{virtual_speedup:.2}x"),
        "".into(),
        "".into(),
    ]);
    print!("{}", t.render());

    // ---- slow-device isolation: per-shard completion frontiers ---------
    let mut s = MeroStore::new(skewed_cluster());
    let straggler = straggler_dev(&s.cluster);
    let id = s.create_object(4096, layout()).unwrap();
    let mut sched = IoScheduler::new();
    for i in 0..n_extents {
        let off = i as u64 * stripe;
        s.write_object_with(
            id,
            off,
            &data[off as usize..(off + stripe) as usize],
            0.0,
            None,
            &mut sched,
        )
        .unwrap();
    }
    let mut t = Table::new(
        "Per-device completion frontiers (sharded write batch)",
        &["device", "profile", "frontier"],
    );
    let mut fast_max = 0.0f64;
    for d in 0..s.cluster.devices.len() {
        let f = sched.frontier(d);
        if d != straggler {
            fast_max = fast_max.max(f);
        }
        t.row(vec![
            format!("dev{d}"),
            if d == straggler { "SMR straggler".into() } else { "SSD".into() },
            sage::metrics::fmt_secs(f),
        ]);
    }
    print!("{}", t.render());
    let isolation = sched.frontier(straggler) / fast_max.max(1e-12);
    println!(
        "straggler frontier / fastest-shard frontier = {isolation:.1}x \
         (healthy shards do not wait for the straggler)\n"
    );

    // ---- wall-clock cycle throughput ----------------------------------
    let m_serial = Bencher::new("sched_serial_fold")
        .iters(warm, iters)
        .wall(|| serial_cycle(&data, n_extents).1);
    let m_sharded = Bencher::new("sched_sharded")
        .iters(warm, iters)
        .wall(|| sharded_cycle(&data, n_extents).1);
    let wall_speedup = m_serial.median / m_sharded.median.max(1e-12);
    let cycle_bytes = (2 * total) as f64;

    let mut t = Table::new(
        &format!("Wall-clock cycle ({} MiB write + read)", total >> 20),
        &["engine", "cycle", "throughput", "speedup"],
    );
    t.row(vec![
        "serial fold".into(),
        sage::metrics::fmt_secs(m_serial.median),
        sage::util::bytes::fmt_bw(cycle_bytes / m_serial.median.max(1e-12)),
        "1.00x".into(),
    ]);
    t.row(vec![
        "sharded".into(),
        sage::metrics::fmt_secs(m_sharded.median),
        sage::util::bytes::fmt_bw(cycle_bytes / m_sharded.median.max(1e-12)),
        format!("{wall_speedup:.2}x"),
    ]);
    print!("{}", t.render());

    record("ablate_sched", &[
        ("k", K as f64),
        ("p", P as f64),
        ("n_extents", n_extents as f64),
        ("iters", iters as f64),
        ("serial_virtual_s", t_serial),
        ("sharded_virtual_s", t_sharded),
        ("virtual_speedup", virtual_speedup),
        ("straggler_isolation", isolation),
        ("serial_cycle_s", m_serial.median),
        ("serial_mad_s", m_serial.mad),
        ("sharded_cycle_s", m_sharded.median),
        ("sharded_mad_s", m_sharded.mad),
        ("serial_bw_bytes_s", cycle_bytes / m_serial.median.max(1e-12)),
        ("sharded_bw_bytes_s", cycle_bytes / m_sharded.median.max(1e-12)),
        ("wall_speedup", wall_speedup),
        ("sharded_io_calls", io_calls as f64),
        ("sharded_unit_ios", ios as f64),
    ]);
}
