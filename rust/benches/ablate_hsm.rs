//! Ablation (Tbl B): HSM tiering policies under a zipfian heat trace —
//! heat-weighted (SAGE) vs FIFO vs static placement. Reports mean
//! access latency (virtual time), migration traffic, and the
//! wall-clock policy-cycle cost (median ± MAD via the in-tree
//! `Bencher`).
//!
//! Migrations execute through the scheduler-driven recovery plane:
//! each HSM cycle's plan runs as ONE batched op group on a sharded
//! per-device scheduler (`Client::migrate_with`), which also publishes
//! the `ObjectMigrated` FDMI feed the heat map consumes.
//!
//! Run: `cargo bench --bench ablate_hsm`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench ablate_hsm`
//! Rows append to `bench_results/ablate_hsm.json`.

use sage::bench::{record, Bencher};
use sage::clovis::Client;
use sage::config::Testbed;
use sage::hsm::{Hsm, TieringPolicy};
use sage::metrics::Table;
use sage::sim::rng::SimRng;

/// One policy evaluation: skewed reads over a population, periodic HSM
/// cycles batched through the recovery plane. Returns (mean read
/// latency, migrations, bytes moved).
fn run_policy(
    policy: TieringPolicy,
    n_objects: usize,
    rounds: u32,
) -> (f64, u64, u64) {
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut hsm = Hsm::new(policy);
    hsm.half_life = 20.0;
    let mut rng = SimRng::new(7);

    let payload: Vec<u8> = vec![42u8; 4 * 65536];
    let objs: Vec<_> = (0..n_objects)
        .map(|_| {
            let o = c.create_object(4096).unwrap();
            c.write_object(&o, 0, &payload).unwrap();
            o
        })
        .collect();
    let _ = c.fdmi.drain();

    let mut read_time = 0.0;
    let mut reads = 0u32;
    for round in 0..rounds {
        let pick = rng.gen_zipf(objs.len() as u64, 0.85) as usize;
        let before = c.now;
        c.read_object(&objs[pick], 0, 65536).unwrap();
        read_time += c.now - before;
        reads += 1;
        if round % 100 == 99 {
            let recs = c.fdmi.drain();
            hsm.observe(&recs, &c.store);
            let plan = hsm.plan(c.now);
            // one batched op group per HSM cycle (recovery plane)
            c.migrate_with(&mut hsm, &plan).ok();
        }
    }
    (read_time / reads as f64, hsm.migrations_run, hsm.bytes_moved)
}

fn main() {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let (n_objects, rounds) = if quick { (12, 200) } else { (30, 600) };
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };

    let mut t = Table::new(
        &format!(
            "Tbl B: HSM policy ablation (zipf 0.85 reads, \
             {n_objects} objects, {rounds} rounds)"
        ),
        &["policy", "mean read", "migrations", "bytes moved", "cycle (wall)"],
    );
    for (idx, (name, policy)) in [
        ("heat-weighted", TieringPolicy::HeatWeighted),
        ("fifo", TieringPolicy::Fifo),
        ("static", TieringPolicy::Static),
    ]
    .into_iter()
    .enumerate()
    {
        let (lat, migs, bytes) = run_policy(policy, n_objects, rounds);
        let m = Bencher::new(&format!("hsm_{name}"))
            .iters(warm, iters)
            .wall(|| run_policy(policy, n_objects, rounds).0);
        t.row(vec![
            name.into(),
            sage::metrics::fmt_secs(lat),
            migs.to_string(),
            sage::util::bytes::fmt_size(bytes),
            format!(
                "{} ± {}",
                sage::metrics::fmt_secs(m.median),
                sage::metrics::fmt_secs(m.mad)
            ),
        ]);
        record("ablate_hsm", &[
            ("policy", idx as f64),
            ("n_objects", n_objects as f64),
            ("rounds", rounds as f64),
            ("iters", iters as f64),
            ("mean_read_s", lat),
            ("migrations", migs as f64),
            ("bytes_moved", bytes as f64),
            ("cycle_s", m.median),
            ("cycle_mad_s", m.mad),
        ]);
    }
    print!("{}", t.render());
    println!(
        "expected: heat-weighted promotes the hot set (lowest latency); \
         static never moves; fifo demotes one first-in resident per \
         tier per cycle"
    );
}
