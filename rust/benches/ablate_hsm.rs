//! Ablation (Tbl B): HSM tiering policies under a zipfian heat trace —
//! heat-weighted (SAGE) vs FIFO vs static placement. Reports mean
//! access latency (virtual time) and migration traffic.
//!
//! Run: `cargo bench --bench ablate_hsm`

use sage::bench::record;
use sage::clovis::Client;
use sage::config::Testbed;
use sage::hsm::{Hsm, TieringPolicy};
use sage::metrics::Table;
use sage::sim::rng::SimRng;

/// One policy evaluation: skewed reads over a population, periodic HSM
/// cycles, report (mean read latency, migrations, bytes moved).
fn run_policy(policy: TieringPolicy) -> (f64, u64, u64) {
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut hsm = Hsm::new(policy);
    hsm.half_life = 20.0;
    let mut rng = SimRng::new(7);

    let payload: Vec<u8> = vec![42u8; 4 * 65536];
    let objs: Vec<_> = (0..30)
        .map(|_| {
            let o = c.create_object(4096).unwrap();
            c.write_object(&o, 0, &payload).unwrap();
            o
        })
        .collect();
    let _ = c.fdmi.drain();

    let mut read_time = 0.0;
    let mut reads = 0u32;
    for round in 0..600 {
        let pick = rng.gen_zipf(objs.len() as u64, 0.85) as usize;
        let before = c.now;
        c.read_object(&objs[pick], 0, 65536).unwrap();
        read_time += c.now - before;
        reads += 1;
        if round % 100 == 99 {
            let recs = c.fdmi.drain();
            hsm.observe(&recs, &c.store);
            let plan = hsm.plan(c.now);
            hsm.migrate(&mut c.store, &plan, c.now).ok();
        }
    }
    (read_time / reads as f64, hsm.migrations_run, hsm.bytes_moved)
}

fn main() {
    let mut t = Table::new(
        "Tbl B: HSM policy ablation (zipf 0.85 reads, 30 objects)",
        &["policy", "mean read", "migrations", "bytes moved"],
    );
    for (name, policy) in [
        ("heat-weighted", TieringPolicy::HeatWeighted),
        ("fifo", TieringPolicy::Fifo),
        ("static", TieringPolicy::Static),
    ] {
        let (lat, migs, bytes) = run_policy(policy);
        t.row(vec![
            name.into(),
            sage::metrics::fmt_secs(lat),
            migs.to_string(),
            sage::util::bytes::fmt_size(bytes),
        ]);
        record("ablate_hsm", &[("mean_read_s", lat), ("migrations", migs as f64)]);
    }
    print!("{}", t.render());
    println!(
        "expected: heat-weighted promotes the hot set (lowest latency); \
         static never moves; fifo moves more for less gain"
    );
}
