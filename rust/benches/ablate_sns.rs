//! Ablation (Tbl A): the SNS parity path — AOT Pallas kernel via PJRT
//! vs the CPU XOR fallback, across stripe geometries; plus end-to-end
//! write-path wall-clock (the L3 hot path the perf pass optimizes) and
//! the §Perf before/after: the zero-copy batched engine vs the
//! preserved pre-change baseline (`sns_baseline`), measured on a
//! >= 64 MiB full-stripe write + read cycle.
//!
//! Run: `make artifacts && cargo bench --bench ablate_sns`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench ablate_sns`
//! (reduced object size + iteration counts).
//!
//! Results append to `bench_results/*.json` (one JSON object per line).

use sage::bench::{record, Bencher};
use sage::config::Testbed;
use sage::mero::{sns, sns_baseline, Layout, MeroStore};
use sage::metrics::Table;
use sage::runtime::Executor;
use sage::sim::device::DeviceKind;
use sage::sim::rng::SimRng;

fn main() {
    let exec = Executor::load_default().ok();
    if exec.is_none() {
        println!("(artifacts missing: kernel rows will be skipped)");
    }

    // -------- parity kernel vs CPU fallback, by geometry ---------------
    let mut t = Table::new(
        "Tbl A: parity computation wall-clock (64 KiB units)",
        &["k", "cpu xor", "pallas/pjrt", "kernel==cpu"],
    );
    let mut rng = SimRng::new(42);
    for k in [4usize, 8] {
        let units: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let mut v = vec![0u8; 65536];
                rng.fill_bytes(&mut v);
                v
            })
            .collect();
        let m_cpu = Bencher::new(&format!("cpu_parity_k{k}"))
            .iters(3, 30)
            .wall(|| sns::cpu_parity(&units));
        let (kernel_str, matches) = match &exec {
            Some(e) => {
                let m_k = Bencher::new(&format!("pjrt_parity_k{k}"))
                    .iters(3, 30)
                    .wall(|| e.parity(&units).unwrap());
                let same = e.parity(&units).unwrap().unwrap()
                    == sns::cpu_parity(&units);
                record("ablate_sns", &[
                    ("k", k as f64),
                    ("cpu_s", m_cpu.median),
                    ("pjrt_s", m_k.median),
                ]);
                (sage::metrics::fmt_secs(m_k.median), same.to_string())
            }
            None => ("n/a".into(), "n/a".into()),
        };
        t.row(vec![
            k.to_string(),
            sage::metrics::fmt_secs(m_cpu.median),
            kernel_str,
            matches,
        ]);
    }
    print!("{}", t.render());

    // -------- end-to-end SNS write path (wall-clock hot path) ----------
    let mut t = Table::new(
        "SNS write path wall-clock (1 MiB object writes)",
        &["geometry", "time/write", "throughput"],
    );
    for (k, p) in [(4u32, 1u32), (8, 1), (4, 0)] {
        let data = {
            let mut v = vec![0u8; 1 << 20];
            rng.fill_bytes(&mut v);
            v
        };
        let m = Bencher::new(&format!("sns_write_{k}+{p}"))
            .iters(2, 10)
            .wall(|| {
                let mut s =
                    MeroStore::new(Testbed::sage_prototype().build_cluster());
                let id = s
                    .create_object(
                        4096,
                        Layout::Raid {
                            data: k,
                            parity: p,
                            unit: 65536,
                            tier: DeviceKind::Ssd,
                        },
                    )
                    .unwrap();
                s.write_object(id, 0, &data, 0.0, exec.as_ref()).unwrap()
            });
        t.row(vec![
            format!("{k}+{p}"),
            sage::metrics::fmt_secs(m.median),
            format!("{}", m.throughput(1 << 20).split_whitespace().last().unwrap_or("")),
        ]);
        record("ablate_sns_write", &[
            ("k", k as f64),
            ("p", p as f64),
            ("wall_s", m.median),
        ]);
    }
    print!("{}", t.render());

    // -------- degraded-read / repair virtual-time costs -----------------
    let mut t = Table::new(
        "SNS resilience costs (virtual time)",
        &["operation", "time"],
    );
    let mut s = MeroStore::new(Testbed::sage_prototype().build_cluster());
    let id = s.create_object(4096, Layout::default()).unwrap();
    let mut data = vec![0u8; 8 * 65536];
    rng.fill_bytes(&mut data);
    s.write_object(id, 0, &data, 0.0, None).unwrap();
    let (_, t_healthy) = s.read_object(id, 0, data.len() as u64, 100.0).unwrap();
    let dev = s.object(id).unwrap().placement(0, 1).unwrap().device;
    s.cluster.fail_device(dev);
    let (_, t_degraded) = s.read_object(id, 0, data.len() as u64, 200.0).unwrap();
    let (_, t_repair) = sns::repair(&mut s, &[id], dev, 300.0).unwrap();
    t.row(vec!["healthy read".into(), sage::metrics::fmt_secs(t_healthy - 100.0)]);
    t.row(vec!["degraded read".into(), sage::metrics::fmt_secs(t_degraded - 200.0)]);
    t.row(vec!["device repair".into(), sage::metrics::fmt_secs(t_repair - 300.0)]);
    print!("{}", t.render());

    hotpath(&mut rng);
}

/// §Perf before/after: the zero-copy batched engine (`sns` +
/// `write_object_owned`/`read_object_into`) against the preserved
/// pre-change engine (`sns_baseline`), on a full-stripe write + read
/// cycle of one large object. Both engines do the same logical work:
/// stripe, compute+store parity, persist blocks with per-block CRC32,
/// read everything back. `SAGE_BENCH_QUICK=1` shrinks the object and
/// iteration counts for CI smoke runs.
fn hotpath(rng: &mut SimRng) {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let mib: u64 = if quick { 16 } else { 64 };
    let total = mib << 20;
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };
    let mut data = vec![0u8; total as usize];
    rng.fill_bytes(&mut data);

    let mut t = Table::new(
        &format!("§Perf hot path: {mib} MiB full-stripe write + read cycle"),
        &["geometry", "engine", "cycle", "throughput", "speedup"],
    );
    for (k, p) in [(4u32, 1u32), (4, 2)] {
        let layout = Layout::Raid {
            data: k,
            parity: p,
            unit: 65536,
            tier: DeviceKind::Ssd,
        };

        // --- baseline: pre-change engine (per-block allocs + clones) ---
        let l = layout.clone();
        let m_base = Bencher::new(&format!("hotpath_baseline_{k}+{p}"))
            .iters(warm, iters)
            .wall(|| {
                let mut s =
                    MeroStore::new(Testbed::sage_prototype().build_cluster());
                let id = s.create_object(4096, l.clone()).unwrap();
                sns_baseline::write(&mut s, id, 0, &data, 0.0, None).unwrap();
                let (back, _) =
                    sns_baseline::read(&mut s, id, 0, total, 1.0).unwrap();
                back.len()
            });

        // --- zero-copy engine: persist-by-move + read into reused buf ---
        let l = layout.clone();
        let mut back = vec![0u8; total as usize];
        let m_opt = Bencher::new(&format!("hotpath_zero_copy_{k}+{p}"))
            .iters(warm, iters)
            .wall(|| {
                let mut s =
                    MeroStore::new(Testbed::sage_prototype().build_cluster());
                let id = s.create_object(4096, l.clone()).unwrap();
                // producing the owned buffer is part of the measured cycle
                let owned = data.clone();
                s.write_object_owned(id, 0, owned, 0.0, None).unwrap();
                s.read_object_into(id, 0, &mut back, 1.0).unwrap();
                back.len()
            });
        assert_eq!(back, data, "engines must return identical bytes");

        let speedup = m_base.median / m_opt.median.max(1e-12);
        let cycle_bytes = (2 * total) as f64; // one write + one read pass
        t.row(vec![
            format!("{k}+{p}"),
            "baseline".into(),
            sage::metrics::fmt_secs(m_base.median),
            sage::util::bytes::fmt_bw(cycle_bytes / m_base.median.max(1e-12)),
            "1.00x".into(),
        ]);
        t.row(vec![
            format!("{k}+{p}"),
            "zero-copy".into(),
            sage::metrics::fmt_secs(m_opt.median),
            sage::util::bytes::fmt_bw(cycle_bytes / m_opt.median.max(1e-12)),
            format!("{speedup:.2}x"),
        ]);
        record("ablate_sns_hotpath", &[
            ("mib", mib as f64),
            ("k", k as f64),
            ("p", p as f64),
            ("iters", iters as f64),
            ("baseline_cycle_s", m_base.median),
            ("baseline_mad_s", m_base.mad),
            ("zero_copy_cycle_s", m_opt.median),
            ("zero_copy_mad_s", m_opt.mad),
            ("baseline_bw_bytes_s", cycle_bytes / m_base.median.max(1e-12)),
            ("zero_copy_bw_bytes_s", cycle_bytes / m_opt.median.max(1e-12)),
            ("speedup", speedup),
        ]);
    }
    print!("{}", t.render());
}
