//! Long-horizon failure-storm soak (ISSUE 6 tentpole): drives
//! `sage::tools::soak` — hours of virtual time of continuous traffic,
//! correlated storms, elastic pool membership — with the durability
//! invariants (no byte lost within pool tolerance, bounded repair
//! backlog, every `RecoveryOutcome` accounted) checked INSIDE the
//! harness, then pins:
//!
//! * **determinism** — the same config run twice yields a bit-identical
//!   [`SoakReport`] (every `f64` compares equal);
//! * **typed beyond-parity loss** — a scripted enclosure-scale storm
//!   (every SSD at once, far past the 4+1 layout's tolerance) surfaces
//!   [`RecoveryVerdict::DataLoss`] naming exactly the striped victims,
//!   never a panic and never silent corruption: reads of the named
//!   objects keep erroring, the other tier's object stays byte-exact.
//!
//! Reported: the soak's verdict ledger and movement totals (virtual),
//! recovery-latency median ± MAD (virtual), and wall-clock soak cycle
//! median ± MAD.
//!
//! Run: `cargo bench --bench soak_storm`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench soak_storm`
//! Rows append to `bench_results/soak_storm.json`
//! (fields documented in `bench_results/README.md`).

use sage::bench::{record, Bencher};
use sage::clovis::{Client, RecoveryVerdict};
use sage::cluster::failure::FailureSchedule;
use sage::config::Testbed;
use sage::mero::Layout;
use sage::metrics::Table;
use sage::sim::device::DeviceKind;
use sage::sim::rng::SimRng;
use sage::tools::soak::{run, SoakConfig};

/// Scripted beyond-tolerance scenario: a whole-tier storm (every SSD
/// within half a virtual second) against one striped SSD object and
/// one HDD object. Returns (data-loss verdicts, outcomes consumed).
fn beyond_parity_storm() -> (u64, u64) {
    let mut c = Client::new_sim(Testbed::sage_prototype());
    let ssd_obj = c.create_object(4096).unwrap(); // default layout: SSD 4+1
    let ssd_data = vec![6u8; 2 * 4 * 65536];
    c.write_object(&ssd_obj, 0, &ssd_data).unwrap();
    let hdd_obj = c
        .create_object_with(
            4096,
            Layout::Raid { data: 4, parity: 1, unit: 65536, tier: DeviceKind::Hdd },
        )
        .unwrap();
    let hdd_data = vec![7u8; 2 * 4 * 65536];
    c.write_object(&hdd_obj, 0, &hdd_data).unwrap();
    let ssds = c
        .store
        .cluster
        .devices_where(|d| d.profile.kind == DeviceKind::Ssd);
    let mut rng = SimRng::new(9);
    let mut feed = FailureSchedule::storm(&ssds, 1.0, 0.5, &mut rng);
    c.now = 2.0;
    let outcomes = c.consume_failure_feed(&mut feed, &[ssd_obj, hdd_obj]);
    assert_eq!(outcomes.len(), ssds.len(), "every storm event consumed");
    let mut losses = 0u64;
    for out in &outcomes {
        assert_ne!(
            out.verdict,
            RecoveryVerdict::Recovered,
            "nothing may pretend to recover past parity tolerance"
        );
        if let RecoveryVerdict::DataLoss { objects } = &out.verdict {
            losses += 1;
            assert!(objects.contains(&ssd_obj), "the striped victim is named");
            assert!(!objects.contains(&hdd_obj), "the other tier is not");
        }
    }
    assert!(losses > 0, "beyond-parity loss is surfaced, typed");
    assert!(
        c.read_object(&ssd_obj, 0, ssd_data.len() as u64).is_err(),
        "lost object reads keep erroring — no silent corruption"
    );
    assert_eq!(
        c.read_object(&hdd_obj, 0, hdd_data.len() as u64).unwrap(),
        hdd_data,
        "the unaffected tier stays byte-exact"
    );
    (losses, outcomes.len() as u64)
}

fn main() {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let cfg = if quick { SoakConfig::quick(42) } else { SoakConfig::full(42) };
    let (warm, iters) = if quick { (0, 2) } else { (1, 5) };

    // ---- the headline soak, twice: the report is a pure function of
    // the config, so the two runs must compare bit-identical
    let a = run(&cfg).expect("soak run");
    let b = run(&cfg).expect("soak rerun");
    assert_eq!(a, b, "same config, bit-identical SoakReport");
    assert!(a.events_consumed > 0, "the feed fired");
    assert!(a.recovered > 0, "repairs ran");
    assert!(a.bytes_rebuilt > 0, "failed devices held data");
    assert!(a.devices_added as usize == cfg.elastic_points, "elastic points fired");

    let mut t = Table::new(
        &format!(
            "Failure-storm soak ({:.1}h virtual, {} objects, {} storms, seed {})",
            cfg.horizon / 3600.0,
            cfg.n_objects,
            cfg.storms,
            cfg.seed
        ),
        &["metric", "value"],
    );
    for (k, v) in [
        ("events consumed", a.events_consumed),
        ("recovered", a.recovered),
        ("transient retried", a.transient_retried),
        ("aborted by re-failure", a.aborted_by_refailure),
        ("escalated to repair", a.escalated_to_repair),
        ("absorbed by escalation", a.absorbed_by_escalation),
        ("data-loss verdicts", a.data_loss_events),
        ("failed recoveries", a.failed_recoveries),
        ("no action", a.no_action),
        ("objects lost (accounted)", a.objects_lost),
        ("devices added", a.devices_added),
        ("drains run", a.drains_run),
        ("writes", a.writes),
        ("max pass outcomes", a.max_pass_outcomes),
    ] {
        t.row(vec![k.into(), v.to_string()]);
    }
    t.row(vec![
        "bytes rebuilt/rebalanced/drained".into(),
        format!(
            "{} / {} / {}",
            sage::util::bytes::fmt_size(a.bytes_rebuilt),
            sage::util::bytes::fmt_size(a.bytes_rebalanced),
            sage::util::bytes::fmt_size(a.bytes_drained)
        ),
    ]);
    t.row(vec![
        "recovery latency p50±MAD".into(),
        format!(
            "{}±{}",
            sage::metrics::fmt_secs(a.recovery_latency_p50),
            sage::metrics::fmt_secs(a.recovery_latency_mad)
        ),
    ]);
    print!("{}", t.render());

    // ---- scripted beyond-parity storm: typed loss, no panic
    let (loss_events, storm_events) = beyond_parity_storm();
    println!(
        "beyond-parity storm: {loss_events} typed data-loss verdicts over \
         {storm_events} events; unaffected tier byte-exact\n"
    );

    // ---- wall-clock: the CI-shaped soak cycle (full soak wall time
    // is dominated by the same code paths; the quick shape keeps the
    // measured loop homogeneous across modes)
    let wall_cfg = SoakConfig::quick(42);
    let m = Bencher::new("soak_quick_cycle")
        .iters(warm, iters)
        .wall(|| run(&wall_cfg).expect("soak wall cycle").events_consumed);

    let mut t = Table::new("Wall-clock soak cycle", &["cycle", "p50", "MAD"]);
    t.row(vec![
        "quick soak".into(),
        sage::metrics::fmt_secs(m.median),
        sage::metrics::fmt_secs(m.mad),
    ]);
    print!("{}", t.render());

    record("soak_storm", &[
        ("horizon_s", cfg.horizon),
        ("n_objects", cfg.n_objects as f64),
        ("storms", cfg.storms as f64),
        ("elastic_points", cfg.elastic_points as f64),
        ("events_consumed", a.events_consumed as f64),
        ("recovered", a.recovered as f64),
        ("transient_retried", a.transient_retried as f64),
        ("aborted_by_refailure", a.aborted_by_refailure as f64),
        ("escalated_to_repair", a.escalated_to_repair as f64),
        ("absorbed_by_escalation", a.absorbed_by_escalation as f64),
        ("data_loss_events", a.data_loss_events as f64),
        ("failed_recoveries", a.failed_recoveries as f64),
        ("no_action", a.no_action as f64),
        ("objects_lost", a.objects_lost as f64),
        ("bytes_rebuilt", a.bytes_rebuilt as f64),
        ("bytes_rebalanced", a.bytes_rebalanced as f64),
        ("bytes_drained", a.bytes_drained as f64),
        ("bytes_written", a.bytes_written as f64),
        ("writes", a.writes as f64),
        ("writes_skipped", a.writes_skipped as f64),
        ("devices_added", a.devices_added as f64),
        ("drains_run", a.drains_run as f64),
        ("repairs_started", a.repairs_started as f64),
        ("repairs_aborted", a.repairs_aborted as f64),
        ("max_pass_outcomes", a.max_pass_outcomes as f64),
        ("recovery_latency_p50_s", a.recovery_latency_p50),
        ("recovery_latency_mad_s", a.recovery_latency_mad),
        ("beyond_parity_loss_events", loss_events as f64),
        ("soak_cycle_s", m.median),
        ("soak_cycle_mad_s", m.mad),
    ]);
}
