//! Bench: Figure 7 — iPIC3D particle visualization I/O at scale,
//! MPI collective I/O vs MPI streams (1 consumer / 15 producers),
//! 100 time steps, 64..8192 processes on the Beskow model.
//!
//! Run: `cargo bench --bench fig7_streams`

use sage::apps::ipic3d;
use sage::bench::record;
use sage::config::Testbed;
use sage::metrics::Table;

fn main() {
    let tb = Testbed::beskow();
    let steps = 100;
    let mut t = Table::new(
        "Fig 7: iPIC3D with collective I/O vs MPI streams (100 steps)",
        &["procs", "collective(s)", "streams(s)", "improvement"],
    );
    let mut p = 64;
    while p <= 8192 {
        let pt = ipic3d::run_scaling(&tb, p, steps);
        t.row(vec![
            p.to_string(),
            format!("{:.1}", pt.t_collective),
            format!("{:.1}", pt.t_streams),
            format!("{:.2}x", pt.improvement),
        ]);
        record("fig7", &[
            ("procs", p as f64),
            ("collective_s", pt.t_collective),
            ("streams_s", pt.t_streams),
            ("improvement", pt.improvement),
        ]);
        p *= 2;
    }
    print!("{}", t.render());
    println!(
        "paper: comparable at small scale; steady improvement from 256 \
         procs reaching 3.6x at 8,192"
    );
}
