//! Bench: Figure 5 — HACC-IO checkpoint/restart strong scaling,
//! MPI-IO vs MPI storage windows, 100M particles, Blackdog and Tegner.
//!
//! Run: `cargo bench --bench fig5_hacc`

use sage::apps::hacc::{self, HaccImpl};
use sage::bench::record;
use sage::config::Testbed;
use sage::metrics::Table;
use sage::pgas::StorageTarget;

const PARTICLES: u64 = 100_000_000;

fn main() {
    // ---------------- Blackdog (workstation) --------------------------
    let bd = Testbed::blackdog();
    let mut t = Table::new(
        "Fig 5 HACC-IO Blackdog: checkpoint+restart (s), 100M particles",
        &["procs", "mpi-io", "windows(hdd)", "win/mpiio"],
    );
    for procs in [1usize, 2, 4, 8] {
        let t_io = hacc::run(&bd, HaccImpl::MpiIo, procs, PARTICLES).unwrap();
        let t_win = hacc::run(
            &bd,
            HaccImpl::StorageWindows(StorageTarget::Hdd),
            procs,
            PARTICLES,
        )
        .unwrap();
        t.row(vec![
            procs.to_string(),
            format!("{t_io:.1}"),
            format!("{t_win:.1}"),
            format!("{:.2}", t_win / t_io),
        ]);
        record("fig5_blackdog", &[
            ("procs", procs as f64),
            ("mpiio_s", t_io),
            ("windows_s", t_win),
        ]);
    }
    print!("{}", t.render());
    println!("paper: similar on Blackdog, MPI-IO slightly ahead (~4%)\n");

    // ---------------- Tegner (cluster + Lustre) ------------------------
    let tegner = Testbed::tegner();
    let mut t = Table::new(
        "Fig 5 HACC-IO Tegner: checkpoint+restart (s), 100M particles",
        &["procs", "mpi-io", "windows(pfs)", "improvement"],
    );
    for procs in [24usize, 48, 96, 144] {
        let t_io = hacc::run(&tegner, HaccImpl::MpiIo, procs, PARTICLES).unwrap();
        let t_win = hacc::run(
            &tegner,
            HaccImpl::StorageWindows(StorageTarget::Pfs),
            procs,
            PARTICLES,
        )
        .unwrap();
        t.row(vec![
            procs.to_string(),
            format!("{t_io:.1}"),
            format!("{t_win:.1}"),
            format!("{:.0}%", (1.0 - t_win / t_io) * 100.0),
        ]);
        record("fig5_tegner", &[
            ("procs", procs as f64),
            ("mpiio_s", t_io),
            ("windows_s", t_win),
        ]);
    }
    print!("{}", t.render());
    println!("paper: ~32% average improvement at higher process counts");
}
