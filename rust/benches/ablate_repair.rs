//! Ablation: scheduler-driven SNS repair vs the serial-fold oracle on
//! a SKEWED 4+2 pool — seven healthy SSDs plus ONE SMR-class (tier-4
//! profile) straggler admitted to the flash pool — with one failed
//! device (the ISSUE 3 recovery-plane geometry).
//!
//! Measurements:
//! * **virtual time** — completion of rebuilding every lost unit:
//!   serial fold (`sns_serial::repair`: survivor reads and the rebuild
//!   write chain unit after unit through direct `io()` calls) vs
//!   sharded (`sns::repair_with`: ONE scheduler, phase A survivor
//!   reads across all objects, phase B rebuild writes at each unit's
//!   reconstruction frontier). `virtual_speedup` = serial / sharded,
//!   must be >= 1 (also property-tested in `tests/prop_repair.rs`).
//! * **per-target frontier** — the completion frontier of every device
//!   shard after the sharded repair: rebuild writes stream onto target
//!   devices while survivor reads of later stripes are in flight, and
//!   the straggler's shard finishes late without dragging the rest.
//! * **wall clock** — repair cycle (store build + fail + rebuild)
//!   median ± MAD via the in-tree `Bencher`.
//!
//! Byte-equivalence is asserted in-bench: both engines rebuild the
//! same byte count and every object reads back its original contents.
//!
//! Run: `cargo bench --bench ablate_repair`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench ablate_repair`
//! Rows append to `bench_results/ablate_repair.json`.

use sage::bench::{record, Bencher};
use sage::cluster::{Cluster, EnclosureCompute};
use sage::mero::{sns, sns_serial, Layout, MeroStore, ObjectId};
use sage::metrics::Table;
use sage::sim::device::{DeviceKind, DeviceProfile};
use sage::sim::network::NetworkModel;
use sage::sim::rng::SimRng;
use sage::sim::sched::IoScheduler;

const UNIT: u64 = 65536;
const K: u32 = 4;
const P: u32 = 2;
const STRIPES_PER_OBJ: u64 = 2;

fn layout() -> Layout {
    Layout::Raid { data: K, parity: P, unit: UNIT, tier: DeviceKind::Ssd }
}

/// The skewed 4+2 pool: seven healthy SSDs plus ONE SMR-class
/// straggler (tier-4 bandwidth/latency/seek profile) admitted to the
/// flash pool, so some survivor reads and rebuild writes land on it.
fn skewed_cluster() -> Cluster {
    let mut profiles: Vec<DeviceProfile> =
        (0..7).map(|_| DeviceProfile::ssd(2 << 40)).collect();
    let mut straggler = DeviceProfile::smr(2 << 40);
    straggler.kind = DeviceKind::Ssd; // pooled with the flash devices
    profiles.push(straggler);
    let mut c = Cluster::new(NetworkModel::fdr_infiniband());
    for chunk in profiles.chunks(4) {
        c.add_node(
            chunk.to_vec(),
            EnclosureCompute { cores: 16, flops: 5e10 },
        );
    }
    c
}

/// Index of the straggler device in [`skewed_cluster`].
fn straggler_dev(c: &Cluster) -> usize {
    (0..c.devices.len())
        .find(|&d| c.devices[d].profile.write_bw < 100e6)
        .expect("straggler present")
}

/// Build a store with `n_objects` striped objects written through the
/// given engine, then fail the device holding object 0's first unit.
/// Both engines allocate in the same order, so the failed device and
/// all placements agree across the serial and sharded stores.
fn seeded_store(
    serial_engine: bool,
    n_objects: usize,
    datas: &[Vec<u8>],
) -> (MeroStore, Vec<ObjectId>, usize) {
    let mut s = MeroStore::new(skewed_cluster());
    let mut objs = Vec::with_capacity(n_objects);
    let mut t = 0.0f64;
    for data in datas.iter().take(n_objects) {
        let id = s.create_object(4096, layout()).unwrap();
        t = if serial_engine {
            sns_serial::write(&mut s, id, 0, data, t, None).unwrap()
        } else {
            s.write_object(id, 0, data, t, None).unwrap()
        };
        objs.push(id);
    }
    let dev = s.object(objs[0]).unwrap().placement(0, 0).unwrap().device;
    s.cluster.fail_device(dev);
    (s, objs, dev)
}

/// One full repair cycle (store build + fail + rebuild) through the
/// chosen engine. The repaired store is returned so the byte oracle
/// can read it back without rebuilding everything.
struct RepairRun {
    bytes: u64,
    t: f64,
    store: MeroStore,
    objs: Vec<ObjectId>,
    dev: usize,
    /// Sharded engine only: per-device frontiers + dispatch stats.
    frontiers: Vec<f64>,
    io_calls: u64,
    ios: u64,
}

fn run_repair(serial: bool, n_objects: usize, datas: &[Vec<u8>]) -> RepairRun {
    let (mut store, objs, dev) = seeded_store(serial, n_objects, datas);
    if serial {
        let (bytes, t) = sns_serial::repair(&mut store, &objs, dev, 0.0).unwrap();
        return RepairRun {
            bytes, t, store, objs, dev,
            frontiers: Vec::new(), io_calls: 0, ios: 0,
        };
    }
    let mut sched = IoScheduler::new();
    let (bytes, t) =
        sns::repair_with(&mut store, &objs, dev, 0.0, &mut sched).unwrap();
    let frontiers: Vec<f64> =
        (0..store.cluster.devices.len()).map(|d| sched.frontier(d)).collect();
    let (io_calls, ios) = (sched.io_calls(), sched.ios());
    RepairRun { bytes, t, store, objs, dev, frontiers, io_calls, ios }
}

fn main() {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let n_objects = if quick { 4 } else { 16 };
    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };
    let obj_bytes = STRIPES_PER_OBJ * K as u64 * UNIT;

    let mut rng = SimRng::new(11);
    let datas: Vec<Vec<u8>> = (0..n_objects)
        .map(|_| {
            let mut d = vec![0u8; obj_bytes as usize];
            rng.fill_bytes(&mut d);
            d
        })
        .collect();

    // ---- virtual-time completion: serial fold vs sharded ---------------
    let mut serial = run_repair(true, n_objects, &datas);
    let mut sharded = run_repair(false, n_objects, &datas);
    let (t_serial, t_sharded) = (serial.t, sharded.t);
    let (io_calls, ios) = (sharded.io_calls, sharded.ios);
    assert_eq!(
        serial.bytes, sharded.bytes,
        "both engines rebuild the same units"
    );
    assert!(serial.bytes > 0, "the failed device held units to rebuild");
    assert_eq!(
        serial.dev, sharded.dev,
        "identical allocation => same failed device"
    );
    assert!(
        t_sharded <= t_serial * (1.0 + 1e-9),
        "sharded repair must not exceed the serial fold \
         ({t_sharded} vs {t_serial})"
    );
    let virtual_speedup = t_serial / t_sharded.max(1e-12);

    // byte oracle on the SAME repaired stores: every object reads back
    // its original contents (the failed device is still down; its
    // units were re-homed)
    for (i, data) in datas.iter().enumerate() {
        let (a, _) = sns_serial::read(
            &mut serial.store,
            serial.objs[i],
            0,
            obj_bytes,
            1e6,
        )
        .unwrap();
        let (b, _) = sns::read(
            &mut sharded.store,
            sharded.objs[i],
            0,
            obj_bytes,
            1e6,
        )
        .unwrap();
        assert_eq!(&a, data, "serial store intact after repair");
        assert_eq!(&b, data, "sharded store intact after repair");
    }
    let frontiers = std::mem::take(&mut sharded.frontiers);

    let mut t = Table::new(
        &format!(
            "Scheduler-driven repair vs serial fold \
             ({n_objects} objects x {STRIPES_PER_OBJ} stripes, {K}+{P}, \
             skewed pool, 1 failed device)"
        ),
        &["engine", "virtual completion", "io() calls", "unit I/Os"],
    );
    t.row(vec![
        "serial fold".into(),
        sage::metrics::fmt_secs(t_serial),
        ios.to_string(),
        ios.to_string(),
    ]);
    t.row(vec![
        "sharded".into(),
        sage::metrics::fmt_secs(t_sharded),
        io_calls.to_string(),
        ios.to_string(),
    ]);
    t.row(vec![
        "speedup".into(),
        format!("{virtual_speedup:.2}x"),
        "".into(),
        "".into(),
    ]);
    print!("{}", t.render());

    // ---- per-target frontier: rebuild writes stream across devices -----
    let probe = MeroStore::new(skewed_cluster());
    let straggler = straggler_dev(&probe.cluster);
    let mut t = Table::new(
        "Per-device completion frontiers (sharded repair)",
        &["device", "profile", "frontier"],
    );
    let mut fast_max = 0.0f64;
    for (d, f) in frontiers.iter().enumerate() {
        if d != straggler {
            fast_max = fast_max.max(*f);
        }
        t.row(vec![
            format!("dev{d}"),
            if d == straggler { "SMR straggler".into() } else { "SSD".into() },
            sage::metrics::fmt_secs(*f),
        ]);
    }
    print!("{}", t.render());
    let isolation = frontiers[straggler] / fast_max.max(1e-12);
    println!(
        "straggler frontier / fastest-target frontier = {isolation:.2}x \
         (healthy targets do not wait for the straggler)\n"
    );

    // ---- wall-clock repair cycle --------------------------------------
    let m_serial = Bencher::new("repair_serial_fold")
        .iters(warm, iters)
        .wall(|| run_repair(true, n_objects, &datas).t);
    let m_sharded = Bencher::new("repair_sharded")
        .iters(warm, iters)
        .wall(|| run_repair(false, n_objects, &datas).t);
    let wall_speedup = m_serial.median / m_sharded.median.max(1e-12);

    let mut t = Table::new(
        "Wall-clock repair cycle (build + fail + rebuild)",
        &["engine", "cycle", "speedup"],
    );
    t.row(vec![
        "serial fold".into(),
        sage::metrics::fmt_secs(m_serial.median),
        "1.00x".into(),
    ]);
    t.row(vec![
        "sharded".into(),
        sage::metrics::fmt_secs(m_sharded.median),
        format!("{wall_speedup:.2}x"),
    ]);
    print!("{}", t.render());

    record("ablate_repair", &[
        ("k", K as f64),
        ("p", P as f64),
        ("n_objects", n_objects as f64),
        ("iters", iters as f64),
        ("bytes_rebuilt", sharded.bytes as f64),
        ("serial_virtual_s", t_serial),
        ("sharded_virtual_s", t_sharded),
        ("virtual_speedup", virtual_speedup),
        ("straggler_isolation", isolation),
        ("serial_cycle_s", m_serial.median),
        ("serial_mad_s", m_serial.mad),
        ("sharded_cycle_s", m_sharded.median),
        ("sharded_mad_s", m_sharded.mad),
        ("wall_speedup", wall_speedup),
        ("sharded_io_calls", io_calls as f64),
        ("sharded_unit_ios", ios as f64),
    ]);
}
