//! Bench: Figure 4 — DHT over MPI windows, Blackdog (8 procs, HDD+SSD)
//! and Tegner (96 procs, Lustre), sweeping the local-volume size.
//!
//! Run: `cargo bench --bench fig4_dht`

use sage::apps::dht::{self, DhtConfig};
use sage::bench::record;
use sage::config::Testbed;
use sage::metrics::Table;
use sage::pgas::{StorageTarget, WindowKind};

/// Scaled-down volumes: the paper uses 25..100M elements per volume;
/// we use 25..100 * SCALE elements so the sweep completes quickly while
/// keeping op-to-volume ratios (the shape driver) identical.
const SCALE: u64 = 2_000;

fn main() {
    // ---------------- (a) Blackdog ------------------------------------
    let tb = Testbed::blackdog();
    let mut t = Table::new(
        "Fig 4(a) DHT Blackdog, 8 procs: execution time (s)",
        &["volume(x)", "memory", "ssd", "hdd", "ssd ovh", "hdd ovh"],
    );
    for m in [25u64, 50, 100] {
        let cfg = DhtConfig {
            ranks: 8,
            local_volume: m * SCALE,
            ops_per_rank: 2 * m * SCALE,
            sync_interval: u64::MAX, // durability fence at the end
        };
        let t_mem = dht::run(&tb, WindowKind::Memory, &cfg).unwrap();
        let t_ssd =
            dht::run(&tb, WindowKind::Storage(StorageTarget::Ssd), &cfg).unwrap();
        let t_hdd =
            dht::run(&tb, WindowKind::Storage(StorageTarget::Hdd), &cfg).unwrap();
        t.row(vec![
            m.to_string(),
            format!("{t_mem:.2}"),
            format!("{t_ssd:.2}"),
            format!("{t_hdd:.2}"),
            format!("{:+.0}%", (t_ssd / t_mem - 1.0) * 100.0),
            format!("{:+.0}%", (t_hdd / t_mem - 1.0) * 100.0),
        ]);
        record("fig4a", &[
            ("volume", m as f64),
            ("mem_s", t_mem),
            ("ssd_s", t_ssd),
            ("hdd_s", t_hdd),
        ]);
    }
    print!("{}", t.render());
    println!("paper: +34% HDD, ~+20% SSD vs memory\n");

    // ---------------- (b) Tegner --------------------------------------
    let tegner = Testbed::tegner();
    let mut t = Table::new(
        "Fig 4(b) DHT Tegner, 96 procs: execution time (s)",
        &["volume(x)", "memory", "lustre", "overhead"],
    );
    for m in [25u64, 50, 100] {
        let cfg = DhtConfig {
            ranks: 96,
            local_volume: m * SCALE,
            ops_per_rank: 2 * m * SCALE,
            sync_interval: u64::MAX,
        };
        let t_mem = dht::run(&tegner, WindowKind::Memory, &cfg).unwrap();
        let t_pfs =
            dht::run(&tegner, WindowKind::Storage(StorageTarget::Pfs), &cfg).unwrap();
        t.row(vec![
            m.to_string(),
            format!("{t_mem:.2}"),
            format!("{t_pfs:.2}"),
            format!("{:+.1}%", (t_pfs / t_mem - 1.0) * 100.0),
        ]);
        record("fig4b", &[
            ("volume", m as f64),
            ("mem_s", t_mem),
            ("pfs_s", t_pfs),
        ]);
    }
    print!("{}", t.render());
    println!("paper: ~2% average degradation");
}
