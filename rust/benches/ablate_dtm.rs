//! Ablation (Tbl C): Mero's epoch-based DTM vs RDBMS-style two-phase
//! locking — the scaling argument of §3.2.1 ("traditional RDMS-style
//! transactions are known not to scale").
//!
//! Sweeps transaction batch sizes and contention levels; reports commit
//! throughput (virtual time) and abort rates for both schemes.
//!
//! Run: `cargo bench --bench ablate_dtm`

use sage::bench::record;
use sage::mero::dtm::{DtmManager, TwoPhaseLocking};
use sage::metrics::Table;
use sage::sim::rng::SimRng;

/// Run `n_tx` transactions of `writes_per_tx` writes over a key space
/// of `keys` (smaller = more contention). Returns (virtual seconds,
/// committed, aborted).
fn run_dtm(n_tx: u64, writes_per_tx: u64, keys: u64) -> (f64, u64, u64) {
    let mut m = DtmManager::new();
    let mut rng = SimRng::new(1);
    let mut now = 0.0;
    for _ in 0..n_tx {
        let tx = m.begin();
        for _ in 0..writes_per_tx {
            let k = rng.gen_range(keys).to_be_bytes().to_vec();
            // read-modify-write: realistic conflict surface
            let _ = m.read(tx, &k);
            m.write(tx, k, b"v".to_vec()).unwrap();
        }
        match m.commit(tx, now) {
            Ok(t) => now = t,
            Err(_) => {} // aborted: optimistic validation failed
        }
    }
    (now, m.committed, m.aborted)
}

fn run_2pl(n_tx: u64, writes_per_tx: u64, keys: u64) -> (f64, u64, u64) {
    let mut l = TwoPhaseLocking::new();
    let mut rng = SimRng::new(1);
    let mut now = 0.0;
    for _ in 0..n_tx {
        let tx = l.begin();
        let mut ok = true;
        for _ in 0..writes_per_tx {
            let k = rng.gen_range(keys).to_be_bytes().to_vec();
            match l.write(tx, k, b"v".to_vec(), now) {
                Ok(t) => now = t,
                Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if ok {
            now = l.commit(tx, now);
        }
    }
    (now, l.committed, l.aborted)
}

fn main() {
    let n_tx = 20_000;
    let mut t = Table::new(
        "Tbl C: epoch DTM vs 2PL (20k txns, virtual time)",
        &["writes/tx", "keyspace", "dtm tput(tx/s)", "2pl tput(tx/s)", "dtm aborts", "2pl aborts"],
    );
    for (w, keys) in [(2u64, 100_000u64), (8, 100_000), (8, 1_000), (32, 1_000)] {
        let (t_dtm, c_dtm, a_dtm) = run_dtm(n_tx, w, keys);
        let (t_2pl, c_2pl, a_2pl) = run_2pl(n_tx, w, keys);
        let tput_dtm = c_dtm as f64 / t_dtm.max(1e-9);
        let tput_2pl = c_2pl as f64 / t_2pl.max(1e-9);
        t.row(vec![
            w.to_string(),
            keys.to_string(),
            format!("{tput_dtm:.0}"),
            format!("{tput_2pl:.0}"),
            a_dtm.to_string(),
            a_2pl.to_string(),
        ]);
        record("ablate_dtm", &[
            ("writes_per_tx", w as f64),
            ("keyspace", keys as f64),
            ("dtm_tput", tput_dtm),
            ("twopl_tput", tput_2pl),
        ]);
    }
    print!("{}", t.render());
    println!(
        "expected: DTM throughput stays log-force bound (group commit, no \
         per-key lock RPCs); 2PL throughput degrades with writes/tx and \
         contention"
    );
}
