//! Sim-core hot-path ablation (§Perf, ISSUE 8): the dense
//! representation overhaul — sorted-run object maps, Vec-indexed
//! scheduler shards, sorted lane tables, recycled ticket storage —
//! measured against the preserved BTreeMap scheduler core
//! (`sage::sim::sched_oracle::OracleScheduler`), with bit-identity
//! asserted IN the bench:
//!
//! * **soak double-run** — the overhauled sim core must still produce
//!   a bit-identical [`SoakReport`] for one config run twice (the
//!   soak's own determinism oracle, now running on the dense paths);
//! * **scheduler differential** — one deterministic submission stream
//!   replays through the dense `IoScheduler` and the preserved
//!   `OracleScheduler`; every completion, epoch frontier and final
//!   device `busy_until` must agree to the bit;
//! * **speedup** — the same replay is wall-clocked on both cores
//!   (median ± MAD); in full mode the bench asserts
//!   `speedup >= 1` — the dense tables must never be slower than the
//!   BTreeMap core they replaced. Quick mode records the ratio
//!   without asserting (CI-noise tolerance on a small stream).
//!
//! Reported: soak cycle wall median ± MAD, the soak's phase timers
//! ([`SoakDiag`]), replay medians for both cores and the speedup.
//!
//! Run: `cargo bench --bench ablate_simcore`
//! CI smoke: `SAGE_BENCH_QUICK=1 cargo bench --bench ablate_simcore`
//! Rows append to `bench_results/ablate_simcore.json`
//! (fields documented in `bench_results/README.md`).

use sage::bench::{record, Bencher};
use sage::metrics::Table;
use sage::sim::device::{Access, Device, DeviceProfile, IoOp};
use sage::sim::rng::SimRng;
use sage::sim::sched::{QosConfig, TenantShares, TrafficClass};
use sage::sim::sched_oracle::OracleScheduler;
use sage::sim::IoScheduler;
use sage::tools::soak::{run, SoakConfig};

/// Virtual seconds between replay epochs.
const EPOCH_GAP: f64 = 10.0;

/// One replayed submission (pre-generated so workload generation
/// stays outside the measured closures).
#[derive(Clone, Copy)]
struct Sub {
    device: usize,
    at: f64,
    size: u64,
    op: IoOp,
    access: Access,
    class: TrafficClass,
    tenant: usize,
}

/// Deterministic submission stream: `n_epochs` epochs of `per_epoch`
/// ops spread over `n_devices` devices, mixing classes, tenants,
/// sizes and access patterns so the QoS, tenancy and coalescing paths
/// all run.
fn gen_workload(
    n_devices: usize,
    n_epochs: usize,
    per_epoch: usize,
    seed: u64,
) -> Vec<Vec<Sub>> {
    let mut rng = SimRng::new(seed);
    let mut epochs = Vec::with_capacity(n_epochs);
    for e in 0..n_epochs {
        let now = e as f64 * EPOCH_GAP;
        let mut subs = Vec::with_capacity(per_epoch);
        for _ in 0..per_epoch {
            subs.push(Sub {
                device: rng.gen_index(n_devices),
                at: now + rng.gen_f64(),
                size: 4096u64 << rng.gen_index(5),
                op: if rng.gen_f64() < 0.5 { IoOp::Read } else { IoOp::Write },
                access: if rng.gen_f64() < 0.7 {
                    Access::Seq
                } else {
                    Access::Random
                },
                class: TrafficClass::ALL[rng.gen_index(3)],
                tenant: rng.gen_index(3),
            });
        }
        epochs.push(subs);
    }
    epochs
}

fn mk_devices(n: usize) -> Vec<Device> {
    (0..n)
        .map(|i| {
            Device::new(if i % 2 == 0 {
                DeviceProfile::ssd(1 << 40)
            } else {
                DeviceProfile::hdd(1 << 40)
            })
        })
        .collect()
}

fn mk_tenants() -> TenantShares {
    let mut t = TenantShares::single();
    t.register(2.0);
    t.register(1.0);
    t
}

fn mk_qos() -> QosConfig {
    QosConfig { repair_share: 0.4, migration_share: 0.25, work_conserving: false }
}

/// Replay the stream through the dense `IoScheduler`; returns the sum
/// of per-epoch `wait_all` frontiers (black-boxed by the bencher).
fn replay_dense(epochs: &[Vec<Sub>], n_devices: usize) -> f64 {
    let mut devices = mk_devices(n_devices);
    let mut sched = IoScheduler::with_qos(mk_qos());
    sched.set_tenants(mk_tenants());
    let mut acc = 0.0;
    let mut frontier_buf = Vec::new();
    for (e, subs) in epochs.iter().enumerate() {
        sched.begin_epoch(e as f64 * EPOCH_GAP);
        for s in subs {
            sched.set_class(s.class);
            sched.set_tenant(s.tenant);
            sched.submit(s.device, s.at, s.size, s.op, s.access);
        }
        sched.drain(&mut devices);
        acc += sched.wait_all();
        // the allocation-free report path the session layer hits
        sched.frontiers_into(&mut frontier_buf);
        acc += frontier_buf.len() as f64;
    }
    acc
}

/// Same replay through the preserved BTreeMap core.
fn replay_oracle(epochs: &[Vec<Sub>], n_devices: usize) -> f64 {
    let mut devices = mk_devices(n_devices);
    let mut sched = OracleScheduler::with_qos(mk_qos());
    sched.set_tenants(mk_tenants());
    let mut acc = 0.0;
    for (e, subs) in epochs.iter().enumerate() {
        sched.begin_epoch(e as f64 * EPOCH_GAP);
        for s in subs {
            sched.set_class(s.class);
            sched.set_tenant(s.tenant);
            sched.submit(s.device, s.at, s.size, s.op, s.access);
        }
        sched.drain(&mut devices);
        acc += sched.wait_all();
        acc += sched.frontiers().len() as f64;
    }
    acc
}

/// Replay once through BOTH cores side by side and assert every
/// observable agrees to the bit: per-ticket completions, per-epoch
/// frontier tables, `wait_all`, and final device `busy_until`.
fn assert_cores_bit_identical(epochs: &[Vec<Sub>], n_devices: usize) {
    let mut dev_a = mk_devices(n_devices);
    let mut dev_b = mk_devices(n_devices);
    let mut dense = IoScheduler::with_qos(mk_qos());
    dense.set_tenants(mk_tenants());
    let mut oracle = OracleScheduler::with_qos(mk_qos());
    oracle.set_tenants(mk_tenants());
    let mut frontier_buf = Vec::new();
    for (e, subs) in epochs.iter().enumerate() {
        let now = e as f64 * EPOCH_GAP;
        dense.begin_epoch(now);
        oracle.begin_epoch(now);
        let mut ta = Vec::with_capacity(subs.len());
        let mut tb = Vec::with_capacity(subs.len());
        for s in subs {
            dense.set_class(s.class);
            dense.set_tenant(s.tenant);
            oracle.set_class(s.class);
            oracle.set_tenant(s.tenant);
            ta.push(dense.submit(s.device, s.at, s.size, s.op, s.access));
            tb.push(oracle.submit(s.device, s.at, s.size, s.op, s.access));
        }
        dense.drain(&mut dev_a);
        oracle.drain(&mut dev_b);
        for (&x, &y) in ta.iter().zip(&tb) {
            assert_eq!(
                dense.completion(x).to_bits(),
                oracle.completion(y).to_bits(),
                "epoch {e}: completion diverged"
            );
        }
        assert_eq!(
            dense.wait_all().to_bits(),
            oracle.wait_all().to_bits(),
            "epoch {e}: wait_all diverged"
        );
        dense.frontiers_into(&mut frontier_buf);
        let of = oracle.frontiers();
        assert_eq!(frontier_buf.len(), of.len(), "epoch {e}: shard count");
        for (a, b) in frontier_buf.iter().zip(&of) {
            assert_eq!(a.0, b.0, "epoch {e}: frontier device order");
            assert_eq!(
                a.1.to_bits(),
                b.1.to_bits(),
                "epoch {e}: frontier diverged (device {})",
                a.0
            );
        }
    }
    for (i, (a, b)) in dev_a.iter().zip(&dev_b).enumerate() {
        assert_eq!(
            a.busy_until.to_bits(),
            b.busy_until.to_bits(),
            "device {i}: busy_until diverged"
        );
    }
}

fn main() {
    let quick = std::env::var("SAGE_BENCH_QUICK").is_ok();
    let (n_devices, n_epochs, per_epoch) =
        if quick { (16, 8, 2_000) } else { (64, 32, 8_000 ) };
    let (warm, iters) = if quick { (1, 3) } else { (2, 7) };

    // ---- oracle 1: the soak on the dense sim core is still a pure
    // function of its config (double-run, bit-identical report)
    let soak_cfg = if quick { SoakConfig::quick(42) } else { SoakConfig::full(42) };
    let a = run(&soak_cfg).expect("soak run");
    let b = run(&soak_cfg).expect("soak rerun");
    assert_eq!(a, b, "dense sim core: same config, bit-identical SoakReport");
    assert!(a.events_consumed > 0 && a.recovered > 0, "the soak exercised recovery");

    // ---- oracle 2: dense scheduler vs preserved BTreeMap core
    let epochs = gen_workload(n_devices, n_epochs, per_epoch, 4242);
    assert_cores_bit_identical(&epochs, n_devices);

    // ---- wall clock: the soak cycle (quick shape in both modes so
    // the measured loop is homogeneous; full mode already ran the
    // full profile above for the equality oracle)
    let wall_cfg = SoakConfig::quick(42);
    let soak_m = Bencher::new("ablate_simcore/soak_quick_cycle")
        .iters(warm, iters)
        .wall(|| run(&wall_cfg).expect("soak wall cycle").events_consumed);

    // ---- wall clock: the scheduler inner loop on both cores
    let dense_m = Bencher::new("ablate_simcore/replay_dense")
        .iters(warm, iters)
        .wall(|| replay_dense(&epochs, n_devices));
    let oracle_m = Bencher::new("ablate_simcore/replay_btree_oracle")
        .iters(warm, iters)
        .wall(|| replay_oracle(&epochs, n_devices));
    let speedup = oracle_m.median / dense_m.median.max(1e-12);
    if !quick {
        assert!(
            speedup >= 1.0,
            "dense scheduler core regressed below the BTreeMap oracle: \
             dense {:.6}s vs oracle {:.6}s (speedup {speedup:.3})",
            dense_m.median,
            oracle_m.median
        );
    }

    let mut t = Table::new(
        &format!(
            "Sim-core ablation ({} devices, {} epochs × {} ops, {})",
            n_devices,
            n_epochs,
            per_epoch,
            if quick { "quick" } else { "full" }
        ),
        &["metric", "value"],
    );
    for (k, v) in [
        ("soak cycle p50", sage::metrics::fmt_secs(soak_m.median)),
        ("soak cycle MAD", sage::metrics::fmt_secs(soak_m.mad)),
        ("soak wall total", sage::metrics::fmt_secs(a.diag.wall_total_s)),
        ("  traffic phase", sage::metrics::fmt_secs(a.diag.wall_traffic_s)),
        ("  consume phase", sage::metrics::fmt_secs(a.diag.wall_consume_s)),
        ("  verify phase", sage::metrics::fmt_secs(a.diag.wall_verify_s)),
        ("replay dense p50", sage::metrics::fmt_secs(dense_m.median)),
        ("replay oracle p50", sage::metrics::fmt_secs(oracle_m.median)),
        ("speedup (oracle/dense)", format!("{speedup:.3}x")),
    ] {
        t.row(vec![k.into(), v]);
    }
    print!("{}", t.render());
    println!(
        "bit-identity: SoakReport double-run OK, {} scheduler epochs \
         dense==oracle to the bit\n",
        n_epochs
    );

    record("ablate_simcore", &[
        ("quick", if quick { 1.0 } else { 0.0 }),
        ("n_devices", n_devices as f64),
        ("n_epochs", n_epochs as f64),
        ("per_epoch", per_epoch as f64),
        ("soak_events_consumed", a.events_consumed as f64),
        ("soak_cycle_s", soak_m.median),
        ("soak_cycle_mad_s", soak_m.mad),
        ("soak_wall_total_s", a.diag.wall_total_s),
        ("soak_wall_traffic_s", a.diag.wall_traffic_s),
        ("soak_wall_consume_s", a.diag.wall_consume_s),
        ("soak_wall_verify_s", a.diag.wall_verify_s),
        ("soak_allocs", a.diag.allocs as f64),
        ("replay_dense_s", dense_m.median),
        ("replay_dense_mad_s", dense_m.mad),
        ("replay_oracle_s", oracle_m.median),
        ("replay_oracle_mad_s", oracle_m.mad),
        ("speedup", speedup),
    ]);
}
