//! Metrics: summary statistics, bandwidth series and table printing for
//! the benchmark harness and the ADDB (§3.2.2) performance reports.
//! In-tree substrate — see ARCHITECTURE.md §Module map.

use std::fmt::Write as _;

/// Summary statistics over a sample set.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    /// Empty collector.
    pub fn new() -> Self {
        Stats::default()
    }

    /// Add one sample.
    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True if no samples.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples.len() as f64)
            .sqrt()
    }

    /// Quantile in [0,1] by linear interpolation.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
        }
    }

    /// Median.
    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// Minimum.
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Maximum.
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// A labelled result table, printed in the aligned format the paper's
/// figures are transcribed into (EXPERIMENTS.md).
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                } else {
                    widths.push(c.len());
                }
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>width$}", h, width = widths[i]))
            .collect();
        let _ = writeln!(out, "{}", hdr.join("  "));
        let _ = writeln!(
            out,
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    format!("{:>width$}", c, width = widths.get(i).copied().unwrap_or(0))
                })
                .collect();
            let _ = writeln!(out, "{}", cells.join("  "));
        }
        out
    }

    /// Write as CSV (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }
}

/// Format seconds for human display.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = Stats::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.quantile(0.25) - 2.0).abs() < 1e-9);
        assert!(s.stddev() > 1.0 && s.stddev() < 2.0);
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.median(), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Fig X", &["procs", "time"]);
        t.row(vec!["8".into(), "1.25".into()]);
        t.row(vec!["8192".into(), "0.01".into()]);
        let r = t.render();
        assert!(r.contains("Fig X"));
        assert!(r.contains("8192"));
        let csv = t.to_csv();
        assert!(csv.starts_with("procs,time\n"));
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(2.5e-6), "2.5us");
    }
}
