//! `sage` — the SAGE stack CLI (leader entrypoint).
//!
//! Subcommands:
//! * `info` — show the loaded artifacts + testbed inventory
//! * `demo` — quick end-to-end smoke: object store round-trip, shipped
//!   function, streamed pipeline
//! * `fig3|fig4|fig5|fig7` — regenerate the paper's figures (same
//!   harnesses the benches use; see EXPERIMENTS.md)
//! * `addb` — run a workload and dump the ADDB performance report
//! * `lint` — the in-tree determinism/invariant static-analysis pass
//!   (see `tools/lint.rs`; exits nonzero on any violation)
//!
//! Examples:
//! ```text
//! sage fig3 --part a --testbed blackdog --elems 1000
//! sage fig7 --steps 100 --max-procs 8192
//! sage demo
//! ```

#![deny(unsafe_code)]

use sage::apps::{dht, hacc, ipic3d, stream};
use sage::clovis::{Client, FunctionKind};
use sage::config::Testbed;
use sage::metrics::Table;
use sage::pgas::{StorageTarget, WindowKind};
use sage::util::cli::Args;
use sage::Result;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("sage: error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("info") => info(args),
        Some("demo") => demo(args),
        Some("fig3") => fig3(args),
        Some("fig4") => fig4(args),
        Some("fig5") => fig5(args),
        Some("fig7") => fig7(args),
        Some("addb") => addb(args),
        Some("soak") => soak(args),
        Some("tenants") => tenants(args),
        Some("lint") => lint(args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
sage — SAGE: Percipient Storage for Exascale Data Centric Computing

USAGE: sage <command> [--options]

COMMANDS:
  info    loaded AOT artifacts + testbed inventory
  demo    end-to-end smoke (object store, function shipping, streams)
  fig3    STREAM over MPI windows        [--part a|b|c] [--elems N(M)]
  fig4    DHT over MPI windows           [--testbed blackdog|tegner]
  fig5    HACC-IO strong scaling         [--particles N]
  fig7    iPIC3D streams vs collective   [--steps N] [--max-procs P]
  addb    run a workload, print the ADDB report
  soak    long-horizon failure-storm soak       [--quick] [--seed N]
  tenants N-tenant contention on the shared scheduler
          [--quick] [--seed N] [--closed] [--no-tenancy]
  lint    determinism/invariant static analysis over rust/src
          [--json] [--src <dir>]; exits 1 on any violation

Common options: --testbed <name>, --csv (machine-readable output)
";

fn testbed(args: &Args, default: &str) -> Result<Testbed> {
    let name = args.get_str("testbed", default);
    Testbed::by_name(&name).ok_or_else(|| {
        sage::SageError::Config(format!("unknown testbed {name}"))
    })
}

fn print_table(args: &Args, t: &Table) {
    if args.flag("csv") {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.render());
    }
}

fn info(args: &Args) -> Result<()> {
    let tb = testbed(args, "sage_prototype")?;
    println!("testbed: {} ({} nodes x {} cores, {} DRAM/node)",
        tb.name, tb.compute_nodes, tb.cores_per_node,
        sage::util::bytes::fmt_size(tb.dram_per_node));
    let mut t = Table::new("storage inventory", &["kind", "capacity", "read", "write"]);
    for p in &tb.storage {
        t.row(vec![
            format!("{:?}", p.kind),
            sage::util::bytes::fmt_size(p.capacity),
            sage::util::bytes::fmt_bw(p.read_bw),
            sage::util::bytes::fmt_bw(p.write_bw),
        ]);
    }
    print_table(args, &t);
    match sage::runtime::Executor::load_default() {
        Ok(e) => {
            let mut v = e.variants();
            v.sort();
            println!("artifacts ({} PJRT devices): {}", e.device_count(), v.join(", "));
        }
        Err(e) => println!("artifacts: not loaded ({e})"),
    }
    Ok(())
}

fn demo(args: &Args) -> Result<()> {
    let tb = testbed(args, "sage_prototype")?;
    let mut client = match Client::new_with_runtime(tb.clone()) {
        Ok(c) => {
            println!("[demo] PJRT runtime loaded");
            c
        }
        Err(_) => {
            println!("[demo] no artifacts; CPU fallbacks");
            Client::new_sim(tb.clone())
        }
    };
    // 1. object store round-trip
    let obj = client.create_object(4096)?;
    let data: Vec<u8> = (0..4 * 65536u32).map(|i| (i % 251) as u8).collect();
    client.write_object(&obj, 0, &data)?;
    let back = client.read_object(&obj, 0, data.len() as u64)?;
    assert_eq!(back, data);
    println!("[demo] object round-trip: {} OK", sage::util::bytes::fmt_size(data.len() as u64));

    // 2. shipped function
    let vals = sage::apps::alf::generate_log_values(16384, 7);
    let log_obj = sage::apps::alf::store_log(&mut client, &vals)?;
    let r = client.ship_to_object(log_obj, FunctionKind::Histogram { lo: 0.0, hi: 1024.0 })?;
    println!(
        "[demo] shipped histogram: moved {} vs {} if data moved ({}x saving)",
        sage::util::bytes::fmt_size(r.net_bytes),
        sage::util::bytes::fmt_size(r.net_bytes_moved),
        r.net_bytes_moved / r.net_bytes.max(1)
    );

    // 3. streamed pipeline
    let exec = client.exec.as_ref();
    let (hot, _) = ipic3d::run_real_pipeline(&tb, exec, 5000, 20, 1.5, None)?;
    println!("[demo] streamed {hot} high-energy particles through the pipeline");

    // 4. batched zero-copy checkpointing (writev_owned / readv)
    let (hot2, ckpt, index) =
        ipic3d::run_checkpointed_pipeline(&mut client, 5000, 20, 1.5, 8)?;
    let restored = ipic3d::restore_checkpoint(&mut client, &ckpt, &index)?;
    let persisted: u64 = restored.iter().map(|b| b.len() as u64).sum();
    assert_eq!(persisted, hot2);
    println!(
        "[demo] checkpointed {persisted} hot particles across {} step batches",
        index.len()
    );
    println!("[demo] all OK");
    Ok(())
}

fn fig3(args: &Args) -> Result<()> {
    let part = args.get_str("part", "a");
    let reps = args.get::<u32>("reps", 3);
    match part.as_str() {
        "a" => {
            let tb = testbed(args, "blackdog")?;
            let mut t = Table::new(
                "Fig 3(a) STREAM on Blackdog: MB/s by problem size",
                &["Melems", "kernel", "memory", "storage(hdd)", "degradation"],
            );
            for m in [10, 100, 500, args.get::<u64>("elems", 1000)] {
                let mem = stream::run(&tb, WindowKind::Memory, m, reps)?;
                let sto = stream::run(&tb, WindowKind::Storage(StorageTarget::Hdd), m, reps)?;
                for (a, b) in mem.iter().zip(sto.iter()) {
                    t.row(vec![
                        m.to_string(),
                        a.kernel.into(),
                        format!("{:.0}", a.bandwidth / 1e6),
                        format!("{:.0}", b.bandwidth / 1e6),
                        format!("{:.1}%", (1.0 - b.bandwidth / a.bandwidth) * 100.0),
                    ]);
                }
            }
            print_table(args, &t);
        }
        "b" => {
            let tb = testbed(args, "tegner")?;
            let mut t = Table::new(
                "Fig 3(b) Lustre read/write asymmetry (copy kernel)",
                &["direction", "MB/s"],
            );
            let (r, w) = stream::rw_asymmetry(&tb, StorageTarget::Pfs, 4 << 30)?;
            t.row(vec!["read".into(), format!("{:.0}", r / 1e6)]);
            t.row(vec!["write".into(), format!("{:.0}", w / 1e6)]);
            print_table(args, &t);
        }
        _ => {
            let tb = testbed(args, "tegner")?;
            let mut t = Table::new(
                "Fig 3(c) STREAM on Tegner (Lustre): MB/s",
                &["Melems", "kernel", "memory", "storage(pfs)", "degradation"],
            );
            for m in [10, 100, args.get::<u64>("elems", 1000)] {
                let mem = stream::run(&tb, WindowKind::Memory, m, reps)?;
                let sto = stream::run(&tb, WindowKind::Storage(StorageTarget::Pfs), m, reps)?;
                for (a, b) in mem.iter().zip(sto.iter()) {
                    t.row(vec![
                        m.to_string(),
                        a.kernel.into(),
                        format!("{:.0}", a.bandwidth / 1e6),
                        format!("{:.0}", b.bandwidth / 1e6),
                        format!("{:.1}%", (1.0 - b.bandwidth / a.bandwidth) * 100.0),
                    ]);
                }
            }
            print_table(args, &t);
        }
    }
    Ok(())
}

fn fig4(args: &Args) -> Result<()> {
    let which = args.get_str("testbed", "blackdog");
    let mut t = Table::new(
        &format!("Fig 4 DHT on {which}: execution time (s)"),
        &["Melems/volume", "memory", "storage", "overhead"],
    );
    let tb = testbed(args, &which)?;
    let (ranks, targets): (usize, Vec<(&str, WindowKind)>) =
        if which == "tegner" {
            (96, vec![("pfs", WindowKind::Storage(StorageTarget::Pfs))])
        } else {
            (
                8,
                vec![
                    ("ssd", WindowKind::Storage(StorageTarget::Ssd)),
                    ("hdd", WindowKind::Storage(StorageTarget::Hdd)),
                ],
            )
        };
    for m in [25, 50, 100] {
        let volume = m * args.get::<u64>("scale", 10_000); // scaled-down default
        let cfg = dht::DhtConfig {
            ranks,
            local_volume: volume,
            ops_per_rank: volume / 2,
            sync_interval: volume,
        };
        let t_mem = dht::run(&tb, WindowKind::Memory, &cfg)?;
        for (label, kind) in &targets {
            let t_sto = dht::run(&tb, *kind, &cfg)?;
            t.row(vec![
                format!("{m} ({label})"),
                format!("{t_mem:.2}"),
                format!("{t_sto:.2}"),
                format!("{:+.1}%", (t_sto / t_mem - 1.0) * 100.0),
            ]);
        }
    }
    print_table(args, &t);
    Ok(())
}

fn fig5(args: &Args) -> Result<()> {
    let particles = args.get::<u64>("particles", 100_000_000);
    for (name, target, ranks) in [
        ("blackdog", StorageTarget::Hdd, vec![1usize, 2, 4, 8]),
        ("tegner", StorageTarget::Pfs, vec![24, 48, 96, 144]),
    ] {
        let tb = Testbed::by_name(name).unwrap();
        let mut t = Table::new(
            &format!("Fig 5 HACC-IO on {name}: checkpoint+restart (s), {particles} particles"),
            &["procs", "mpi-io", "storage windows", "win/mpiio"],
        );
        for r in ranks {
            let t_mpiio = hacc::run(&tb, hacc::HaccImpl::MpiIo, r, particles)?;
            let t_win = hacc::run(&tb, hacc::HaccImpl::StorageWindows(target), r, particles)?;
            t.row(vec![
                r.to_string(),
                format!("{t_mpiio:.2}"),
                format!("{t_win:.2}"),
                format!("{:.2}", t_win / t_mpiio),
            ]);
        }
        print_table(args, &t);
    }
    Ok(())
}

fn fig7(args: &Args) -> Result<()> {
    let tb = testbed(args, "beskow")?;
    let steps = args.get::<u64>("steps", 100);
    let maxp = args.get::<usize>("max-procs", 8192);
    let mut t = Table::new(
        "Fig 7 iPIC3D: collective I/O vs MPI streams (100 steps)",
        &["procs", "collective(s)", "streams(s)", "improvement"],
    );
    let mut p = 64;
    while p <= maxp {
        let pt = ipic3d::run_scaling(&tb, p, steps);
        t.row(vec![
            p.to_string(),
            format!("{:.2}", pt.t_collective),
            format!("{:.2}", pt.t_streams),
            format!("{:.2}x", pt.improvement),
        ]);
        p *= 2;
    }
    print_table(args, &t);
    Ok(())
}

fn soak(args: &Args) -> Result<()> {
    let seed = args.get::<u64>("seed", 42);
    let cfg = if args.flag("quick") {
        sage::tools::soak::SoakConfig::quick(seed)
    } else {
        sage::tools::soak::SoakConfig::full(seed)
    };
    println!(
        "[soak] {:.1}h virtual, {} objects, {} storms, seed {seed} — \
         durability invariants checked in-harness",
        cfg.horizon / 3600.0,
        cfg.n_objects,
        cfg.storms
    );
    let r = sage::tools::soak::run(&cfg)?;
    let mut t = Table::new("Failure-storm soak", &["metric", "value"]);
    for (k, v) in [
        ("virtual time", sage::metrics::fmt_secs(r.final_now)),
        ("ticks", r.ticks.to_string()),
        ("events consumed", r.events_consumed.to_string()),
        ("  recovered", r.recovered.to_string()),
        ("  transient retried", r.transient_retried.to_string()),
        ("  aborted by re-failure", r.aborted_by_refailure.to_string()),
        ("  escalated to repair", r.escalated_to_repair.to_string()),
        ("  absorbed by escalation", r.absorbed_by_escalation.to_string()),
        ("  data-loss verdicts", r.data_loss_events.to_string()),
        ("  failed recoveries", r.failed_recoveries.to_string()),
        ("  no action", r.no_action.to_string()),
        ("objects lost (accounted)", r.objects_lost.to_string()),
        ("bytes rebuilt", sage::util::bytes::fmt_size(r.bytes_rebuilt)),
        ("bytes rebalanced", sage::util::bytes::fmt_size(r.bytes_rebalanced)),
        ("bytes drained", sage::util::bytes::fmt_size(r.bytes_drained)),
        ("bytes written", sage::util::bytes::fmt_size(r.bytes_written)),
        ("writes (skipped)", format!("{} ({})", r.writes, r.writes_skipped)),
        ("reads verified", r.reads_verified.to_string()),
        ("full verifies", r.full_verifies.to_string()),
        ("devices added", r.devices_added.to_string()),
        ("drains run (errors)", format!("{} ({})", r.drains_run, r.drain_errors)),
        ("repairs started/aborted", format!("{}/{}", r.repairs_started, r.repairs_aborted)),
        ("max pass outcomes", r.max_pass_outcomes.to_string()),
        (
            "recovery latency p50±MAD",
            format!(
                "{}±{}",
                sage::metrics::fmt_secs(r.recovery_latency_p50),
                sage::metrics::fmt_secs(r.recovery_latency_mad)
            ),
        ),
    ] {
        t.row(vec![k.into(), v]);
    }
    print_table(args, &t);
    println!("[soak] all durability invariants held");
    Ok(())
}

fn tenants(args: &Args) -> Result<()> {
    use sage::tools::tenants::{run as run_tenants, ArrivalModel, TenantsConfig};
    let seed = args.get::<u64>("seed", 42);
    let mut cfg = if args.flag("quick") {
        TenantsConfig::quick(seed)
    } else {
        TenantsConfig::full(seed)
    };
    if args.flag("closed") {
        cfg.arrival = ArrivalModel::Closed { think: 0.3 };
    }
    if args.flag("no-tenancy") {
        cfg.tenancy = false; // the FIFO baseline, same arrivals
    }
    println!(
        "[tenants] {} tenants x {} requests, {:?} arrivals, tenancy {}, \
         seed {seed} — byte/share invariants checked in-harness",
        cfg.weights.len(),
        cfg.requests_per_tenant,
        cfg.arrival,
        if cfg.tenancy { "on" } else { "off" },
    );
    let r = run_tenants(&cfg)?;
    let mut t = Table::new(
        "Multi-tenant contention (latencies in virtual seconds)",
        &["tenant", "weight", "requests", "bytes", "p50", "p99", "p999", "max share"],
    );
    for pt in &r.per_tenant {
        t.row(vec![
            pt.tenant.to_string(),
            format!("{:.1}", pt.weight),
            pt.requests.to_string(),
            sage::util::bytes::fmt_size(pt.bytes),
            format!("{:.4}", pt.p50),
            format!("{:.4}", pt.p99),
            format!("{:.4}", pt.p999),
            format!("{:.3}", pt.max_observed_share),
        ]);
    }
    print_table(args, &t);
    println!(
        "[tenants] jain fairness {:.4}, makespan {}, {} total, crc {:08x}",
        r.jain,
        sage::metrics::fmt_secs(r.makespan),
        sage::util::bytes::fmt_size(r.total_bytes),
        r.bytes_crc
    );
    Ok(())
}

fn lint(args: &Args) -> Result<()> {
    let src = args.get_str("src", "");
    let root = if src.is_empty() {
        sage::tools::lint::default_src_root()
    } else {
        std::path::PathBuf::from(src)
    };
    let report = sage::tools::lint::run_lint(&root)?;
    if args.flag("json") {
        println!("{}", report.to_json());
    } else {
        println!("{}", report.render());
    }
    let denied = report.deny_count();
    if denied > 0 {
        return Err(sage::SageError::Invalid(format!(
            "lint: {denied} violation(s) (see above)"
        )));
    }
    Ok(())
}

fn addb(args: &Args) -> Result<()> {
    let tb = testbed(args, "sage_prototype")?;
    let mut client = Client::new_sim(tb);
    for i in 0..8 {
        let obj = client.create_object(4096)?;
        let data = vec![i as u8; 4 * 65536];
        client.write_object(&obj, 0, &data)?;
        client.read_object(&obj, 0, data.len() as u64)?;
        client.ship_to_object(obj, FunctionKind::IntegrityCheck)?;
    }
    println!("{}", client.addb.report());
    Ok(())
}
