//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them on the request
//! path. Python never runs here. This is the L2/L1 → L3 bridge of the
//! three-layer build (ARCHITECTURE.md §Module map); it serves the
//! §3.2.1 SNS parity and function-shipping hot spots.
//!
//! One compiled executable per model variant (e.g. `parity_k4`,
//! `parity_k8`, `postprocess_16k`, `postprocess_64k`); callers such as
//! the SNS write path and the function-shipping engine pick the variant
//! matching their (padded) request size via the typed helpers below.
//!
//! The PJRT backend (the `xla` crate) is gated behind the **`pjrt`**
//! cargo feature: the offline build carries no XLA binding, so the
//! default build compiles this module as a stub whose [`Executor::load`]
//! fails cleanly. Every caller already falls back to the CPU reference
//! implementations (identical bytes, no kernel offload), so the whole
//! stack — SNS parity, function shipping, post-processing — works
//! unchanged without the feature.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Result, SageError};

/// Manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    /// Input shapes (row-major dims per input).
    pub input_shapes: Vec<Vec<usize>>,
    pub num_outputs: usize,
}

/// The PJRT executor: a CPU client + one loaded executable per variant.
/// Without the `pjrt` feature this is an uninstantiable stub —
/// [`Executor::load`] always errors and callers use CPU fallbacks.
pub struct Executor {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    infos: HashMap<String, ArtifactInfo>,
}

impl Executor {
    /// Load every artifact listed in `<dir>/manifest.json`, compiling
    /// each HLO text module on the PJRT CPU client.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(_dir: &Path) -> Result<Executor> {
        Err(SageError::Runtime(
            "PJRT runtime not compiled in (build with the `pjrt` feature); \
             CPU fallbacks remain fully functional"
                .into(),
        ))
    }

    /// Load every artifact listed in `<dir>/manifest.json`, compiling
    /// each HLO text module on the PJRT CPU client.
    #[cfg(feature = "pjrt")]
    pub fn load(dir: &Path) -> Result<Executor> {
        use crate::util::json::Json;
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            SageError::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Json::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        let mut infos = HashMap::new();
        for entry in manifest.items() {
            let name = entry
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| SageError::Runtime("manifest: no name".into()))?
                .to_string();
            let input_shapes = entry
                .get("inputs")
                .map(|ins| {
                    ins.items()
                        .iter()
                        .map(|i| {
                            i.get("shape")
                                .map(|s| {
                                    s.items()
                                        .iter()
                                        .filter_map(|d| d.as_u64())
                                        .map(|d| d as usize)
                                        .collect()
                                })
                                .unwrap_or_default()
                        })
                        .collect()
                })
                .unwrap_or_default();
            let num_outputs = entry
                .get("num_outputs")
                .and_then(|n| n.as_u64())
                .unwrap_or(1) as usize;
            let hlo_path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path.to_str().unwrap(),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert(name.clone(), exe);
            infos.insert(name.clone(), ArtifactInfo { name, input_shapes, num_outputs });
        }
        Ok(Executor { client, exes, infos })
    }

    /// Load from the conventional `artifacts/` directory (honors the
    /// `SAGE_ARTIFACTS` env override).
    pub fn load_default() -> Result<Executor> {
        let dir = std::env::var("SAGE_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    /// Names of loaded artifacts.
    pub fn variants(&self) -> Vec<&str> {
        self.infos.keys().map(|s| s.as_str()).collect()
    }

    /// Whether a named variant is loaded.
    pub fn has(&self, name: &str) -> bool {
        self.infos.contains_key(name)
    }

    /// Artifact metadata.
    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.infos.get(name)
    }

    /// Raw execution: run `name` with the given literals, unpack the
    /// result tuple.
    #[cfg(feature = "pjrt")]
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| SageError::Runtime(format!("no artifact {name}")))?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    // ------------------------------------------------------------ parity

    /// SNS parity via the Pallas kernel. Picks `parity_k{K}` by the
    /// number of units; returns `Ok(None)` when no variant matches (the
    /// caller falls back to CPU XOR).
    #[cfg(not(feature = "pjrt"))]
    pub fn parity(&self, _units: &[Vec<u8>]) -> Result<Option<Vec<u8>>> {
        Ok(None)
    }

    /// SNS parity via the Pallas kernel. Picks `parity_k{K}` by the
    /// number of units; returns `Ok(None)` when no variant matches (the
    /// caller falls back to CPU XOR).
    #[cfg(feature = "pjrt")]
    pub fn parity(&self, units: &[Vec<u8>]) -> Result<Option<Vec<u8>>> {
        let k = units.len();
        let name = format!("parity_k{k}");
        let Some(info) = self.infos.get(&name) else {
            return Ok(None);
        };
        let lanes = info.input_shapes[0][1];
        let unit_bytes = lanes * 4;
        if units.iter().any(|u| u.len() != units[0].len())
            || units[0].is_empty()
            || units[0].len() > unit_bytes
        {
            return Ok(None);
        }
        let ulen = units[0].len();
        // pack into i32 lanes, zero-padded to the artifact shape
        let mut lanes_i32 = vec![0i32; k * lanes];
        for (ui, u) in units.iter().enumerate() {
            for (li, chunk) in u.chunks(4).enumerate() {
                let mut b = [0u8; 4];
                b[..chunk.len()].copy_from_slice(chunk);
                lanes_i32[ui * lanes + li] = i32::from_le_bytes(b);
            }
        }
        let lit = xla::Literal::vec1(&lanes_i32)
            .reshape(&[k as i64, lanes as i64])?;
        let out = self.execute(&name, &[lit])?;
        let parity: Vec<i32> = out[0].to_vec()?;
        let mut bytes = Vec::with_capacity(ulen);
        for v in parity {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.truncate(ulen);
        Ok(Some(bytes))
    }

    // ------------------------------------------------- particle filter

    /// iPIC3D post-processing (`postprocess_{16k,64k}`): energies, mask
    /// and stats for up to 65536 particles (padded). `particles` is
    /// row-major (n, 8) with columns (x,y,z,u,v,w,q,id).
    #[cfg(not(feature = "pjrt"))]
    pub fn postprocess(
        &self,
        particles: &[f32],
        _threshold: f32,
    ) -> Result<Option<PostprocessOut>> {
        if particles.len() % 8 != 0 {
            return Err(SageError::Invalid(
                "particles must be (n, 8) row-major".into(),
            ));
        }
        Ok(None)
    }

    /// iPIC3D post-processing (`postprocess_{16k,64k}`): energies, mask
    /// and stats for up to 65536 particles (padded). `particles` is
    /// row-major (n, 8) with columns (x,y,z,u,v,w,q,id).
    #[cfg(feature = "pjrt")]
    pub fn postprocess(
        &self,
        particles: &[f32],
        threshold: f32,
    ) -> Result<Option<PostprocessOut>> {
        if particles.len() % 8 != 0 {
            return Err(SageError::Invalid(
                "particles must be (n, 8) row-major".into(),
            ));
        }
        let n = particles.len() / 8;
        let name = if n <= 16384 && self.has("postprocess_16k") {
            "postprocess_16k"
        } else if n <= 65536 && self.has("postprocess_64k") {
            "postprocess_64k"
        } else {
            return Ok(None);
        };
        let cap = self.infos[name].input_shapes[0][0];
        let mut padded = vec![0f32; cap * 8];
        padded[..particles.len()].copy_from_slice(particles);
        let parts = xla::Literal::vec1(&padded).reshape(&[cap as i64, 8])?;
        let thr = xla::Literal::vec1(&[threshold]);
        let out = self.execute(name, &[parts, thr])?;
        let energies: Vec<f32> = out[0].to_vec()?;
        let mask: Vec<f32> = out[1].to_vec()?;
        let stats: Vec<f32> = out[2].to_vec()?;
        Ok(Some(PostprocessOut {
            energies: energies[..n].to_vec(),
            mask: mask[..n].to_vec(),
            selected: mask[..n].iter().sum::<f32>() as usize,
            stats: [stats[0], stats[1], stats[2], stats[3]],
        }))
    }

    // ------------------------------------------------------- histogram

    /// ALF log histogram (`alf_histogram_64k`): 64 uniform bins over
    /// `[lo, hi)`. Longer inputs are processed in artifact-capacity
    /// chunks and summed (the kernel is linear in its input blocks).
    #[cfg(not(feature = "pjrt"))]
    pub fn histogram(&self, _values: &[f32], _lo: f32, _hi: f32) -> Result<Option<Vec<f32>>> {
        Ok(None)
    }

    /// ALF log histogram (`alf_histogram_64k`): 64 uniform bins over
    /// `[lo, hi)`. Longer inputs are processed in artifact-capacity
    /// chunks and summed (the kernel is linear in its input blocks).
    #[cfg(feature = "pjrt")]
    pub fn histogram(&self, values: &[f32], lo: f32, hi: f32) -> Result<Option<Vec<f32>>> {
        let name = "alf_histogram_64k";
        let Some(info) = self.infos.get(name) else {
            return Ok(None);
        };
        let cap = info.input_shapes[0][0];
        let mut counts = vec![0f32; 64];
        for chunk in values.chunks(cap) {
            // pad with `lo` (lands in bin 0), subtract the padding after
            let mut padded = vec![lo; cap];
            padded[..chunk.len()].copy_from_slice(chunk);
            let vals = xla::Literal::vec1(&padded);
            let range = xla::Literal::vec1(&[lo, hi]);
            let out = self.execute(name, &[vals, range])?;
            let c: Vec<f32> = out[0].to_vec()?;
            for (acc, v) in counts.iter_mut().zip(c.iter()) {
                *acc += v;
            }
            counts[0] -= (cap - chunk.len()) as f32;
        }
        Ok(Some(counts))
    }

    // ------------------------------------------------------- integrity

    /// Fletcher-style block digests (`integrity_16x4k`): 16 blocks of
    /// 4096 i32 lanes; returns [sum, weighted-sum] per block.
    #[cfg(not(feature = "pjrt"))]
    pub fn integrity(&self, _blocks: &[i32]) -> Result<Option<Vec<[i32; 2]>>> {
        Ok(None)
    }

    /// Fletcher-style block digests (`integrity_16x4k`): 16 blocks of
    /// 4096 i32 lanes; returns [sum, weighted-sum] per block.
    #[cfg(feature = "pjrt")]
    pub fn integrity(&self, blocks: &[i32]) -> Result<Option<Vec<[i32; 2]>>> {
        let name = "integrity_16x4k";
        let Some(info) = self.infos.get(name) else {
            return Ok(None);
        };
        let (b, l) = (info.input_shapes[0][0], info.input_shapes[0][1]);
        if blocks.len() != b * l {
            return Ok(None);
        }
        let lit = xla::Literal::vec1(blocks).reshape(&[b as i64, l as i64])?;
        let out = self.execute(name, &[lit])?;
        let flat: Vec<i32> = out[0].to_vec()?;
        Ok(Some(flat.chunks(2).map(|c| [c[0], c[1]]).collect()))
    }

    /// Device count of the PJRT client (diagnostics).
    pub fn device_count(&self) -> usize {
        #[cfg(feature = "pjrt")]
        {
            self.client.device_count()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            0
        }
    }
}

/// Output of [`Executor::postprocess`].
#[derive(Debug, Clone)]
pub struct PostprocessOut {
    pub energies: Vec<f32>,
    pub mask: Vec<f32>,
    /// Number of selected (high-energy) particles.
    pub selected: usize,
    /// [count, selected energy sum, max energy, mean energy] over the
    /// padded batch; use `selected` for the exact count.
    pub stats: [f32; 4],
}
