//! The pre-optimization SNS engine, preserved verbatim-in-spirit as
//! (a) the wall-clock **baseline** for the §Perf hot-path benchmark
//! (`benches/ablate_sns.rs`) and (b) the **differential-test oracle**
//! the property tests compare the zero-copy engine against
//! (`tests/prop_vectored_io.rs`).
//!
//! Characteristic costs of this engine — exactly what the §Perf work
//! in [`super::sns`] removes:
//! * `store.object()?.placement()` double map lookup per unit, per
//!   stripe, per write/read;
//! * a fresh `Vec<u8>` per data unit for every partial-stripe RMW;
//! * `chunk.to_vec()` + `resize` per 4 KiB block on persist (one heap
//!   allocation per block);
//! * `p.clone()` per extra parity unit;
//! * reads allocate a zeroed output and look blocks up one index at a
//!   time.
//!
//! Plain RAID layouts only (no mirror/compression): that is the hot
//! path under measurement. Stored state is byte-identical to the
//! optimized engine's, so reads from either engine interoperate.

use crate::error::{Result, SageError};
use crate::mero::layout::Layout;
use crate::mero::object::{Mobject, ObjectId, PlacedUnit};
use crate::mero::MeroStore;
use crate::runtime::Executor;
use crate::sim::clock::SimTime;
use crate::sim::device::{Access, DeviceKind, IoOp};

use super::sns::{compute_parity, compute_parity_slices, cpu_parity};

/// XOR costing constant (mirror of the engine's).
const XOR_BW: f64 = 5.0e9;

#[derive(Clone, Copy)]
struct Geom {
    data: u32,
    parity: u32,
    unit: u64,
    tier: DeviceKind,
}

impl Geom {
    fn stripe_width(&self) -> u64 {
        self.data as u64 * self.unit
    }
    fn units_per_stripe(&self) -> u32 {
        self.data + self.parity
    }
}

fn geom(store: &MeroStore, id: ObjectId, offset: u64) -> Result<Geom> {
    let layout = store.object(id)?.layout.clone();
    if layout.compressed() {
        return Err(SageError::Invalid(
            "sns_baseline: plain RAID layouts only".into(),
        ));
    }
    match layout.at_offset(offset) {
        Layout::Raid { data, parity, unit, tier } => Ok(Geom {
            data: *data,
            parity: *parity,
            unit: *unit,
            tier: *tier,
        }),
        _ => Err(SageError::Invalid(
            "sns_baseline: plain RAID layouts only".into(),
        )),
    }
}

/// Pre-optimization write path (borrowed payload, per-block persist).
pub fn write(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    data: &[u8],
    now: SimTime,
    exec: Option<&Executor>,
) -> Result<SimTime> {
    let len = data.len() as u64;
    if len == 0 {
        return Ok(now);
    }
    store.object(id)?.check_aligned(offset, len)?;
    let g = geom(store, id, offset)?;
    let width = g.stripe_width();
    let first_stripe = offset / width;
    let last_stripe = (offset + len - 1) / width;
    let mut done = now;

    for stripe in first_stripe..=last_stripe {
        let sbase = stripe * width;
        let wstart = offset.max(sbase);
        let wend = (offset + len).min(sbase + width);
        let full_stripe = wstart == sbase && wend == sbase + width;

        // ---- parity: fresh unit buffers per partial stripe -------------
        let parity_unit: Option<Vec<u8>> = if g.parity > 0 {
            if full_stripe {
                let slices: Vec<&[u8]> = (0..g.data)
                    .map(|u| {
                        let ustart = (sbase + u as u64 * g.unit - offset) as usize;
                        &data[ustart..ustart + g.unit as usize]
                    })
                    .collect();
                Some(compute_parity_slices(&slices, exec)?)
            } else {
                let mut units: Vec<Vec<u8>> = Vec::with_capacity(g.data as usize);
                for u in 0..g.data {
                    let ustart = sbase + u as u64 * g.unit;
                    let uend = ustart + g.unit;
                    let mut buf =
                        read_logical(store.object(id)?, ustart, g.unit);
                    let ov_start = wstart.max(ustart);
                    let ov_end = wend.min(uend);
                    if ov_start < ov_end {
                        buf[(ov_start - ustart) as usize
                            ..(ov_end - ustart) as usize]
                            .copy_from_slice(
                                &data[(ov_start - offset) as usize
                                    ..(ov_end - offset) as usize],
                            );
                    }
                    units.push(buf);
                }
                Some(compute_parity(&units, exec)?)
            }
        } else {
            None
        };

        ensure_placement(store, id, stripe, g)?;

        // ---- RMW read cost: placement looked up per unit ---------------
        let mut t_stripe = now;
        if !full_stripe {
            let mut t_read = now;
            for u in 0..g.units_per_stripe() {
                let dev = store.object(id)?.placement(stripe, u).unwrap().device;
                if !store.cluster.devices[dev].failed {
                    let t =
                        store.cluster.io(dev, now, g.unit, IoOp::Read, Access::Random);
                    t_read = t_read.max(t);
                }
            }
            t_stripe = t_read;
        }

        if g.parity > 0 {
            t_stripe += (g.data as u64 * g.unit) as f64 / XOR_BW;
        }

        // ---- unit writes: placement looked up per unit -----------------
        let mut t_done = t_stripe;
        for u in 0..g.units_per_stripe() {
            let pu = *store.object(id)?.placement(stripe, u).unwrap();
            if store.cluster.devices[pu.device].failed {
                continue;
            }
            let t_net = store.cluster.net.pt2pt(g.unit);
            let t = store
                .cluster
                .io(pu.device, t_stripe + t_net, g.unit, IoOp::Write, Access::Seq);
            t_done = t_done.max(t);
        }

        // ---- persist parity: deep clone per extra parity unit ----------
        if let Some(p) = parity_unit {
            let obj = store.object_mut(id)?;
            for pi in 0..g.parity {
                if pi + 1 == g.parity {
                    obj.put_unit(stripe, g.data + pi, p);
                    break;
                }
                obj.put_unit(stripe, g.data + pi, p.clone());
            }
        }

        done = done.max(t_done);
    }

    // ---- persist blocks: one allocation + copy per block ---------------
    {
        let obj = store.object_mut(id)?;
        let bs = obj.block_size;
        for (i, chunk) in data.chunks(bs as usize).enumerate() {
            let mut block = chunk.to_vec();
            block.resize(bs as usize, 0);
            obj.put_block(offset / bs + i as u64, block);
        }
    }

    Ok(done)
}

fn ensure_placement(
    store: &mut MeroStore,
    id: ObjectId,
    stripe: u64,
    g: Geom,
) -> Result<()> {
    if store.object(id)?.placement(stripe, 0).is_some() {
        return Ok(());
    }
    let mut used = Vec::new();
    for u in 0..g.units_per_stripe() {
        let d = store.pools.allocate(&mut store.cluster, g.tier, g.unit, &used)?;
        used.push(d);
        store.object_mut(id)?.place_unit(PlacedUnit {
            stripe,
            unit: u,
            device: d,
            size: g.unit,
            is_parity: u >= g.data,
        });
    }
    Ok(())
}

/// Pre-optimization read: zeroed output allocation + per-index block
/// lookups + per-unit placement lookups.
pub fn read(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
) -> Result<(Vec<u8>, SimTime)> {
    if len == 0 {
        return Ok((Vec::new(), now));
    }
    store.object(id)?.check_aligned(offset, len)?;
    let g = geom(store, id, offset)?;
    let width = g.stripe_width();
    let mut out = vec![0u8; len as usize];
    let mut t_done = now;

    let first_stripe = offset / width;
    let last_stripe = (offset + len - 1) / width;
    for stripe in first_stripe..=last_stripe {
        let sbase = stripe * width;
        for u in 0..g.data {
            let ustart = sbase + u as u64 * g.unit;
            let uend = ustart + g.unit;
            let ov_start = offset.max(ustart);
            let ov_end = (offset + len).min(uend);
            if ov_start >= ov_end {
                continue;
            }
            let placed = store.object(id)?.placement(stripe, u).copied();
            let Some(pu) = placed else { continue };

            let failed = store.cluster.devices[pu.device].failed;
            if !failed {
                let t = store
                    .cluster
                    .io(pu.device, now, g.unit, IoOp::Read, Access::Seq);
                let obj = store.object(id)?;
                if obj.real_blocks() > 0 {
                    copy_logical(
                        obj,
                        ov_start,
                        &mut out[(ov_start - offset) as usize
                            ..(ov_end - offset) as usize],
                    );
                }
                t_done = t_done.max(t);
                continue;
            }
            if g.parity == 0 {
                return Err(SageError::Unavailable(format!(
                    "unit ({stripe},{u}) lost and no parity"
                )));
            }
            let (bytes, t) = reconstruct_unit(store, id, stripe, u, now, g)?;
            if let Some(b) = bytes {
                let dst = (ov_start - offset) as usize..(ov_end - offset) as usize;
                let src = (ov_start - ustart) as usize..(ov_end - ustart) as usize;
                out[dst].copy_from_slice(&b[src]);
            }
            t_done = t_done.max(t);
        }
    }
    Ok((out, t_done))
}

/// Per-block-index logical read into a zeroed buffer (the old cost
/// profile: one map lookup per block index in the range).
fn copy_logical(obj: &Mobject, offset: u64, dst: &mut [u8]) {
    let bs = obj.block_size;
    let len = dst.len() as u64;
    if len == 0 {
        return;
    }
    let first = offset / bs;
    let last = (offset + len - 1) / bs;
    for b in first..=last {
        let bstart = b * bs;
        let ov_start = offset.max(bstart);
        let ov_end = (offset + len).min(bstart + bs);
        if let Some(block) = obj.block_ref(b) {
            dst[(ov_start - offset) as usize..(ov_end - offset) as usize]
                .copy_from_slice(
                    &block[(ov_start - bstart) as usize
                        ..(ov_end - bstart) as usize],
                );
        }
    }
}

fn read_logical(obj: &Mobject, offset: u64, len: u64) -> Vec<u8> {
    let mut out = vec![0u8; len as usize];
    copy_logical(obj, offset, &mut out);
    out
}

fn reconstruct_unit(
    store: &mut MeroStore,
    id: ObjectId,
    stripe: u64,
    lost: u32,
    now: SimTime,
    g: Geom,
) -> Result<(Option<Vec<u8>>, SimTime)> {
    let mut t_read = now;
    let mut survivors: Vec<Vec<u8>> = Vec::new();
    let mut have_all_payloads = store.object(id)?.real_blocks() > 0;
    let mut alive = 0;
    let mut lost_data_units = 1;
    let sbase = stripe * g.stripe_width();
    for u in 0..g.units_per_stripe() {
        if u == lost {
            continue;
        }
        let pu = *store
            .object(id)?
            .placement(stripe, u)
            .ok_or_else(|| SageError::Unavailable("missing placement".into()))?;
        if store.cluster.devices[pu.device].failed {
            if u < g.data {
                lost_data_units += 1;
            }
            continue;
        }
        alive += 1;
        let t = store
            .cluster
            .io(pu.device, now, g.unit, IoOp::Read, Access::Seq);
        t_read = t_read.max(t);
        if !have_all_payloads {
            continue;
        }
        if u < g.data {
            let obj = store.object(id)?;
            survivors.push(read_logical(obj, sbase + u as u64 * g.unit, g.unit));
        } else {
            match store.object(id)?.get_unit(stripe, u) {
                Some(b) => survivors.push(b.to_vec()),
                None => have_all_payloads = false,
            }
        }
    }
    if alive < g.data || lost_data_units > 1 {
        return Err(SageError::Unavailable(format!(
            "stripe {stripe}: {lost_data_units} data units lost, {alive} live \
             (XOR parity tolerates one data loss)"
        )));
    }
    let t = t_read + g.unit as f64 * g.data as f64 / XOR_BW;
    let payload = if have_all_payloads && !survivors.is_empty() {
        let take = g.data as usize;
        Some(cpu_parity(&survivors[..take.min(survivors.len())]))
    } else {
        None
    };
    Ok((payload, t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;
    use crate::sim::rng::SimRng;

    fn stores() -> (MeroStore, MeroStore) {
        (
            MeroStore::new(Testbed::sage_prototype().build_cluster()),
            MeroStore::new(Testbed::sage_prototype().build_cluster()),
        )
    }

    fn raid(s: &mut MeroStore, k: u32, p: u32) -> ObjectId {
        s.create_object(
            4096,
            Layout::Raid { data: k, parity: p, unit: 16384, tier: DeviceKind::Ssd },
        )
        .unwrap()
    }

    fn random_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = SimRng::new(seed);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    #[test]
    fn baseline_and_engine_agree_on_full_stripes() {
        let (mut a, mut b) = stores();
        let ida = raid(&mut a, 4, 1);
        let idb = raid(&mut b, 4, 1);
        let data = random_bytes(4 * 16384 * 2, 21);
        write(&mut a, ida, 0, &data, 0.0, None).unwrap();
        b.write_object(idb, 0, &data, 0.0, None).unwrap();
        let (ra, _) = read(&mut a, ida, 0, data.len() as u64, 1.0).unwrap();
        let (rb, _) = b.read_object(idb, 0, data.len() as u64, 1.0).unwrap();
        assert_eq!(ra, data);
        assert_eq!(rb, data);
        // cross-engine: each engine reads the other's stored state
        let (cross_a, _) = b.read_object(idb, 0, data.len() as u64, 2.0).unwrap();
        let (cross_b, _) = read(&mut a, ida, 0, data.len() as u64, 2.0).unwrap();
        assert_eq!(cross_a, cross_b);
    }

    #[test]
    fn baseline_and_engine_agree_on_rmw_and_degraded() {
        let (mut a, mut b) = stores();
        let ida = raid(&mut a, 4, 1);
        let idb = raid(&mut b, 4, 1);
        let full = random_bytes(4 * 16384, 22);
        let patch = random_bytes(8192, 23);
        write(&mut a, ida, 0, &full, 0.0, None).unwrap();
        write(&mut a, ida, 4096, &patch, 1.0, None).unwrap();
        b.write_object(idb, 0, &full, 0.0, None).unwrap();
        b.write_object(idb, 4096, &patch, 1.0, None).unwrap();
        // degrade the same logical unit in both stores
        let da = a.object(ida).unwrap().placement(0, 1).unwrap().device;
        let db = b.object(idb).unwrap().placement(0, 1).unwrap().device;
        a.cluster.fail_device(da);
        b.cluster.fail_device(db);
        let (ra, _) = read(&mut a, ida, 0, full.len() as u64, 2.0).unwrap();
        let (rb, _) = b.read_object(idb, 0, full.len() as u64, 2.0).unwrap();
        assert_eq!(ra, rb, "reconstruction must agree between engines");
    }

    #[test]
    fn baseline_rejects_non_raid() {
        let (mut a, _) = stores();
        let id = a
            .create_object(4096, Layout::Mirror { copies: 2, tier: DeviceKind::Ssd })
            .unwrap();
        assert!(write(&mut a, id, 0, &[0u8; 4096], 0.0, None).is_err());
    }
}
