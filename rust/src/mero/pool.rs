//! Tiered device pools and allocation (§3.1: "NVRAM pools that have
//! higher performance but lower capacity … drain to lower tier
//! devices").
//!
//! One pool per [`DeviceKind`]; allocation is least-utilized-first so
//! striped units spread across devices (which is what gives SNS its
//! bandwidth aggregation).
//!
//! ISSUE 10 closes the QoS→placement feedback loop: a
//! [`CongestionView`] built from the scheduler's
//! [`QosShardReport`] backlog depths is installed on the [`PoolSet`]
//! for the duration of a session, and [`PoolSet::allocate`] keys
//! lexicographically on `(backlog depth, utilization)` — so new
//! writes and repair/drain targets steer away from congested shards,
//! while an empty or uniform view ties on depth and reduces
//! bit-for-bit to the historical least-utilized ordering (the
//! no-feedback baseline stays the oracle).

use std::collections::BTreeMap;

use crate::cluster::{Cluster, DeviceId};
use crate::error::{Result, SageError};
use crate::sim::clock::SimTime;
use crate::sim::device::DeviceKind;
use crate::sim::sched::QosShardReport;

/// Per-device committed-backlog depths sampled from the cluster-wide
/// scheduler (ISSUE 10). The placement-side half of the QoS feedback
/// loop: [`PoolSet::allocate`] prefers shallower backlog before
/// utilization. Devices absent from the view read as depth 0.0, so
/// the default (empty) view never perturbs placement.
#[derive(Debug, Default, Clone)]
pub struct CongestionView {
    depths: BTreeMap<DeviceId, f64>,
}

impl CongestionView {
    /// Build a view from scheduler shard reports at virtual time
    /// `now` ([`IoScheduler::qos_report_all`] is the intended feed).
    /// Shards whose frontier has fallen at or behind the clock carry
    /// zero depth and are dropped, so back-to-back sessions produce
    /// an empty view.
    ///
    /// [`IoScheduler::qos_report_all`]:
    ///     crate::sim::sched::IoScheduler::qos_report_all
    pub fn from_reports(reports: &[QosShardReport], now: SimTime) -> Self {
        let mut depths = BTreeMap::new();
        for r in reports {
            let depth = r.backlog_depth(now);
            if depth > 0.0 {
                depths.insert(r.device, depth);
            }
        }
        CongestionView { depths }
    }

    /// Committed backlog depth of `dev` in virtual seconds (0.0 when
    /// the device is idle or unknown to the view).
    pub fn depth(&self, dev: DeviceId) -> f64 {
        self.depths.get(&dev).copied().unwrap_or(0.0)
    }

    /// True when no device carries backlog — allocation is then
    /// bit-identical to the no-feedback baseline.
    pub fn is_empty(&self) -> bool {
        self.depths.is_empty()
    }
}

/// Device pools keyed by tier/kind.
#[derive(Debug, Default)]
pub struct PoolSet {
    pools: BTreeMap<u8, (DeviceKind, Vec<DeviceId>)>,
    congestion: CongestionView,
}

impl PoolSet {
    /// Build pools from a cluster's device inventory.
    pub fn from_cluster(cluster: &Cluster) -> Self {
        let mut set = PoolSet::default();
        for (id, d) in cluster.devices.iter().enumerate() {
            if d.profile.kind == DeviceKind::Dram {
                continue; // DRAM is not a storage pool
            }
            set.pools
                .entry(d.profile.kind.tier())
                .or_insert_with(|| (d.profile.kind, Vec::new()))
                .1
                .push(id);
        }
        set
    }

    /// Register a newly-attached device into its kind's pool (elastic
    /// membership under load): allocations — foreground writes,
    /// repairs, drains — see the new capacity immediately. Existing
    /// placements are untouched until a Migration-class rebalance
    /// session moves units onto it (`sns::rebalance_onto_with`, the
    /// inverse of `sns::drain_with`). Idempotent; DRAM is never
    /// pooled.
    pub fn register(&mut self, cluster: &Cluster, dev: DeviceId) {
        let kind = cluster.devices[dev].profile.kind;
        if kind == DeviceKind::Dram {
            return;
        }
        let pool = self
            .pools
            .entry(kind.tier())
            .or_insert_with(|| (kind, Vec::new()));
        if !pool.1.contains(&dev) {
            pool.1.push(dev);
        }
    }

    /// Install the congestion view subsequent [`PoolSet::allocate`]
    /// calls steer by. [`Session::run`] installs a fresh view at
    /// adoption time and clears it at release, so the view's lifetime
    /// is exactly one session (ISSUE 10).
    ///
    /// [`Session::run`]: crate::clovis::session::Session::run
    pub fn set_congestion(&mut self, view: CongestionView) {
        self.congestion = view;
    }

    /// Drop the congestion view — allocation reverts to the
    /// no-feedback least-utilized baseline.
    pub fn clear_congestion(&mut self) {
        self.congestion = CongestionView::default();
    }

    /// The currently installed congestion view.
    pub fn congestion(&self) -> &CongestionView {
        &self.congestion
    }

    /// Devices of a tier (by kind), failed ones filtered by the caller.
    pub fn devices(&self, kind: DeviceKind) -> &[DeviceId] {
        self.pools
            .get(&kind.tier())
            .map(|(_, v)| v.as_slice())
            .unwrap_or(&[])
    }

    /// Kinds present, fastest tier first.
    pub fn tiers(&self) -> Vec<DeviceKind> {
        self.pools.values().map(|(k, _)| *k).collect()
    }

    /// The fastest tier with at least `need` free bytes on some device.
    pub fn fastest_with_space(
        &self,
        cluster: &Cluster,
        need: u64,
    ) -> Option<DeviceKind> {
        for (kind, devs) in self.pools.values() {
            if devs
                .iter()
                .any(|&d| !cluster.devices[d].failed && cluster.devices[d].free() >= need)
            {
                return Some(*kind);
            }
        }
        None
    }

    /// Allocate `size` bytes on some live device of `kind`, avoiding
    /// the devices in `exclude` (SNS: units of one stripe should land
    /// on distinct devices). Candidates are ranked lexicographically
    /// by `(congestion-view backlog depth, utilization)`: with no view
    /// installed — or a uniform one — every depth ties and the
    /// historical least-utilized-first order decides bit-for-bit;
    /// under a live view the shallowest-backlog device wins first, so
    /// new writes and rebuild targets drain away from congested
    /// shards (ISSUE 10). Liveness, free space and `exclude` are
    /// hard constraints the view can never override. When the pool is
    /// narrower than the stripe (fewer devices than units), the
    /// distinctness constraint is relaxed — the real Mero spills wide
    /// stripes across devices the same way, trading fault independence
    /// for availability.
    pub fn allocate(
        &self,
        cluster: &mut Cluster,
        kind: DeviceKind,
        size: u64,
        exclude: &[DeviceId],
    ) -> Result<DeviceId> {
        let candidates = self.devices(kind);
        let pick = |cluster: &Cluster, honor_exclude: bool| {
            candidates
                .iter()
                .copied()
                .filter(|d| {
                    let dev = &cluster.devices[*d];
                    !dev.failed
                        && dev.free() >= size
                        && (!honor_exclude || !exclude.contains(d))
                })
                .min_by(|a, b| {
                    self.congestion
                        .depth(*a)
                        .total_cmp(&self.congestion.depth(*b))
                        .then_with(|| {
                            cluster.devices[*a]
                                .utilization()
                                .total_cmp(&cluster.devices[*b].utilization())
                        })
                })
        };
        let best = pick(cluster, true)
            .or_else(|| pick(cluster, false))
            .ok_or_else(|| {
                SageError::NoSpace(format!(
                    "no {kind:?} device with {size} free"
                ))
            })?;
        cluster.devices[best].used += size;
        Ok(best)
    }

    /// Release `size` bytes on `dev`.
    pub fn release(&self, cluster: &mut Cluster, dev: DeviceId, size: u64) {
        let d = &mut cluster.devices[dev];
        d.used = d.used.saturating_sub(size);
    }

    /// Pool-wide free bytes for a tier.
    pub fn free_bytes(&self, cluster: &Cluster, kind: DeviceKind) -> u64 {
        self.devices(kind)
            .iter()
            .filter(|&&d| !cluster.devices[d].failed)
            .map(|&d| cluster.devices[d].free())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::EnclosureCompute;
    use crate::sim::device::DeviceProfile;
    use crate::sim::network::NetworkModel;
    use crate::sim::sched::N_CLASSES;

    fn cluster() -> Cluster {
        let mut c = Cluster::new(NetworkModel::fdr_infiniband());
        c.add_node(
            vec![
                DeviceProfile::nvram(1 << 20),
                DeviceProfile::ssd(1 << 30),
                DeviceProfile::ssd(1 << 30),
                DeviceProfile::hdd(1 << 40),
            ],
            EnclosureCompute { cores: 8, flops: 1e10 },
        );
        c
    }

    #[test]
    fn pools_by_tier() {
        let c = cluster();
        let p = PoolSet::from_cluster(&c);
        assert_eq!(p.devices(DeviceKind::Ssd).len(), 2);
        assert_eq!(p.devices(DeviceKind::Nvram).len(), 1);
        assert_eq!(p.tiers(), vec![DeviceKind::Nvram, DeviceKind::Ssd, DeviceKind::Hdd]);
    }

    #[test]
    fn allocate_spreads_and_excludes() {
        let mut c = cluster();
        let p = PoolSet::from_cluster(&c);
        let d1 = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
        let d2 = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[d1]).unwrap();
        assert_ne!(d1, d2);
        // least-utilized: a third unexcluded allocation balances
        let d3 = p.allocate(&mut c, DeviceKind::Ssd, 1 << 19, &[]).unwrap();
        assert!(d3 == d1 || d3 == d2);
    }

    #[test]
    fn register_grows_the_pool_under_load() {
        let mut c = cluster();
        let mut p = PoolSet::from_cluster(&c);
        assert_eq!(p.devices(DeviceKind::Ssd).len(), 2);
        let d = c.attach_device(0, DeviceProfile::ssd(1 << 30));
        p.register(&c, d);
        assert_eq!(p.devices(DeviceKind::Ssd).len(), 3);
        // idempotent
        p.register(&c, d);
        assert_eq!(p.devices(DeviceKind::Ssd).len(), 3);
        // the empty newcomer is least-utilized → next allocation lands on it
        c.devices[p.devices(DeviceKind::Ssd)[0]].used = 1 << 20;
        c.devices[p.devices(DeviceKind::Ssd)[1]].used = 1 << 20;
        let got = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
        assert_eq!(got, d);
        // a kind absent so far creates its pool
        let smr = c.attach_device(0, DeviceProfile::smr(1 << 40));
        p.register(&c, smr);
        assert_eq!(p.devices(DeviceKind::Smr), &[smr]);
        // DRAM never pools
        let dram = c.attach_device(0, DeviceProfile::dram(1 << 30, 1e11));
        p.register(&c, dram);
        assert!(p.devices(DeviceKind::Dram).is_empty());
    }

    #[test]
    fn no_space_errors() {
        let mut c = cluster();
        let p = PoolSet::from_cluster(&c);
        assert!(matches!(
            p.allocate(&mut c, DeviceKind::Nvram, 1 << 30, &[]),
            Err(SageError::NoSpace(_))
        ));
    }

    #[test]
    fn fastest_with_space_degrades() {
        let mut c = cluster();
        let p = PoolSet::from_cluster(&c);
        assert_eq!(p.fastest_with_space(&c, 1 << 10), Some(DeviceKind::Nvram));
        // fill NVRAM
        let nv = p.devices(DeviceKind::Nvram)[0];
        c.devices[nv].used = c.devices[nv].profile.capacity;
        assert_eq!(p.fastest_with_space(&c, 1 << 10), Some(DeviceKind::Ssd));
    }

    fn report(device: usize, frontier: f64) -> QosShardReport {
        QosShardReport {
            device,
            base: 0.0,
            frontier,
            class_busy: [0.0; N_CLASSES],
            class_frontier: [frontier; N_CLASSES],
            lent: [0.0; N_CLASSES],
        }
    }

    #[test]
    fn empty_and_uniform_views_leave_allocation_bit_identical() {
        // baseline: no view installed
        let mut c1 = cluster();
        let p1 = PoolSet::from_cluster(&c1);
        let baseline: Vec<DeviceId> = (0..6)
            .map(|_| p1.allocate(&mut c1, DeviceKind::Ssd, 1 << 18, &[]).unwrap())
            .collect();
        // a uniform view ties on depth everywhere → identical sequence
        let mut c2 = cluster();
        let mut p2 = PoolSet::from_cluster(&c2);
        let ssd = p2.devices(DeviceKind::Ssd).to_vec();
        p2.set_congestion(CongestionView::from_reports(
            &[report(ssd[0], 5.0), report(ssd[1], 5.0)],
            0.0,
        ));
        let uniform: Vec<DeviceId> = (0..6)
            .map(|_| p2.allocate(&mut c2, DeviceKind::Ssd, 1 << 18, &[]).unwrap())
            .collect();
        assert_eq!(uniform, baseline);
        // drained-past frontiers (now beyond every frontier) ⇒ empty view
        let drained =
            CongestionView::from_reports(&[report(ssd[0], 5.0), report(ssd[1], 3.0)], 9.0);
        assert!(drained.is_empty());
        assert_eq!(drained.depth(ssd[0]), 0.0);
        let mut c3 = cluster();
        let mut p3 = PoolSet::from_cluster(&c3);
        p3.set_congestion(drained);
        let empty: Vec<DeviceId> = (0..6)
            .map(|_| p3.allocate(&mut c3, DeviceKind::Ssd, 1 << 18, &[]).unwrap())
            .collect();
        assert_eq!(empty, baseline);
        // clear_congestion reverts to the baseline view
        p3.clear_congestion();
        assert!(p3.congestion().is_empty());
    }

    #[test]
    fn congested_shard_receives_strictly_fewer_new_units() {
        let mut c = cluster();
        let mut p = PoolSet::from_cluster(&c);
        let ssd = p.devices(DeviceKind::Ssd).to_vec();
        // ssd[0] carries committed backlog; ssd[1] is idle
        p.set_congestion(CongestionView::from_reports(&[report(ssd[0], 4.0)], 1.0));
        assert!((p.congestion().depth(ssd[0]) - 3.0).abs() < 1e-12);
        let mut counts = [0usize; 2];
        for _ in 0..8 {
            let got = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
            counts[if got == ssd[0] { 0 } else { 1 }] += 1;
        }
        // depth dominates utilization: everything steers to the idle shard
        assert_eq!(counts, [0, 8]);
    }

    #[test]
    fn rebuild_target_avoids_the_deepest_backlog_device() {
        let mut c = cluster();
        let mut p = PoolSet::from_cluster(&c);
        let extra = c.attach_device(0, DeviceProfile::ssd(1 << 30));
        p.register(&c, extra);
        let ssd = p.devices(DeviceKind::Ssd).to_vec();
        // drain re-home: source excluded, remaining targets at
        // different backlog depths — the shallower one wins even when
        // the deeper one is emptier
        c.devices[ssd[1]].used = 1 << 24;
        p.set_congestion(CongestionView::from_reports(
            &[report(ssd[1], 2.0), report(extra, 8.0)],
            0.0,
        ));
        let got = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[ssd[0]]).unwrap();
        assert_eq!(got, ssd[1]);
        assert_ne!(got, extra);
    }

    #[test]
    fn view_never_overrides_exclusion_liveness_or_spread() {
        let mut c = cluster();
        let mut p = PoolSet::from_cluster(&c);
        let ssd = p.devices(DeviceKind::Ssd).to_vec();
        p.set_congestion(CongestionView::from_reports(&[report(ssd[0], 9.0)], 0.0));
        // exclusion beats congestion: only the congested device remains
        let got = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[ssd[1]]).unwrap();
        assert_eq!(got, ssd[0]);
        // stripe-unit spread holds under the view
        let d1 = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
        let d2 = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[d1]).unwrap();
        assert_ne!(d1, d2);
        // liveness beats congestion preference: fail the idle device
        // and the congested one still serves
        c.devices[ssd[1]].failed = true;
        let got = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
        assert_eq!(got, ssd[0]);
    }

    #[test]
    fn release_returns_space() {
        let mut c = cluster();
        let p = PoolSet::from_cluster(&c);
        let before = p.free_bytes(&c, DeviceKind::Ssd);
        let d = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
        assert_eq!(p.free_bytes(&c, DeviceKind::Ssd), before - (1 << 20));
        p.release(&mut c, d, 1 << 20);
        assert_eq!(p.free_bytes(&c, DeviceKind::Ssd), before);
    }
}
