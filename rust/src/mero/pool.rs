//! Tiered device pools and allocation (§3.1: "NVRAM pools that have
//! higher performance but lower capacity … drain to lower tier
//! devices").
//!
//! One pool per [`DeviceKind`]; allocation is least-utilized-first so
//! striped units spread across devices (which is what gives SNS its
//! bandwidth aggregation).

use std::collections::BTreeMap;

use crate::cluster::{Cluster, DeviceId};
use crate::error::{Result, SageError};
use crate::sim::device::DeviceKind;

/// Device pools keyed by tier/kind.
#[derive(Debug, Default)]
pub struct PoolSet {
    pools: BTreeMap<u8, (DeviceKind, Vec<DeviceId>)>,
}

impl PoolSet {
    /// Build pools from a cluster's device inventory.
    pub fn from_cluster(cluster: &Cluster) -> Self {
        let mut set = PoolSet::default();
        for (id, d) in cluster.devices.iter().enumerate() {
            if d.profile.kind == DeviceKind::Dram {
                continue; // DRAM is not a storage pool
            }
            set.pools
                .entry(d.profile.kind.tier())
                .or_insert_with(|| (d.profile.kind, Vec::new()))
                .1
                .push(id);
        }
        set
    }

    /// Register a newly-attached device into its kind's pool (elastic
    /// membership under load): allocations — foreground writes,
    /// repairs, drains — see the new capacity immediately. Existing
    /// placements are untouched until a Migration-class rebalance
    /// session moves units onto it (`sns::rebalance_onto_with`, the
    /// inverse of `sns::drain_with`). Idempotent; DRAM is never
    /// pooled.
    pub fn register(&mut self, cluster: &Cluster, dev: DeviceId) {
        let kind = cluster.devices[dev].profile.kind;
        if kind == DeviceKind::Dram {
            return;
        }
        let pool = self
            .pools
            .entry(kind.tier())
            .or_insert_with(|| (kind, Vec::new()));
        if !pool.1.contains(&dev) {
            pool.1.push(dev);
        }
    }

    /// Devices of a tier (by kind), failed ones filtered by the caller.
    pub fn devices(&self, kind: DeviceKind) -> &[DeviceId] {
        self.pools
            .get(&kind.tier())
            .map(|(_, v)| v.as_slice())
            .unwrap_or(&[])
    }

    /// Kinds present, fastest tier first.
    pub fn tiers(&self) -> Vec<DeviceKind> {
        self.pools.values().map(|(k, _)| *k).collect()
    }

    /// The fastest tier with at least `need` free bytes on some device.
    pub fn fastest_with_space(
        &self,
        cluster: &Cluster,
        need: u64,
    ) -> Option<DeviceKind> {
        for (kind, devs) in self.pools.values() {
            if devs
                .iter()
                .any(|&d| !cluster.devices[d].failed && cluster.devices[d].free() >= need)
            {
                return Some(*kind);
            }
        }
        None
    }

    /// Allocate `size` bytes on some live device of `kind`, avoiding
    /// the devices in `exclude` (SNS: units of one stripe should land
    /// on distinct devices). Least-utilized-first. When the pool is
    /// narrower than the stripe (fewer devices than units), the
    /// distinctness constraint is relaxed — the real Mero spills wide
    /// stripes across devices the same way, trading fault independence
    /// for availability.
    pub fn allocate(
        &self,
        cluster: &mut Cluster,
        kind: DeviceKind,
        size: u64,
        exclude: &[DeviceId],
    ) -> Result<DeviceId> {
        let candidates = self.devices(kind);
        let pick = |cluster: &Cluster, honor_exclude: bool| {
            candidates
                .iter()
                .copied()
                .filter(|d| {
                    let dev = &cluster.devices[*d];
                    !dev.failed
                        && dev.free() >= size
                        && (!honor_exclude || !exclude.contains(d))
                })
                .min_by(|a, b| {
                    cluster.devices[*a]
                        .utilization()
                        .total_cmp(&cluster.devices[*b].utilization())
                })
        };
        let best = pick(cluster, true)
            .or_else(|| pick(cluster, false))
            .ok_or_else(|| {
                SageError::NoSpace(format!(
                    "no {kind:?} device with {size} free"
                ))
            })?;
        cluster.devices[best].used += size;
        Ok(best)
    }

    /// Release `size` bytes on `dev`.
    pub fn release(&self, cluster: &mut Cluster, dev: DeviceId, size: u64) {
        let d = &mut cluster.devices[dev];
        d.used = d.used.saturating_sub(size);
    }

    /// Pool-wide free bytes for a tier.
    pub fn free_bytes(&self, cluster: &Cluster, kind: DeviceKind) -> u64 {
        self.devices(kind)
            .iter()
            .filter(|&&d| !cluster.devices[d].failed)
            .map(|&d| cluster.devices[d].free())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::EnclosureCompute;
    use crate::sim::device::DeviceProfile;
    use crate::sim::network::NetworkModel;

    fn cluster() -> Cluster {
        let mut c = Cluster::new(NetworkModel::fdr_infiniband());
        c.add_node(
            vec![
                DeviceProfile::nvram(1 << 20),
                DeviceProfile::ssd(1 << 30),
                DeviceProfile::ssd(1 << 30),
                DeviceProfile::hdd(1 << 40),
            ],
            EnclosureCompute { cores: 8, flops: 1e10 },
        );
        c
    }

    #[test]
    fn pools_by_tier() {
        let c = cluster();
        let p = PoolSet::from_cluster(&c);
        assert_eq!(p.devices(DeviceKind::Ssd).len(), 2);
        assert_eq!(p.devices(DeviceKind::Nvram).len(), 1);
        assert_eq!(p.tiers(), vec![DeviceKind::Nvram, DeviceKind::Ssd, DeviceKind::Hdd]);
    }

    #[test]
    fn allocate_spreads_and_excludes() {
        let mut c = cluster();
        let p = PoolSet::from_cluster(&c);
        let d1 = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
        let d2 = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[d1]).unwrap();
        assert_ne!(d1, d2);
        // least-utilized: a third unexcluded allocation balances
        let d3 = p.allocate(&mut c, DeviceKind::Ssd, 1 << 19, &[]).unwrap();
        assert!(d3 == d1 || d3 == d2);
    }

    #[test]
    fn register_grows_the_pool_under_load() {
        let mut c = cluster();
        let mut p = PoolSet::from_cluster(&c);
        assert_eq!(p.devices(DeviceKind::Ssd).len(), 2);
        let d = c.attach_device(0, DeviceProfile::ssd(1 << 30));
        p.register(&c, d);
        assert_eq!(p.devices(DeviceKind::Ssd).len(), 3);
        // idempotent
        p.register(&c, d);
        assert_eq!(p.devices(DeviceKind::Ssd).len(), 3);
        // the empty newcomer is least-utilized → next allocation lands on it
        c.devices[p.devices(DeviceKind::Ssd)[0]].used = 1 << 20;
        c.devices[p.devices(DeviceKind::Ssd)[1]].used = 1 << 20;
        let got = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
        assert_eq!(got, d);
        // a kind absent so far creates its pool
        let smr = c.attach_device(0, DeviceProfile::smr(1 << 40));
        p.register(&c, smr);
        assert_eq!(p.devices(DeviceKind::Smr), &[smr]);
        // DRAM never pools
        let dram = c.attach_device(0, DeviceProfile::dram(1 << 30, 1e11));
        p.register(&c, dram);
        assert!(p.devices(DeviceKind::Dram).is_empty());
    }

    #[test]
    fn no_space_errors() {
        let mut c = cluster();
        let p = PoolSet::from_cluster(&c);
        assert!(matches!(
            p.allocate(&mut c, DeviceKind::Nvram, 1 << 30, &[]),
            Err(SageError::NoSpace(_))
        ));
    }

    #[test]
    fn fastest_with_space_degrades() {
        let mut c = cluster();
        let p = PoolSet::from_cluster(&c);
        assert_eq!(p.fastest_with_space(&c, 1 << 10), Some(DeviceKind::Nvram));
        // fill NVRAM
        let nv = p.devices(DeviceKind::Nvram)[0];
        c.devices[nv].used = c.devices[nv].profile.capacity;
        assert_eq!(p.fastest_with_space(&c, 1 << 10), Some(DeviceKind::Ssd));
    }

    #[test]
    fn release_returns_space() {
        let mut c = cluster();
        let p = PoolSet::from_cluster(&c);
        let before = p.free_bytes(&c, DeviceKind::Ssd);
        let d = p.allocate(&mut c, DeviceKind::Ssd, 1 << 20, &[]).unwrap();
        assert_eq!(p.free_bytes(&c, DeviceKind::Ssd), before - (1 << 20));
        p.release(&mut c, d, 1 << 20);
        assert_eq!(p.free_bytes(&c, DeviceKind::Ssd), before);
    }
}
