//! Containers: user-defined grouping of objects (§3.2.1).
//!
//! "Containers provide labelling of objects so as to provide a form of
//! virtualisation of object name space … based on performance (high
//! performance containers for objects to be stored in higher tiers) and
//! data format descriptions (HDF5 containers, NetCDF containers) …
//! also useful for performing one shot operations on objects such as
//! shipping a function to a container."

use crate::mero::object::ObjectId;
use crate::sim::device::DeviceKind;

/// Opaque container identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContainerId(pub u64);

/// Data-format description attached to a container (advanced views are
/// built on these labels, §3.2.1 "Advanced Views").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FormatLabel {
    Raw,
    Hdf5,
    NetCdf,
    Vtk,
    Posix,
    S3,
    Custom(String),
}

/// A container: label + tier hint + member objects.
#[derive(Debug)]
pub struct Container {
    pub id: ContainerId,
    pub label: String,
    /// Performance hint: preferred tier for member objects.
    pub tier_hint: Option<DeviceKind>,
    pub format: FormatLabel,
    members: Vec<ObjectId>,
}

impl Container {
    /// New container.
    pub fn new(id: ContainerId, label: &str, tier_hint: Option<DeviceKind>) -> Self {
        Container {
            id,
            label: label.to_string(),
            tier_hint,
            format: FormatLabel::Raw,
            members: Vec::new(),
        }
    }

    /// Set the data-format description.
    pub fn with_format(mut self, format: FormatLabel) -> Self {
        self.format = format;
        self
    }

    /// Add an object to the group (idempotent).
    pub fn add(&mut self, obj: ObjectId) {
        if !self.members.contains(&obj) {
            self.members.push(obj);
        }
    }

    /// Remove an object; true if it was a member.
    pub fn remove(&mut self, obj: ObjectId) -> bool {
        let before = self.members.len();
        self.members.retain(|&o| o != obj);
        self.members.len() != before
    }

    /// Member objects, in insertion order (one-shot ops iterate these).
    pub fn objects(&self) -> &[ObjectId] {
        &self.members
    }

    /// Membership test.
    pub fn contains(&self, obj: ObjectId) -> bool {
        self.members.contains(&obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn membership() {
        let mut c = Container::new(ContainerId(1), "hot-data", Some(DeviceKind::Nvram));
        c.add(ObjectId(10));
        c.add(ObjectId(11));
        c.add(ObjectId(10)); // idempotent
        assert_eq!(c.objects(), &[ObjectId(10), ObjectId(11)]);
        assert!(c.contains(ObjectId(10)));
        assert!(c.remove(ObjectId(10)));
        assert!(!c.remove(ObjectId(10)));
        assert_eq!(c.objects(), &[ObjectId(11)]);
    }

    #[test]
    fn labels() {
        let c = Container::new(ContainerId(2), "sim-output", None)
            .with_format(FormatLabel::Hdf5);
        assert_eq!(c.format, FormatLabel::Hdf5);
        assert_eq!(c.label, "sim-output");
        assert_eq!(c.tier_hint, None);
    }
}
