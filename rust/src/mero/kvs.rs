//! Mero key-value indices (§3.2.2 Clovis Access Interface).
//!
//! "A Clovis index is a key-value store. An index stores records in
//! some order … keys are unique within an index. Clovis provides GET,
//! PUT, DEL and NEXT operations on indices", each over a *set* of keys
//! (batched, as in the real API).
//!
//! At the Clovis layer every index operation is an op on the session
//! builder (`Session::idx_put/idx_get/idx_del/idx_next`): results and
//! completion stamps ride the same scheduler-backed op group as object
//! I/O, transactions and function shipping, so KV access can be
//! `.after`-chained with any other operation kind (ISSUE 4; metadata
//! carries no pool-device I/O in this model — see ROADMAP open items
//! for the device-backed cost model).

use std::collections::BTreeMap;
use std::ops::Bound;

/// Opaque index identifier.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct IndexId(pub u64);

/// An ordered key-value index.
#[derive(Debug, Default)]
pub struct KvIndex {
    pub id: IndexId,
    map: BTreeMap<Vec<u8>, Vec<u8>>,
}

impl KvIndex {
    /// New empty index.
    pub fn new(id: IndexId) -> Self {
        KvIndex { id, map: BTreeMap::new() }
    }

    // --------------------------------------------------- single-record

    /// Insert / overwrite one record.
    pub fn put(&mut self, key: Vec<u8>, val: Vec<u8>) {
        self.map.insert(key, val);
    }

    /// Lookup one key.
    pub fn get(&self, key: &[u8]) -> Option<&[u8]> {
        self.map.get(key).map(|v| v.as_slice())
    }

    /// Delete one key; true if it existed.
    pub fn del(&mut self, key: &[u8]) -> bool {
        self.map.remove(key).is_some()
    }

    // -------------------------------------------------------- batched

    /// GET: matching records for a set of keys (None for misses).
    pub fn get_batch(&self, keys: &[Vec<u8>]) -> Vec<Option<Vec<u8>>> {
        keys.iter().map(|k| self.map.get(k).cloned()).collect()
    }

    /// PUT: write/rewrite a set of records.
    pub fn put_batch(&mut self, records: Vec<(Vec<u8>, Vec<u8>)>) {
        for (k, v) in records {
            self.map.insert(k, v);
        }
    }

    /// DEL: delete all matching records; returns per-key success.
    pub fn del_batch(&mut self, keys: &[Vec<u8>]) -> Vec<bool> {
        keys.iter().map(|k| self.map.remove(k).is_some()).collect()
    }

    /// NEXT: for each given key, the record with the smallest key
    /// strictly greater than it (the paper's "set of next keys").
    pub fn next_batch(&self, keys: &[Vec<u8>]) -> Vec<Option<(Vec<u8>, Vec<u8>)>> {
        keys.iter()
            .map(|k| {
                self.map
                    .range::<Vec<u8>, _>((Bound::Excluded(k.clone()), Bound::Unbounded))
                    .next()
                    .map(|(k, v)| (k.clone(), v.clone()))
            })
            .collect()
    }

    /// Range scan from `start` (inclusive), up to `limit` records —
    /// used by gateway namespaces (pNFS) and FDMI plugins.
    pub fn scan(&self, start: &[u8], limit: usize) -> Vec<(Vec<u8>, Vec<u8>)> {
        self.map
            .range::<Vec<u8>, _>((Bound::Included(start.to_vec()), Bound::Unbounded))
            .take(limit)
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx() -> KvIndex {
        let mut i = KvIndex::new(IndexId(1));
        i.put_batch(vec![
            (b"a".to_vec(), b"1".to_vec()),
            (b"c".to_vec(), b"3".to_vec()),
            (b"e".to_vec(), b"5".to_vec()),
        ]);
        i
    }

    #[test]
    fn get_put_del() {
        let mut i = idx();
        assert_eq!(i.get(b"a"), Some(b"1".as_ref()));
        assert_eq!(i.get(b"b"), None);
        i.put(b"a".to_vec(), b"9".to_vec()); // rewrite
        assert_eq!(i.get(b"a"), Some(b"9".as_ref()));
        assert!(i.del(b"a"));
        assert!(!i.del(b"a"));
    }

    #[test]
    fn batched_ops() {
        let mut i = idx();
        let got = i.get_batch(&[b"a".to_vec(), b"x".to_vec()]);
        assert_eq!(got, vec![Some(b"1".to_vec()), None]);
        let deleted = i.del_batch(&[b"a".to_vec(), b"x".to_vec()]);
        assert_eq!(deleted, vec![true, false]);
    }

    #[test]
    fn next_is_strictly_greater() {
        let i = idx();
        let nx = i.next_batch(&[b"a".to_vec(), b"b".to_vec(), b"e".to_vec()]);
        assert_eq!(nx[0].as_ref().unwrap().0, b"c".to_vec());
        assert_eq!(nx[1].as_ref().unwrap().0, b"c".to_vec());
        assert_eq!(nx[2], None);
    }

    #[test]
    fn scan_ordered() {
        let i = idx();
        let all = i.scan(b"", 10);
        let keys: Vec<_> = all.iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec![b"a".to_vec(), b"c".to_vec(), b"e".to_vec()]);
        assert_eq!(i.scan(b"c", 1).len(), 1);
    }
}
