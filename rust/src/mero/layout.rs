//! Layouts: how storage entities map to hardware and tiers (§3.2.1).
//!
//! "A layout determines how a storage entity … is mapped to the
//! available storage hardware and tiers … RAID layouts with different
//! combinations of data and parity, compressed layouts, mirrored
//! layouts … Different portions of objects mapped to different tiers
//! can have their own layout."

use crate::error::{Result, SageError};
use crate::sim::device::DeviceKind;

/// Object layout descriptor.
#[derive(Debug, Clone, PartialEq)]
pub enum Layout {
    /// N+K parity-declustered striping (SNS). `unit` bytes per stripe
    /// unit; data+parity units rotate across the tier's devices.
    Raid { data: u32, parity: u32, unit: u64, tier: DeviceKind },
    /// N-way replication.
    Mirror { copies: u32, tier: DeviceKind },
    /// Transparent compression wrapped around an inner layout.
    Compressed { inner: Box<Layout> },
    /// Different byte ranges with their own layouts (deep-hierarchy
    /// placement: e.g. first GiB on NVRAM, rest on disk).
    Composite { extents: Vec<(u64, u64, Layout)> },
}

impl Default for Layout {
    /// 4+1 SNS over the SSD tier with 64 KiB units — a sane default for
    /// the SAGE prototype.
    fn default() -> Self {
        Layout::Raid { data: 4, parity: 1, unit: 64 * 1024, tier: DeviceKind::Ssd }
    }
}

impl Layout {
    /// Validate parameters (positive widths, pow-2 unit, sane extents).
    pub fn validate(&self) -> Result<()> {
        match self {
            Layout::Raid { data, parity, unit, .. } => {
                if *data == 0 {
                    return Err(SageError::Invalid("raid: data width 0".into()));
                }
                if *parity > 2 {
                    return Err(SageError::Invalid(
                        "raid: at most 2 parity units supported".into(),
                    ));
                }
                if !crate::util::is_pow2(*unit) {
                    return Err(SageError::Invalid(format!(
                        "raid: unit {unit} not a power of two"
                    )));
                }
                Ok(())
            }
            Layout::Mirror { copies, .. } => {
                if *copies == 0 {
                    return Err(SageError::Invalid("mirror: 0 copies".into()));
                }
                Ok(())
            }
            Layout::Compressed { inner } => inner.validate(),
            Layout::Composite { extents } => {
                if extents.is_empty() {
                    return Err(SageError::Invalid("composite: empty".into()));
                }
                let mut end = 0u64;
                for (off, len, inner) in extents {
                    if *off != end {
                        return Err(SageError::Invalid(format!(
                            "composite: extent at {off} not contiguous (expected {end})"
                        )));
                    }
                    if *len == 0 {
                        return Err(SageError::Invalid("composite: empty extent".into()));
                    }
                    inner.validate()?;
                    end = off + len;
                }
                Ok(())
            }
        }
    }

    /// The layout governing byte `offset` (descends composites and
    /// compression wrappers — the physical mapping is the inner layout).
    pub fn at_offset(&self, offset: u64) -> &Layout {
        match self {
            Layout::Composite { extents } => {
                for (off, len, inner) in extents {
                    if offset >= *off && offset < off + len {
                        return inner.at_offset(offset - off);
                    }
                }
                // past the last extent: the final extent's layout governs
                extents.last().map(|(_, _, l)| l.at_offset(0)).unwrap_or(self)
            }
            Layout::Compressed { inner } => inner.at_offset(offset),
            _ => self,
        }
    }

    /// Tier this (sub)layout targets.
    pub fn tier(&self) -> DeviceKind {
        match self {
            Layout::Raid { tier, .. } | Layout::Mirror { tier, .. } => *tier,
            Layout::Compressed { inner } => inner.tier(),
            Layout::Composite { extents } => {
                extents.first().map(|(_, _, l)| l.tier()).unwrap_or(DeviceKind::Ssd)
            }
        }
    }

    /// Storage overhead factor (bytes stored per logical byte):
    /// RAID (n+k)/n, mirror = copies, compression estimated by ratio 1
    /// (real ratio known only per-payload).
    pub fn overhead(&self) -> f64 {
        match self {
            Layout::Raid { data, parity, .. } => {
                (*data + *parity) as f64 / *data as f64
            }
            Layout::Mirror { copies, .. } => *copies as f64,
            Layout::Compressed { inner } => inner.overhead(),
            Layout::Composite { extents } => {
                // weighted mean over extents
                let total: u64 = extents.iter().map(|(_, l, _)| l).sum();
                extents
                    .iter()
                    .map(|(_, len, l)| l.overhead() * *len as f64)
                    .sum::<f64>()
                    / total.max(1) as f64
            }
        }
    }

    /// True if any layer applies compression.
    pub fn compressed(&self) -> bool {
        match self {
            Layout::Compressed { .. } => true,
            Layout::Composite { extents } => {
                extents.iter().any(|(_, _, l)| l.compressed())
            }
            _ => false,
        }
    }

    /// Stripe width in bytes (data portion) for RAID; None otherwise.
    pub fn stripe_width(&self) -> Option<u64> {
        match self {
            Layout::Raid { data, unit, .. } => Some(*data as u64 * unit),
            Layout::Compressed { inner } => inner.stripe_width(),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_valid() {
        assert!(Layout::default().validate().is_ok());
    }

    #[test]
    fn rejects_bad_params() {
        assert!(Layout::Raid { data: 0, parity: 1, unit: 4096, tier: DeviceKind::Ssd }
            .validate()
            .is_err());
        assert!(Layout::Raid { data: 4, parity: 3, unit: 4096, tier: DeviceKind::Ssd }
            .validate()
            .is_err());
        assert!(Layout::Raid { data: 4, parity: 1, unit: 5000, tier: DeviceKind::Ssd }
            .validate()
            .is_err());
        assert!(Layout::Mirror { copies: 0, tier: DeviceKind::Hdd }.validate().is_err());
    }

    #[test]
    fn composite_contiguity() {
        let good = Layout::Composite {
            extents: vec![
                (0, 1 << 20, Layout::Raid { data: 2, parity: 1, unit: 4096, tier: DeviceKind::Nvram }),
                (1 << 20, 1 << 30, Layout::default()),
            ],
        };
        assert!(good.validate().is_ok());
        let bad = Layout::Composite {
            extents: vec![(4096, 4096, Layout::default())],
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn at_offset_descends() {
        let l = Layout::Composite {
            extents: vec![
                (0, 1 << 20, Layout::Mirror { copies: 2, tier: DeviceKind::Nvram }),
                (1 << 20, 1 << 30, Layout::default()),
            ],
        };
        assert_eq!(l.at_offset(0).tier(), DeviceKind::Nvram);
        assert_eq!(l.at_offset(1 << 21).tier(), DeviceKind::Ssd);
        // past-the-end falls into the last extent
        assert_eq!(l.at_offset(1 << 40).tier(), DeviceKind::Ssd);
    }

    #[test]
    fn overhead_factors() {
        assert!((Layout::default().overhead() - 1.25).abs() < 1e-9);
        let m = Layout::Mirror { copies: 3, tier: DeviceKind::Hdd };
        assert_eq!(m.overhead(), 3.0);
    }
}
