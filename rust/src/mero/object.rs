//! Mero objects: arrays of power-of-two-sized blocks (§3.2.2).
//!
//! "A Clovis object is an array of blocks. Blocks are of a power of two
//! size bytes … objects can be read from and written to at block level
//! granularity." Block payloads live in a sparse map so petabyte-scale
//! *phantom* objects (benchmarks) carry no memory cost, while real
//! objects round-trip bytes exactly.

use std::collections::BTreeMap;

use crate::cluster::DeviceId;
use crate::error::{Result, SageError};
use crate::mero::layout::Layout;

/// Opaque object identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjectId(pub u64);

/// A stripe unit placed on a device (SNS placement record).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlacedUnit {
    /// Stripe number within the object.
    pub stripe: u64,
    /// Unit index within the stripe (data 0..k, parity k..k+p).
    pub unit: u32,
    /// Where the unit lives.
    pub device: DeviceId,
    /// Unit size in bytes.
    pub size: u64,
    /// True for parity units.
    pub is_parity: bool,
}

/// An object: metadata + sparse block payloads + SNS placement map.
#[derive(Debug)]
pub struct Mobject {
    pub id: ObjectId,
    pub block_size: u64,
    pub layout: Layout,
    /// Sparse data blocks (block index -> payload). Only blocks written
    /// through the *real* path exist here.
    blocks: BTreeMap<u64, Vec<u8>>,
    /// SNS unit placements, keyed by (stripe, unit).
    placements: BTreeMap<(u64, u32), PlacedUnit>,
    /// Unit payloads for SNS (parity units included), keyed likewise.
    /// Present only for real writes.
    unit_data: BTreeMap<(u64, u32), Vec<u8>>,
    /// Logical extent high-water mark in bytes.
    pub size: u64,
    /// CRC32 of each written block (integrity checking, §3.2.3).
    checksums: BTreeMap<u64, u32>,
}

impl Mobject {
    /// New empty object.
    pub fn new(id: ObjectId, block_size: u64, layout: Layout) -> Self {
        Mobject {
            id,
            block_size,
            layout,
            blocks: BTreeMap::new(),
            placements: BTreeMap::new(),
            unit_data: BTreeMap::new(),
            size: 0,
            checksums: BTreeMap::new(),
        }
    }

    /// Validate that (offset, len) is block-aligned.
    pub fn check_aligned(&self, offset: u64, len: u64) -> Result<()> {
        if offset % self.block_size != 0 || len % self.block_size != 0 {
            return Err(SageError::Invalid(format!(
                "unaligned I/O: offset={offset} len={len} block={}",
                self.block_size
            )));
        }
        Ok(())
    }

    /// Store a real block payload (length must equal block_size).
    pub fn put_block(&mut self, idx: u64, data: Vec<u8>) {
        debug_assert_eq!(data.len() as u64, self.block_size);
        self.checksums.insert(idx, crc32fast::hash(&data));
        self.blocks.insert(idx, data);
        self.size = self.size.max((idx + 1) * self.block_size);
    }

    /// Fetch a block; zero-filled if never written (sparse semantics).
    pub fn get_block(&self, idx: u64) -> Vec<u8> {
        self.blocks
            .get(&idx)
            .cloned()
            .unwrap_or_else(|| vec![0; self.block_size as usize])
    }

    /// Borrow a block's payload without copying (None = sparse zeros).
    pub fn block_ref(&self, idx: u64) -> Option<&[u8]> {
        self.blocks.get(&idx).map(|v| v.as_slice())
    }

    /// Verify a block against its stored checksum. Blocks never written
    /// (or phantom) trivially pass.
    pub fn verify_block(&self, idx: u64) -> Result<()> {
        if let (Some(data), Some(&sum)) =
            (self.blocks.get(&idx), self.checksums.get(&idx))
        {
            if crc32fast::hash(data) != sum {
                return Err(SageError::Integrity(format!(
                    "object {:?} block {idx} checksum mismatch",
                    self.id
                )));
            }
        }
        Ok(())
    }

    /// Corrupt a block in place (test hook for integrity checking).
    #[doc(hidden)]
    pub fn corrupt_block(&mut self, idx: u64, byte: usize) {
        if let Some(b) = self.blocks.get_mut(&idx) {
            b[byte] ^= 0xFF;
        }
    }

    /// Record an SNS unit placement.
    pub fn place_unit(&mut self, u: PlacedUnit) {
        self.placements.insert((u.stripe, u.unit), u);
    }

    /// Placement of (stripe, unit) if recorded.
    pub fn placement(&self, stripe: u64, unit: u32) -> Option<&PlacedUnit> {
        self.placements.get(&(stripe, unit))
    }

    /// All placed units.
    pub fn placed_units(&self) -> impl Iterator<Item = &PlacedUnit> {
        self.placements.values()
    }

    /// Store an SNS unit payload (real path).
    pub fn put_unit(&mut self, stripe: u64, unit: u32, data: Vec<u8>) {
        self.unit_data.insert((stripe, unit), data);
    }

    /// Fetch an SNS unit payload.
    pub fn get_unit(&self, stripe: u64, unit: u32) -> Option<&[u8]> {
        self.unit_data.get(&(stripe, unit)).map(|v| v.as_slice())
    }

    /// Drop a unit payload (e.g. the device holding it failed).
    pub fn drop_unit(&mut self, stripe: u64, unit: u32) {
        self.unit_data.remove(&(stripe, unit));
    }

    /// Number of materialized (real) blocks.
    pub fn real_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Drop all placements and unit payloads (HSM re-tiering: the next
    /// write re-places every stripe on the new tier).
    pub fn clear_placements(&mut self) {
        self.placements.clear();
        self.unit_data.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj() -> Mobject {
        Mobject::new(ObjectId(1), 4096, Layout::default())
    }

    #[test]
    fn sparse_blocks_zero_filled() {
        let mut o = obj();
        o.put_block(5, vec![9; 4096]);
        assert_eq!(o.get_block(5), vec![9; 4096]);
        assert_eq!(o.get_block(0), vec![0; 4096]);
        assert_eq!(o.size, 6 * 4096);
        assert_eq!(o.real_blocks(), 1);
    }

    #[test]
    fn alignment_enforced() {
        let o = obj();
        assert!(o.check_aligned(4096, 8192).is_ok());
        assert!(o.check_aligned(100, 4096).is_err());
        assert!(o.check_aligned(0, 100).is_err());
    }

    #[test]
    fn integrity_detects_corruption() {
        let mut o = obj();
        o.put_block(0, vec![7; 4096]);
        assert!(o.verify_block(0).is_ok());
        o.corrupt_block(0, 17);
        assert!(matches!(
            o.verify_block(0),
            Err(crate::error::SageError::Integrity(_))
        ));
    }

    #[test]
    fn unit_placement_roundtrip() {
        let mut o = obj();
        let u = PlacedUnit {
            stripe: 2,
            unit: 1,
            device: 3,
            size: 65536,
            is_parity: false,
        };
        o.place_unit(u);
        assert_eq!(o.placement(2, 1), Some(&u));
        assert_eq!(o.placement(0, 0), None);
        o.put_unit(2, 1, vec![1, 2, 3]);
        assert_eq!(o.get_unit(2, 1), Some(&[1u8, 2, 3][..]));
        o.drop_unit(2, 1);
        assert_eq!(o.get_unit(2, 1), None);
    }
}
