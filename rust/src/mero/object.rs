//! Mero objects: arrays of power-of-two-sized blocks (§3.2.2).
//!
//! "A Clovis object is an array of blocks. Blocks are of a power of two
//! size bytes … objects can be read from and written to at block level
//! granularity." Block payloads live in a sparse map so petabyte-scale
//! *phantom* objects (benchmarks) carry no memory cost, while real
//! objects round-trip bytes exactly.
//!
//! ## Zero-copy segment storage (§Perf)
//!
//! Payloads are stored as **segments**: one write extent persists as a
//! single shared buffer (`Arc<Vec<u8>>`) plus one map entry covering
//! all its blocks — a 64 MiB write costs one buffer move (owned
//! payloads, [`Mobject::put_blocks`]) or one bulk copy, not ~16k
//! per-block allocations and map inserts. Overwrites split the
//! affected segments at block granularity (head/tail keep *views* into
//! the original buffer — still no payload copies). Per-block CRC32s
//! live inline in the segment. Reads walk the few segments overlapping
//! the range and bulk-copy each overlap ([`Mobject::read_range_into`]),
//! zero-filling sparse gaps. Parity units are `Arc`-shared so
//! multi-parity layouts store one payload, not `p` deep clones.
//!
//! ## Dense sorted-run storage (ISSUE 8 §Perf)
//!
//! The segment, placement and unit-view maps are **sorted Vecs**
//! (binary-search lookup, `partition_point` range scans), not
//! BTreeMaps: at soak scale the per-entry node allocations and pointer
//! chases dominated the sim core's wall-clock time. Writes land in
//! increasing (stripe, unit) / block order on the hot path, so inserts
//! are amortized O(1) appends; overwrite splits mutate runs in place
//! (the head keeps the original buffer, only a mid-split tail bumps
//! the `Arc` refcount — reads never do). Lookup results, iteration
//! order and `Arc` sharing are bit-compatible with the BTreeMap
//! layout, pinned by this module's tests and every `prop_*` suite.

use std::sync::Arc;

use crate::cluster::DeviceId;
use crate::error::{Result, SageError};
use crate::mero::layout::Layout;

/// Opaque object identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjectId(pub u64);

/// A stripe unit placed on a device (SNS placement record).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlacedUnit {
    /// Stripe number within the object.
    pub stripe: u64,
    /// Unit index within the stripe (data 0..k, parity k..k+p).
    pub unit: u32,
    /// Where the unit lives.
    pub device: DeviceId,
    /// Unit size in bytes.
    pub size: u64,
    /// True for parity units.
    pub is_parity: bool,
}

/// A run of consecutive blocks viewing one shared write buffer.
#[derive(Debug, Clone)]
struct Segment {
    buf: Arc<Vec<u8>>,
    /// Byte offset of this segment's first block within `buf`.
    off: usize,
    /// Number of blocks covered.
    n: u64,
    /// CRC32 per covered block (`n` entries).
    crcs: Vec<u32>,
}

/// An SNS unit payload: a view into a (possibly shared) buffer.
/// Parity units of one write all view ONE per-write parity buffer
/// (§Perf: no per-stripe parity allocation, no clone per parity copy).
#[derive(Debug, Clone)]
struct UnitView {
    buf: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

/// An object: metadata + sparse block segments + SNS placement map.
#[derive(Debug)]
pub struct Mobject {
    pub id: ObjectId,
    pub block_size: u64,
    pub layout: Layout,
    /// Sparse, disjoint block segments: `(first block idx, run)` pairs
    /// sorted by first index (dense sorted-run storage, §Perf). Only
    /// blocks written through the *real* path exist here.
    blocks: Vec<(u64, Segment)>,
    /// SNS unit placements, sorted by (stripe, unit) — the order
    /// `ensure_placement` creates them in, so inserts append.
    placements: Vec<PlacedUnit>,
    /// Unit payloads for SNS (parity units included), sorted by
    /// (stripe, unit). Present only for real writes; stored as views
    /// so one per-write parity buffer serves every parity unit of
    /// every stripe.
    unit_data: Vec<((u64, u32), UnitView)>,
    /// Logical extent high-water mark in bytes.
    pub size: u64,
}

impl Mobject {
    /// New empty object.
    pub fn new(id: ObjectId, block_size: u64, layout: Layout) -> Self {
        Mobject {
            id,
            block_size,
            layout,
            blocks: Vec::new(),
            placements: Vec::new(),
            unit_data: Vec::new(),
            size: 0,
        }
    }

    /// Validate that (offset, len) is block-aligned.
    pub fn check_aligned(&self, offset: u64, len: u64) -> Result<()> {
        if offset % self.block_size != 0 || len % self.block_size != 0 {
            return Err(SageError::Invalid(format!(
                "unaligned I/O: offset={offset} len={len} block={}",
                self.block_size
            )));
        }
        Ok(())
    }

    /// Remove block coverage of `[a, b)`, splitting boundary segments.
    /// Head/tail pieces keep views into their original buffers — no
    /// payload copies, and boundary runs are mutated **in place**
    /// (truncate / re-key) instead of remove+reinsert (§Perf).
    fn carve(&mut self, a: u64, b: u64) {
        let bs = self.block_size as usize;
        // first run with key >= a
        let lo = self.blocks.partition_point(|(k, _)| *k < a);
        // left neighbor extending into [a, b): shrink it to the head
        // in place; if it reached past b, split off a tail view at b
        if lo > 0 {
            let (k, seg_end) = {
                let (k, seg) = &self.blocks[lo - 1];
                (*k, *k + seg.n)
            };
            if seg_end > a {
                let head_n = a - k;
                let tail = {
                    let seg = &mut self.blocks[lo - 1].1;
                    let tail = (seg_end > b).then(|| {
                        let skip = (b - k) as usize;
                        Segment {
                            buf: seg.buf.clone(),
                            off: seg.off + skip * bs,
                            n: seg_end - b,
                            crcs: seg.crcs[skip..].to_vec(),
                        }
                    });
                    seg.n = head_n;
                    seg.crcs.truncate(head_n as usize);
                    tail
                };
                if let Some(tail) = tail {
                    // the neighbor covered all of [a, b), so no run
                    // starts inside the range: the tail slots in right
                    // after the head
                    self.blocks.insert(lo, (b, tail));
                    return;
                }
            }
        }
        // runs starting inside [a, b): drop them; the last may extend
        // past b — re-key it to b in place as the tail
        let mut hi = self.blocks.partition_point(|(k, _)| *k < b);
        if lo < hi {
            let (k, seg) = &mut self.blocks[hi - 1];
            let seg_end = *k + seg.n;
            if seg_end > b {
                let skip = (b - *k) as usize;
                *k = b;
                seg.off += skip * bs;
                seg.n = seg_end - b;
                seg.crcs.drain(..skip);
                hi -= 1;
            }
            self.blocks.drain(lo..hi);
        }
    }

    /// Store a real block payload (length must equal block_size).
    pub fn put_block(&mut self, idx: u64, data: Vec<u8>) {
        debug_assert_eq!(data.len() as u64, self.block_size);
        self.put_blocks(idx, Arc::new(data));
    }

    /// Store a whole write extent as ONE segment sharing ONE buffer
    /// (§Perf zero-copy path). `data.len()` must be a non-zero
    /// multiple of block_size; blocks `first_idx..first_idx + n` view
    /// their slice of `data` without copying.
    pub fn put_blocks(&mut self, first_idx: u64, data: Arc<Vec<u8>>) {
        let bs = self.block_size as usize;
        debug_assert!(bs > 0 && data.len() % bs == 0);
        let n = (data.len() / bs) as u64;
        if n == 0 {
            return;
        }
        self.carve(first_idx, first_idx + n);
        let crcs: Vec<u32> =
            data.chunks_exact(bs).map(crc32fast::hash).collect();
        // carve cleared [first_idx, first_idx+n): a fresh sorted
        // insert, an O(1) append for sequential writes
        let pos = self.blocks.partition_point(|(k, _)| *k < first_idx);
        self.blocks
            .insert(pos, (first_idx, Segment { buf: data, off: 0, n, crcs }));
        self.size = self.size.max((first_idx + n) * self.block_size);
    }

    /// Index into `blocks` of the run covering `idx` (binary search).
    fn seg_pos(&self, idx: u64) -> Option<usize> {
        match self.blocks.binary_search_by(|(k, _)| k.cmp(&idx)) {
            Ok(i) => Some(i),
            Err(0) => None,
            Err(i) => {
                let (k, seg) = &self.blocks[i - 1];
                (idx < k + seg.n).then_some(i - 1)
            }
        }
    }

    /// Locate the segment covering `idx`: (first block idx, segment).
    fn segment_of(&self, idx: u64) -> Option<(u64, &Segment)> {
        self.seg_pos(idx).map(|i| {
            let (k, seg) = &self.blocks[i];
            (*k, seg)
        })
    }

    /// Fetch a block; zero-filled if never written (sparse semantics).
    pub fn get_block(&self, idx: u64) -> Vec<u8> {
        match self.block_ref(idx) {
            Some(b) => b.to_vec(),
            None => vec![0; self.block_size as usize],
        }
    }

    /// Borrow a block's payload without copying (None = sparse zeros).
    pub fn block_ref(&self, idx: u64) -> Option<&[u8]> {
        let bs = self.block_size as usize;
        self.segment_of(idx).map(|(k, seg)| {
            let start = seg.off + ((idx - k) as usize) * bs;
            &seg.buf[start..start + bs]
        })
    }

    /// Iterate the materialized blocks in `[first, last]` in index
    /// order, borrowing payloads.
    pub fn blocks_in(
        &self,
        first: u64,
        last: u64,
    ) -> impl Iterator<Item = (u64, &[u8])> {
        let bs = self.block_size as usize;
        let start = self.scan_start(first);
        self.blocks[start..]
            .iter()
            .take_while(move |(k, _)| *k <= last)
            .flat_map(move |(k, seg)| {
                let k = *k;
                (0..seg.n).filter_map(move |i| {
                    let idx = k + i;
                    if idx < first || idx > last {
                        return None;
                    }
                    let start = seg.off + i as usize * bs;
                    Some((idx, &seg.buf[start..start + bs]))
                })
            })
    }

    /// Index of the first run a scan over blocks `>= first` must
    /// visit: the run covering `first` when one does, else the first
    /// run starting at or after it.
    fn scan_start(&self, first: u64) -> usize {
        let mut start = self.blocks.partition_point(|(k, _)| *k < first);
        if start > 0 {
            let (k, seg) = &self.blocks[start - 1];
            if k + seg.n > first {
                start -= 1;
            }
        }
        start
    }

    /// Fill `dst` with the logical bytes at `offset`: every byte of
    /// `dst` is written — segment overlaps are bulk-copied (one memcpy
    /// per segment, §Perf), sparse gaps zero-filled. `offset`/`len`
    /// need not be block-aligned.
    pub fn read_range_into(&self, offset: u64, dst: &mut [u8]) {
        let bs = self.block_size;
        let len = dst.len() as u64;
        if len == 0 {
            return;
        }
        let first = offset / bs;
        let last = (offset + len - 1) / bs;
        let start = self.scan_start(first);
        let mut cursor = 0usize; // next byte of dst not yet written
        for (k, seg) in self.blocks[start..]
            .iter()
            .take_while(|(k, _)| *k <= last)
        {
            let k = *k;
            let byte_start = (k * bs).max(offset);
            let byte_end = ((k + seg.n) * bs).min(offset + len);
            if byte_start >= byte_end {
                continue;
            }
            let d0 = (byte_start - offset) as usize;
            let d1 = (byte_end - offset) as usize;
            if d0 > cursor {
                dst[cursor..d0].fill(0); // sparse gap
            }
            let src = seg.off + (byte_start - k * bs) as usize;
            dst[d0..d1].copy_from_slice(&seg.buf[src..src + (d1 - d0)]);
            cursor = d1;
        }
        if cursor < dst.len() {
            dst[cursor..].fill(0);
        }
    }

    /// Verify a block against its stored checksum. Blocks never written
    /// (or phantom) trivially pass.
    pub fn verify_block(&self, idx: u64) -> Result<()> {
        let bs = self.block_size as usize;
        if let Some((k, seg)) = self.segment_of(idx) {
            let i = (idx - k) as usize;
            let start = seg.off + i * bs;
            if crc32fast::hash(&seg.buf[start..start + bs]) != seg.crcs[i] {
                return Err(SageError::Integrity(format!(
                    "object {:?} block {idx} checksum mismatch",
                    self.id
                )));
            }
        }
        Ok(())
    }

    /// Corrupt a block in place (test hook for integrity checking).
    /// The block is re-homed to a private single-block segment that
    /// keeps the ORIGINAL checksum, so sibling blocks sharing the
    /// write buffer are unaffected and verification now fails.
    #[doc(hidden)]
    pub fn corrupt_block(&mut self, idx: u64, byte: usize) {
        let bs = self.block_size as usize;
        let (own, old_crc) = match self.segment_of(idx) {
            Some((k, seg)) => {
                let i = (idx - k) as usize;
                let start = seg.off + i * bs;
                (seg.buf[start..start + bs].to_vec(), seg.crcs[i])
            }
            None => return,
        };
        let mut own = own;
        own[byte] ^= 0xFF;
        self.carve(idx, idx + 1);
        let pos = self.blocks.partition_point(|(k, _)| *k < idx);
        self.blocks.insert(
            pos,
            (
                idx,
                Segment {
                    buf: Arc::new(own),
                    off: 0,
                    n: 1,
                    crcs: vec![old_crc],
                },
            ),
        );
    }

    /// Record an SNS unit placement. Placements are kept sorted by
    /// (stripe, unit) — the order `ensure_placement` creates them in,
    /// so the common case is an O(1) append; re-placing an existing
    /// unit overwrites it in place.
    pub fn place_unit(&mut self, u: PlacedUnit) {
        let key = (u.stripe, u.unit);
        match self
            .placements
            .binary_search_by(|p| (p.stripe, p.unit).cmp(&key))
        {
            Ok(i) => self.placements[i] = u,
            Err(i) => self.placements.insert(i, u),
        }
    }

    /// Placement of (stripe, unit) if recorded (binary search).
    pub fn placement(&self, stripe: u64, unit: u32) -> Option<&PlacedUnit> {
        self.placements
            .binary_search_by(|p| (p.stripe, p.unit).cmp(&(stripe, unit)))
            .ok()
            .map(|i| &self.placements[i])
    }

    /// All placed units, in (stripe, unit) order.
    pub fn placed_units(&self) -> impl Iterator<Item = &PlacedUnit> {
        self.placements.iter()
    }

    /// Store an SNS unit payload (real path). Accepts an owned `Vec`
    /// or an `Arc` already shared with sibling parity units; the whole
    /// buffer becomes the unit's payload.
    pub fn put_unit<T: Into<Arc<Vec<u8>>>>(&mut self, stripe: u64, unit: u32, data: T) {
        let buf: Arc<Vec<u8>> = data.into();
        let len = buf.len();
        self.set_unit_view(stripe, unit, UnitView { buf, off: 0, len });
    }

    /// Sorted insert-or-replace into the unit-view table (the common
    /// case — units written in (stripe, unit) order — appends).
    fn set_unit_view(&mut self, stripe: u64, unit: u32, view: UnitView) {
        let key = (stripe, unit);
        match self.unit_data.binary_search_by(|(k, _)| k.cmp(&key)) {
            Ok(i) => self.unit_data[i].1 = view,
            Err(i) => self.unit_data.insert(i, (key, view)),
        }
    }

    /// Store an SNS unit payload as a VIEW into a shared buffer
    /// (§Perf: every parity unit of a multi-stripe write views one
    /// per-write parity buffer — one allocation per write, not one per
    /// stripe per parity copy).
    pub fn put_unit_view(
        &mut self,
        stripe: u64,
        unit: u32,
        buf: Arc<Vec<u8>>,
        off: usize,
        len: usize,
    ) {
        debug_assert!(off + len <= buf.len(), "unit view out of bounds");
        self.set_unit_view(stripe, unit, UnitView { buf, off, len });
    }

    /// Fetch an SNS unit payload (binary search, borrowed — the read
    /// path never bumps the buffer's refcount).
    pub fn get_unit(&self, stripe: u64, unit: u32) -> Option<&[u8]> {
        self.unit_data
            .binary_search_by(|(k, _)| k.cmp(&(stripe, unit)))
            .ok()
            .map(|i| {
                let v = &self.unit_data[i].1;
                &v.buf[v.off..v.off + v.len]
            })
    }

    /// Drop a unit payload (e.g. the device holding it failed).
    pub fn drop_unit(&mut self, stripe: u64, unit: u32) {
        if let Ok(i) = self
            .unit_data
            .binary_search_by(|(k, _)| k.cmp(&(stripe, unit)))
        {
            self.unit_data.remove(i);
        }
    }

    /// Number of materialized (real) blocks.
    pub fn real_blocks(&self) -> usize {
        self.blocks.iter().map(|(_, s)| s.n as usize).sum()
    }

    /// Drop all placements and unit payloads (HSM re-tiering: the next
    /// write re-places every stripe on the new tier).
    pub fn clear_placements(&mut self) {
        self.placements.clear();
        self.unit_data.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj() -> Mobject {
        Mobject::new(ObjectId(1), 4096, Layout::default())
    }

    #[test]
    fn sparse_blocks_zero_filled() {
        let mut o = obj();
        o.put_block(5, vec![9; 4096]);
        assert_eq!(o.get_block(5), vec![9; 4096]);
        assert_eq!(o.get_block(0), vec![0; 4096]);
        assert_eq!(o.size, 6 * 4096);
        assert_eq!(o.real_blocks(), 1);
    }

    #[test]
    fn alignment_enforced() {
        let o = obj();
        assert!(o.check_aligned(4096, 8192).is_ok());
        assert!(o.check_aligned(100, 4096).is_err());
        assert!(o.check_aligned(0, 100).is_err());
    }

    #[test]
    fn integrity_detects_corruption() {
        let mut o = obj();
        o.put_block(0, vec![7; 4096]);
        assert!(o.verify_block(0).is_ok());
        o.corrupt_block(0, 17);
        assert!(matches!(
            o.verify_block(0),
            Err(crate::error::SageError::Integrity(_))
        ));
    }

    #[test]
    fn unit_placement_roundtrip() {
        let mut o = obj();
        let u = PlacedUnit {
            stripe: 2,
            unit: 1,
            device: 3,
            size: 65536,
            is_parity: false,
        };
        o.place_unit(u);
        assert_eq!(o.placement(2, 1), Some(&u));
        assert_eq!(o.placement(0, 0), None);
        o.put_unit(2, 1, vec![1, 2, 3]);
        assert_eq!(o.get_unit(2, 1), Some(&[1u8, 2, 3][..]));
        o.drop_unit(2, 1);
        assert_eq!(o.get_unit(2, 1), None);
    }

    #[test]
    fn unit_views_share_one_buffer() {
        let mut o = obj();
        // one per-write parity buffer; two stripes' parity as views
        let buf = Arc::new(vec![5u8; 2 * 1024]);
        o.put_unit_view(0, 2, buf.clone(), 0, 1024);
        o.put_unit_view(1, 2, buf.clone(), 1024, 1024);
        assert_eq!(Arc::strong_count(&buf), 3, "views, not clones");
        assert_eq!(o.get_unit(0, 2).unwrap().len(), 1024);
        assert_eq!(
            o.get_unit(0, 2).unwrap().as_ptr() as usize + 1024,
            o.get_unit(1, 2).unwrap().as_ptr() as usize,
            "adjacent views into the same allocation"
        );
        o.drop_unit(0, 2);
        assert!(o.get_unit(0, 2).is_none());
        assert!(o.get_unit(1, 2).is_some());
    }

    #[test]
    fn put_blocks_shares_one_buffer() {
        let mut o = obj();
        let mut payload = vec![0u8; 4 * 4096];
        for (i, b) in payload.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        let buf = Arc::new(payload.clone());
        o.put_blocks(2, buf.clone());
        // one segment view + the caller's handle — no deep copies
        assert_eq!(Arc::strong_count(&buf), 2);
        assert_eq!(o.real_blocks(), 4);
        assert_eq!(o.size, 6 * 4096);
        for i in 0..4u64 {
            let want = &payload[i as usize * 4096..(i as usize + 1) * 4096];
            assert_eq!(o.block_ref(2 + i), Some(want));
            assert!(o.verify_block(2 + i).is_ok());
        }
        assert_eq!(o.block_ref(1), None);
        assert_eq!(o.block_ref(6), None);
    }

    #[test]
    fn blocks_in_walks_range_in_order() {
        let mut o = obj();
        o.put_blocks(1, Arc::new(vec![1u8; 2 * 4096]));
        o.put_block(7, vec![7u8; 4096]);
        let seen: Vec<u64> = o.blocks_in(0, 10).map(|(i, _)| i).collect();
        assert_eq!(seen, vec![1, 2, 7]);
        let seen: Vec<u64> = o.blocks_in(2, 6).map(|(i, _)| i).collect();
        assert_eq!(seen, vec![2]);
    }

    #[test]
    fn overwrite_splits_segments_without_copying_payloads() {
        let mut o = obj();
        let base = Arc::new(vec![1u8; 6 * 4096]);
        o.put_blocks(0, base.clone());
        // overwrite blocks 2..4: head [0,2), new [2,4), tail [4,6)
        o.put_blocks(2, Arc::new(vec![9u8; 2 * 4096]));
        // head and tail still VIEW the original buffer (no deep copy)
        assert_eq!(Arc::strong_count(&base), 3, "base + head + tail views");
        assert_eq!(o.real_blocks(), 6);
        for i in [0u64, 1, 4, 5] {
            assert_eq!(o.block_ref(i).unwrap()[0], 1, "block {i}");
            assert!(o.verify_block(i).is_ok(), "block {i}");
        }
        for i in [2u64, 3] {
            assert_eq!(o.block_ref(i).unwrap()[0], 9, "block {i}");
            assert!(o.verify_block(i).is_ok(), "block {i}");
        }
    }

    #[test]
    fn read_range_into_bulk_copies_and_zero_fills() {
        let mut o = obj();
        let mut payload = vec![0u8; 2 * 4096];
        for (i, b) in payload.iter_mut().enumerate() {
            *b = (i % 253) as u8;
        }
        o.put_blocks(1, Arc::new(payload.clone()));
        // dirty destination spanning [0, 4) blocks
        let mut dst = vec![0xEEu8; 4 * 4096];
        o.read_range_into(0, &mut dst);
        assert_eq!(&dst[..4096], &vec![0u8; 4096][..], "gap before");
        assert_eq!(&dst[4096..3 * 4096], &payload[..]);
        assert_eq!(&dst[3 * 4096..], &vec![0u8; 4096][..], "gap after");
        // unaligned sub-range
        let mut small = vec![0xEEu8; 100];
        o.read_range_into(4096 + 50, &mut small);
        assert_eq!(&small[..], &payload[50..150]);
    }

    #[test]
    fn corrupting_one_shared_block_spares_siblings() {
        let mut o = obj();
        o.put_blocks(0, Arc::new(vec![3u8; 3 * 4096]));
        o.corrupt_block(1, 0);
        assert!(o.verify_block(0).is_ok());
        assert!(o.verify_block(1).is_err());
        assert!(o.verify_block(2).is_ok());
        assert_eq!(o.block_ref(0).unwrap()[0], 3);
        assert_eq!(o.block_ref(1).unwrap()[0], 3 ^ 0xFF);
        assert_eq!(o.real_blocks(), 3);
    }

    #[test]
    fn placements_sort_regardless_of_insert_order() {
        // dense sorted-Vec placements must iterate in (stripe, unit)
        // order no matter the insertion order (old BTreeMap semantics)
        let mut o = obj();
        let mk = |stripe, unit| PlacedUnit {
            stripe,
            unit,
            device: (stripe * 3 + unit as u64) as DeviceId,
            size: 1024,
            is_parity: false,
        };
        for (s, u) in [(2, 1), (0, 0), (2, 0), (1, 2), (0, 1)] {
            o.place_unit(mk(s, u));
        }
        let order: Vec<(u64, u32)> =
            o.placed_units().map(|p| (p.stripe, p.unit)).collect();
        assert_eq!(order, vec![(0, 0), (0, 1), (1, 2), (2, 0), (2, 1)]);
        // re-placing overwrites in place, no duplicate rows
        let moved = PlacedUnit { device: 9, ..mk(1, 2) };
        o.place_unit(moved);
        assert_eq!(o.placed_units().count(), 5);
        assert_eq!(o.placement(1, 2), Some(&moved));
    }

    #[test]
    fn unit_views_sort_and_replace_regardless_of_insert_order() {
        let mut o = obj();
        o.put_unit(3, 0, vec![3u8; 8]);
        o.put_unit(0, 1, vec![1u8; 8]);
        o.put_unit(0, 0, vec![0u8; 8]);
        assert_eq!(o.get_unit(0, 0).unwrap()[0], 0);
        assert_eq!(o.get_unit(0, 1).unwrap()[0], 1);
        assert_eq!(o.get_unit(3, 0).unwrap()[0], 3);
        // rewrite replaces the view rather than stacking a duplicate
        o.put_unit(0, 1, vec![7u8; 4]);
        assert_eq!(o.get_unit(0, 1), Some(&[7u8, 7, 7, 7][..]));
        o.drop_unit(0, 0);
        assert!(o.get_unit(0, 0).is_none());
        assert!(o.get_unit(0, 1).is_some());
    }

    #[test]
    fn out_of_order_block_writes_keep_sorted_runs() {
        // writes landing out of block order still read back in order
        let mut o = obj();
        o.put_block(9, vec![9u8; 4096]);
        o.put_block(1, vec![1u8; 4096]);
        o.put_blocks(4, Arc::new(vec![4u8; 2 * 4096]));
        let seen: Vec<u64> = o.blocks_in(0, 20).map(|(i, _)| i).collect();
        assert_eq!(seen, vec![1, 4, 5, 9]);
        assert_eq!(o.real_blocks(), 4);
        for (i, v) in [(1u64, 1u8), (4, 4), (5, 4), (9, 9)] {
            assert_eq!(o.block_ref(i).unwrap()[0], v);
            assert!(o.verify_block(i).is_ok());
        }
    }

    #[test]
    fn carve_three_way_overlap_patterns() {
        // overwrite straddling two runs exercises both the left-
        // neighbor shrink and the in-range tail re-key paths
        let mut o = obj();
        o.put_blocks(0, Arc::new(vec![1u8; 3 * 4096])); // [0,3)
        o.put_blocks(4, Arc::new(vec![2u8; 3 * 4096])); // [4,7)
        o.put_blocks(2, Arc::new(vec![9u8; 3 * 4096])); // [2,5)
        let vals: Vec<u8> =
            (0..7).map(|i| o.block_ref(i).unwrap()[0]).collect();
        assert_eq!(vals, vec![1, 1, 9, 9, 9, 2, 2]);
        assert_eq!(o.real_blocks(), 7);
        for i in 0..7 {
            assert!(o.verify_block(i).is_ok(), "block {i}");
        }
        // exact-cover overwrite of a whole run leaves no stale tail
        o.put_blocks(4, Arc::new(vec![5u8; 4096]));
        assert_eq!(o.block_ref(4).unwrap()[0], 5);
        assert_eq!(o.real_blocks(), 7);
    }

    #[test]
    fn overwrite_replaces_block_view() {
        let mut o = obj();
        o.put_blocks(0, Arc::new(vec![1u8; 2 * 4096]));
        o.put_block(0, vec![9u8; 4096]);
        assert_eq!(o.block_ref(0).unwrap()[0], 9);
        assert_eq!(o.block_ref(1).unwrap()[0], 1);
        assert!(o.verify_block(0).is_ok());
        assert!(o.verify_block(1).is_ok());
    }
}
