//! Server Network Striping: Mero's distributed RAID (§3.2.1).
//!
//! Objects with a [`Layout::Raid`] are split into stripes of `data`
//! units plus `parity` XOR units; units of one stripe land on distinct
//! devices of the layout's tier, with the parity position rotating per
//! stripe (RAID-5 style declustering). Reads reconstruct through parity
//! when devices have failed; [`repair`] rebuilds a failed device's
//! units onto survivors (driven by the HA subsystem).
//!
//! The parity hot-spot is the L1 Pallas kernel (`parity_k4`/`parity_k8`
//! artifacts) executed via PJRT when an [`Executor`] is supplied;
//! otherwise a CPU XOR fallback computes the same bytes. Virtual-time
//! cost is always modelled from the enclosure's compute capability —
//! wall-clock kernel time on the build machine is not a TPU proxy.
//!
//! ## Sharded op execution (ISSUE 2 tentpole)
//!
//! Device time is no longer accounted with a direct `io()` call per
//! unit: the engine **dispatches** unit I/Os onto an
//! [`IoScheduler`] — per-device submission queues with completion
//! frontiers (`sim::sched`) — in one pass over the placement plan, and
//! drains the shards per phase (RMW reads, then unit writes). Units on
//! different devices overlap in virtual time; a slow or degraded
//! device only delays the stripes that actually queue on it; the
//! operation completes at the max over per-device frontiers. The
//! `*_with` entry points ([`write_with`], [`read_with`],
//! [`read_into_with`]) accept an external scheduler so a whole Clovis
//! op group shares one set of shards; the plain entry points wrap a
//! private scheduler for self-contained calls. `sns_serial` preserves
//! the de-sharded engine (serial-fold completion, one `io()` per unit)
//! as the differential oracle and scheduling baseline.
//!
//! ## Scheduler-driven recovery plane (ISSUE 3 tentpole)
//!
//! Recovery traffic is a first-class scheduled workload, not a serial
//! fold of direct `io()` calls:
//!
//! * **degraded reads** — the degraded path of the RAID read plans
//!   every stripe's survivor reads up front (`plan_reconstruct`),
//!   submits them to per-device shards in ONE pass, drains once, and
//!   XOR-reconstructs from the completed buffers. Reconstructions of
//!   different stripes overlap in virtual time instead of chaining
//!   behind each other.
//! * **repair** — [`repair_with`] rebuilds a failed device in two
//!   phases on ONE scheduler: phase A submits the survivor reads of
//!   every lost unit across ALL objects, phase B allocates replacement
//!   homes and submits the rebuild writes at each unit's
//!   reconstruction frontier — so writes stream onto target devices
//!   while survivor reads of later stripes are still in flight.
//! * **proactive drain** — [`drain_with`] executes the HA subsystem's
//!   `RepairAction::ProactiveDrain` on the same two-phase shape: every
//!   unit resident on a degrading (still-live) device is read off it
//!   in one pass and rewritten elsewhere at its own read frontier — no
//!   reconstruction, because the source still serves reads.
//! * **oracle** — `sns_serial` keeps the serial-fold timings
//!   (`sns_serial::read`, `sns_serial::repair`) as the differential
//!   baseline; `tests/prop_repair.rs` proves byte-identity and
//!   sharded-completion <= serial on every sampled geometry, and
//!   `benches/ablate_repair.rs` measures the gap.
//!
//! Both engines reconstruct through the one shared planner
//! (`plan_reconstruct`), which separates *what to read and which bytes
//! come back* from *when the reads complete*.
//!
//! Recovery traffic is also **QoS-classed** (ISSUE 5; §3.2.1 repair
//! throttling): [`repair_with`] and [`drain_with`] stamp every
//! submission [`TrafficClass::Repair`], and the degraded read path
//! tags its survivor reads likewise — so when the caller's scheduler
//! carries a bandwidth split (`sim::sched::QosConfig`, as every Clovis
//! session's does), rebuild traffic is capped at its configured share
//! of each device instead of starving foreground I/O. The private
//! schedulers of the self-contained entry points enforce no split, so
//! the oracles and the `prop_repair`/`ablate_repair` comparisons keep
//! their pre-QoS timings bit-exactly.
//!
//! ## §Perf: the zero-copy batched write/read engine
//!
//! The hot path avoids per-stripe and per-unit map traffic and buffer
//! churn:
//! * a **placement plan** (flat `Vec<PlanUnit>`) is computed once per
//!   write/read, replacing the per-unit `store.object()?.placement()`
//!   double map lookup of the old engine;
//! * partial-stripe RMW reuses one **scratch unit buffer set** across
//!   stripes instead of allocating `data` fresh `Vec<u8>`s per stripe;
//! * parity for the WHOLE write is computed into **one per-write
//!   parity buffer**; every parity unit of every stripe is a *view*
//!   into it ([`Mobject::put_unit_view`]) — one allocation per write,
//!   never a clone per unit or per stripe;
//! * device accounting is **batched**: shard submissions coalesce into
//!   device-contiguous runs, one `io_run()` call per run instead of
//!   one `io()` per unit;
//! * the logical bytes of a write persist as **one shared buffer**
//!   ([`Mobject::put_blocks`]): zero-copy for [`Payload::Owned`]
//!   (persist-by-move), one bulk copy for [`Payload::Real`];
//! * [`read_into`] fills a caller-provided buffer — no per-read
//!   allocation, and the healthy path is a single ordered walk of the
//!   block map instead of a lookup per block.
//!
//! `sns_baseline` preserves the pre-PR-1 engine as the zero-copy
//! differential-test oracle and allocation baseline.

use std::sync::Arc;

use crate::error::{Result, SageError};
use crate::mero::layout::Layout;
use crate::mero::object::{Mobject, ObjectId, PlacedUnit};
use crate::mero::MeroStore;
use crate::runtime::Executor;
use crate::sim::clock::SimTime;
use crate::sim::device::{Access, DeviceKind, IoOp};
use crate::sim::sched::{IoScheduler, Ticket, TrafficClass};

/// Real bytes (borrowed or owned) or a phantom length (time/placement
/// accounting only). [`Payload::Owned`] enables persist-by-move: the
/// buffer becomes the object's block storage without a copy.
pub enum Payload<'a> {
    Real(&'a [u8]),
    Owned(Vec<u8>),
    Phantom(u64),
}

impl Payload<'_> {
    fn len(&self) -> u64 {
        match self {
            Payload::Real(d) => d.len() as u64,
            Payload::Owned(d) => d.len() as u64,
            Payload::Phantom(l) => *l,
        }
    }
    /// Borrow the real bytes (None for phantom payloads).
    fn bytes(&self) -> Option<&[u8]> {
        match self {
            Payload::Real(d) => Some(d),
            Payload::Owned(d) => Some(d.as_slice()),
            Payload::Phantom(_) => None,
        }
    }
}

/// XOR throughput of the in-enclosure compute path, bytes/s. Used for
/// virtual-time costing of parity generation and reconstruction.
const XOR_BW: f64 = 5.0e9;

/// Write `payload` at `offset` of object `id` as a self-contained op
/// (private scheduler). Returns completion time.
pub fn write(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    payload: Payload<'_>,
    now: SimTime,
    exec: Option<&Executor>,
) -> Result<SimTime> {
    let mut sched = IoScheduler::new();
    write_with(store, id, offset, payload, now, exec, &mut sched)
}

/// Write `payload` at `offset`, dispatching device I/O onto `sched` —
/// the shared per-device shards of the caller's op group (sharded op
/// execution; see the module docs). Returns this op's completion time;
/// the group completion is `sched.wait_all()`.
pub fn write_with(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    payload: Payload<'_>,
    now: SimTime,
    exec: Option<&Executor>,
    sched: &mut IoScheduler,
) -> Result<SimTime> {
    let len = payload.len();
    if len == 0 {
        return Ok(now);
    }
    let (_block_size, layout) = {
        let obj = store.object(id)?;
        obj.check_aligned(offset, len)?;
        (obj.block_size, obj.layout.clone())
    };
    if layout.compressed() && offset != 0 {
        return Err(SageError::Invalid(
            "compressed layouts support whole-object writes only".into(),
        ));
    }

    // Transparent compression: stripe the deflated bytes.
    let payload = if layout.compressed() {
        match payload {
            Payload::Real(d) => Payload::Owned(deflate(d)),
            Payload::Owned(d) => Payload::Owned(deflate(&d)),
            Payload::Phantom(l) => Payload::Phantom(estimate_compressed(l)),
        }
    } else {
        payload
    };

    match layout.at_offset(offset).clone() {
        Layout::Raid { data, parity, unit, tier } => write_raid(
            store, id, offset, payload, now, exec,
            RaidGeom { data, parity, unit, tier },
            sched,
        ),
        Layout::Mirror { copies, tier } => {
            write_mirror(store, id, offset, payload, now, copies, tier, sched)
        }
        other => Err(SageError::Invalid(format!(
            "unsupported write layout {other:?}"
        ))),
    }
}

/// RAID stripe geometry (shared with the `sns_serial` oracle).
#[derive(Clone, Copy)]
pub(crate) struct RaidGeom {
    pub(crate) data: u32,
    pub(crate) parity: u32,
    pub(crate) unit: u64,
    pub(crate) tier: DeviceKind,
}

impl RaidGeom {
    pub(crate) fn stripe_width(&self) -> u64 {
        self.data as u64 * self.unit
    }
    pub(crate) fn units_per_stripe(&self) -> u32 {
        self.data + self.parity
    }
    /// RAID-5 rotation: device-slot of logical unit `u` in `stripe`.
    fn rotate(&self, stripe: u64, u: u32) -> u32 {
        ((u as u64 + stripe) % self.units_per_stripe() as u64) as u32
    }
}

/// One unit of a write/read placement plan: the per-unit facts the hot
/// loops need, gathered in a single pass (§Perf).
#[derive(Clone, Copy)]
struct PlanUnit {
    device: usize,
    failed: bool,
    placed: bool,
}

/// Flat placement plan for `stripes` x `units_per_stripe`, stripe-major.
fn build_plan(
    store: &MeroStore,
    id: ObjectId,
    first_stripe: u64,
    last_stripe: u64,
    g: RaidGeom,
) -> Result<Vec<PlanUnit>> {
    let ups = g.units_per_stripe();
    let n = (last_stripe - first_stripe + 1) as usize * ups as usize;
    let mut plan = Vec::with_capacity(n);
    let obj = store.object(id)?;
    for stripe in first_stripe..=last_stripe {
        for u in 0..ups {
            match obj.placement(stripe, u) {
                Some(pu) => plan.push(PlanUnit {
                    device: pu.device,
                    failed: store.cluster.devices[pu.device].failed,
                    placed: true,
                }),
                None => plan.push(PlanUnit {
                    device: 0,
                    failed: false,
                    placed: false,
                }),
            }
        }
    }
    Ok(plan)
}

#[allow(clippy::too_many_arguments)]
fn write_raid(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    payload: Payload<'_>,
    now: SimTime,
    exec: Option<&Executor>,
    g: RaidGeom,
    sched: &mut IoScheduler,
) -> Result<SimTime> {
    let len = payload.len();
    let width = g.stripe_width();
    let first_stripe = offset / width;
    let last_stripe = (offset + len - 1) / width;
    let ups = g.units_per_stripe() as usize;
    let n_stripes = (last_stripe - first_stripe + 1) as usize;
    let unit_len = g.unit as usize;

    // ---- placement (first touch) + plan: once per write, not per unit
    for stripe in first_stripe..=last_stripe {
        ensure_placement(store, id, stripe, g)?;
    }
    let plan = build_plan(store, id, first_stripe, last_stripe, g)?;

    // ---- phase A: dispatch EVERY partial stripe's RMW reads to their
    // home-device shards in one pass, then drain — reads of different
    // stripes overlap in virtual time instead of queueing behind the
    // previous stripe's writes (sharded op execution).
    let mut rmw: Vec<(usize, Ticket)> = Vec::new();
    for si in 0..n_stripes {
        let stripe = first_stripe + si as u64;
        let sbase = stripe * width;
        let wstart = offset.max(sbase);
        let wend = (offset + len).min(sbase + width);
        if wstart == sbase && wend == sbase + width {
            continue; // full stripe: no RMW
        }
        // must read old data units + parity to recompute parity
        for pu in &plan[si * ups..][..ups] {
            if pu.placed && !pu.failed {
                rmw.push((
                    si,
                    sched.submit(pu.device, now, g.unit, IoOp::Read, Access::Random),
                ));
            }
        }
    }
    sched.drain(&mut store.cluster.devices);
    // per-stripe RMW read frontier (max completion of its reads)
    let mut t_read = vec![now; n_stripes];
    for (si, ticket) in &rmw {
        t_read[*si] = t_read[*si].max(sched.completion(*ticket));
    }

    // Parity for the whole write lands in ONE buffer; parity units
    // become views into it (§Perf: one allocation per write).
    let real_parity = g.parity > 0 && payload.bytes().is_some();
    let mut parity_buf =
        vec![0u8; if real_parity { n_stripes * unit_len } else { 0 }];

    let mut done = now;
    // RMW scratch units: allocated on the first partial stripe, reused
    // for every later one (§Perf: no per-stripe buffer churn).
    let mut scratch: Vec<Vec<u8>> = Vec::new();

    for si in 0..n_stripes {
        let stripe = first_stripe + si as u64;
        let sbase = stripe * width;
        let wstart = offset.max(sbase);
        let wend = (offset + len).min(sbase + width);
        let full_stripe = wstart == sbase && wend == sbase + width;
        let punits = &plan[si * ups..][..ups];

        // ---- parity over the stripe's data units ------------------------
        // Full stripes: XOR directly over slices of the caller's buffer
        // (no unit copies). Partial stripes: patch the reusable scratch
        // units from the block map (RMW). Result goes straight into this
        // stripe's slice of the per-write parity buffer.
        if real_parity {
            let data = payload.bytes().expect("real_parity implies bytes");
            let pslice = &mut parity_buf[si * unit_len..(si + 1) * unit_len];
            if full_stripe {
                let slices: Vec<&[u8]> = (0..g.data)
                    .map(|u| {
                        let ustart =
                            (sbase + u as u64 * g.unit - offset) as usize;
                        &data[ustart..ustart + unit_len]
                    })
                    .collect();
                parity_into(&slices, exec, pslice)?;
            } else {
                if scratch.is_empty() {
                    scratch = vec![vec![0u8; unit_len]; g.data as usize];
                }
                let obj = store.object(id)?;
                for (u, buf) in scratch.iter_mut().enumerate() {
                    let ustart = sbase + u as u64 * g.unit;
                    let uend = ustart + g.unit;
                    // read-modify-write: start from the old logical
                    // bytes (zero-filled where sparse) …
                    read_logical_into(obj, ustart, buf);
                    // … then patch in the new bytes
                    let ov_start = wstart.max(ustart);
                    let ov_end = wend.min(uend);
                    if ov_start < ov_end {
                        buf[(ov_start - ustart) as usize
                            ..(ov_end - ustart) as usize]
                            .copy_from_slice(
                                &data[(ov_start - offset) as usize
                                    ..(ov_end - offset) as usize],
                            );
                    }
                }
                let slices: Vec<&[u8]> =
                    scratch.iter().map(|b| b.as_slice()).collect();
                parity_into(&slices, exec, pslice)?;
            }
        }

        // ---- parity compute cost (after the stripe's RMW frontier) ------
        let mut t_stripe = t_read[si];
        if g.parity > 0 {
            t_stripe += (g.data as u64 * g.unit) as f64 / XOR_BW;
        }

        // ---- phase B: dispatch the stripe's unit writes to their home
        // shards (one drain below covers the whole write; full-stripe
        // batches coalesce into one accounting run per device)
        for pu in punits {
            if !pu.placed || pu.failed {
                continue; // degraded write: skip failed device
            }
            let t_net = store.cluster.net.pt2pt(g.unit);
            sched.submit(
                pu.device,
                t_stripe + t_net,
                g.unit,
                IoOp::Write,
                Access::Seq,
            );
        }

        done = done.max(t_stripe);
    }
    done = done.max(sched.drain(&mut store.cluster.devices));

    // ---- persist parity: every parity unit of every stripe is a view
    // into the ONE per-write parity buffer (§Perf).
    if real_parity {
        let shared: Arc<Vec<u8>> = Arc::new(parity_buf);
        let obj = store.object_mut(id)?;
        for si in 0..n_stripes {
            let stripe = first_stripe + si as u64;
            for pi in 0..g.parity {
                obj.put_unit_view(
                    stripe,
                    g.data + pi,
                    shared.clone(),
                    si * unit_len,
                    unit_len,
                );
            }
        }
    }

    // update logical size + store real blocks for block-granular access
    if let Payload::Phantom(_) = payload {
        let obj = store.object_mut(id)?;
        obj.size = obj.size.max(offset + len);
    } else {
        persist_extent(store, id, offset, payload)?;
    }

    Ok(done)
}

/// XOR parity over borrowed unit slices, written into `out` (a slice
/// of the per-write parity buffer) — via the AOT Pallas kernel when
/// one is loaded, else the auto-vectorized CPU loop. Same bytes as
/// [`compute_parity_slices`], zero intermediate allocation on the CPU
/// path.
fn parity_into(
    units: &[&[u8]],
    exec: Option<&Executor>,
    out: &mut [u8],
) -> Result<()> {
    if let Some(e) = exec {
        let owned: Vec<Vec<u8>> = units.iter().map(|u| u.to_vec()).collect();
        if let Some(p) = e.parity(&owned)? {
            out.copy_from_slice(&p);
            return Ok(());
        }
    }
    out.copy_from_slice(units[0]);
    cpu_parity_slices_into(&units[1..], out);
    Ok(())
}

/// Persist a real write extent into the block map as ONE shared buffer:
/// owned payloads move in without a copy, borrowed payloads cost a
/// single bulk copy (§Perf). Shared with the `sns_serial` oracle so
/// both engines store byte-identical state.
pub(crate) fn persist_extent(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    payload: Payload<'_>,
) -> Result<()> {
    let bs = store.object(id)?.block_size;
    let mut data = match payload {
        Payload::Owned(d) => d,
        Payload::Real(d) => {
            let rounded = crate::util::round_up(d.len() as u64, bs) as usize;
            let mut v = Vec::with_capacity(rounded);
            v.extend_from_slice(d);
            v
        }
        Payload::Phantom(_) => return Ok(()),
    };
    let rounded = crate::util::round_up(data.len() as u64, bs) as usize;
    data.resize(rounded, 0);
    store.object_mut(id)?.put_blocks(offset / bs, Arc::new(data));
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn write_mirror(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    payload: Payload<'_>,
    now: SimTime,
    copies: u32,
    tier: DeviceKind,
    sched: &mut IoScheduler,
) -> Result<SimTime> {
    let len = payload.len();
    // placement: one pseudo-stripe per written extent, keyed by offset
    let stripe = offset;
    let mut devs = Vec::new();
    for u in 0..copies {
        if store.object(id)?.placement(stripe, u).is_none() {
            let d = store
                .pools
                .allocate(&mut store.cluster, tier, len, &devs)?;
            store.object_mut(id)?.place_unit(PlacedUnit {
                stripe,
                unit: u,
                device: d,
                size: len,
                is_parity: false,
            });
        }
        let d = store.object(id)?.placement(stripe, u).unwrap().device;
        devs.push(d);
    }
    // replica writes ride the shards like every other unit I/O (the
    // recovery plane migrates mirrored objects through the same
    // scheduler as RAID traffic)
    for &d in &devs {
        if store.cluster.devices[d].failed {
            continue;
        }
        let t_net = store.cluster.net.pt2pt(len);
        sched.submit(d, now + t_net, len, IoOp::Write, Access::Seq);
    }
    let t_done = now.max(sched.drain(&mut store.cluster.devices));
    persist_extent(store, id, offset, payload)?;
    Ok(t_done)
}

/// Ensure all units of `stripe` have device placements.
fn ensure_placement(
    store: &mut MeroStore,
    id: ObjectId,
    stripe: u64,
    g: RaidGeom,
) -> Result<()> {
    if store.object(id)?.placement(stripe, 0).is_some() {
        return Ok(());
    }
    let mut used = Vec::new();
    for u in 0..g.units_per_stripe() {
        let slot = g.rotate(stripe, u);
        let _ = slot; // slot rotation folds into allocation order
        let d = store.pools.allocate(&mut store.cluster, g.tier, g.unit, &used)?;
        used.push(d);
        store.object_mut(id)?.place_unit(PlacedUnit {
            stripe,
            unit: u,
            device: d,
            size: g.unit,
            is_parity: u >= g.data,
        });
    }
    Ok(())
}

/// Compute XOR parity over data units — via the AOT Pallas kernel when
/// a matching artifact variant is loaded, else the CPU fallback (same
/// bytes either way; pytest + integration tests assert equivalence).
pub fn compute_parity(units: &[Vec<u8>], exec: Option<&Executor>) -> Result<Vec<u8>> {
    if let Some(e) = exec {
        if let Some(p) = e.parity(units)? {
            return Ok(p);
        }
    }
    Ok(cpu_parity(units))
}

/// Borrowed-slice variant (full-stripe fast path; avoids unit copies
/// when the kernel path is not engaged).
pub fn compute_parity_slices(units: &[&[u8]], exec: Option<&Executor>) -> Result<Vec<u8>> {
    if let Some(e) = exec {
        let owned: Vec<Vec<u8>> = units.iter().map(|u| u.to_vec()).collect();
        if let Some(p) = e.parity(&owned)? {
            return Ok(p);
        }
    }
    Ok(cpu_parity_slices(units))
}

/// Read a logical byte range from the object's block map (sparse
/// blocks read as zeros). The block map is the single store for data;
/// SNS unit payloads exist only for parity.
fn read_logical(obj: &Mobject, offset: u64, len: u64) -> Vec<u8> {
    let mut out = vec![0u8; len as usize];
    read_logical_into(obj, offset, &mut out);
    out
}

/// Fill `dst` with the logical bytes at `offset` (zero-copy read path:
/// no intermediate unit buffer). Every byte of `dst` is written:
/// materialized segments are bulk-copied in one ordered walk of the
/// segment map (§Perf: one memcpy per segment, no per-block lookups),
/// sparse gaps are zero-filled.
fn read_logical_into(obj: &Mobject, offset: u64, dst: &mut [u8]) {
    obj.read_range_into(offset, dst);
}

/// Pure-CPU XOR parity (u64-lane main loop; byte tail).
pub fn cpu_parity(units: &[Vec<u8>]) -> Vec<u8> {
    let slices: Vec<&[u8]> = units.iter().map(|u| u.as_slice()).collect();
    cpu_parity_slices(&slices)
}

/// XOR parity over borrowed unit slices (the full-stripe write path
/// computes parity directly from the caller's buffer — no unit copies).
///
/// Perf note (§Perf in EXPERIMENTS.md): the naive byte loop is KEPT on
/// purpose — rustc auto-vectorizes it to AVX-512 (measured 37.7 GB/s);
/// a hand-rolled u64-lane version measured 4.2x *slower* (8.9 GB/s)
/// because the `from_ne_bytes`/`copy_from_slice` round-trip blocks
/// vectorization. Tried and reverted.
pub fn cpu_parity_slices(units: &[&[u8]]) -> Vec<u8> {
    let mut out = units[0].to_vec();
    cpu_parity_slices_into(&units[1..], &mut out);
    out
}

/// The single XOR kernel both CPU paths share: fold `units` into
/// `out` in place (callers seed `out` with the first unit).
fn cpu_parity_slices_into(units: &[&[u8]], out: &mut [u8]) {
    for u in units {
        // zip elides bounds checks => rustc vectorizes this loop
        for (o, b) in out.iter_mut().zip(u.iter()) {
            *o ^= b;
        }
    }
}

/// Read `len` bytes at `offset`, reconstructing lost units via parity
/// (self-contained op: private scheduler).
pub fn read(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
) -> Result<(Vec<u8>, SimTime)> {
    let mut sched = IoScheduler::new();
    read_with(store, id, offset, len, now, &mut sched)
}

/// [`read`] dispatching device I/O onto the caller's group scheduler.
pub fn read_with(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<(Vec<u8>, SimTime)> {
    if len == 0 {
        return Ok((Vec::new(), now));
    }
    let layout = store.object(id)?.layout.clone();
    store.object(id)?.check_aligned(offset, len)?;

    match layout.at_offset(offset).clone() {
        Layout::Raid { data, parity, unit, tier } => {
            let g = RaidGeom { data, parity, unit, tier };
            if layout.compressed() {
                // compressed extents are whole-object: read the stored
                // (physical) extent, inflate, return the logical bytes
                let phys = store.object(id)?.size;
                let mut buf = vec![0u8; phys.max(len) as usize];
                let t = read_raid_into_with(store, id, 0, &mut buf, now, g, sched)?;
                let mut raw = inflate(&buf);
                raw.resize(len as usize, 0);
                return Ok((raw, t));
            }
            let mut out = vec![0u8; len as usize];
            let t = read_raid_into_with(store, id, offset, &mut out, now, g, sched)?;
            Ok((out, t))
        }
        Layout::Mirror { .. } => read_mirror(store, id, offset, len, now, sched),
        other => Err(SageError::Invalid(format!(
            "unsupported read layout {other:?}"
        ))),
    }
}

/// Read `dst.len()` bytes at `offset` directly into `dst` (§Perf: the
/// caller owns — and can reuse — the destination buffer; the healthy
/// RAID path performs no allocation at all). Semantically identical to
/// [`read`], including parity reconstruction under device failures.
pub fn read_into(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    dst: &mut [u8],
    now: SimTime,
) -> Result<SimTime> {
    let mut sched = IoScheduler::new();
    read_into_with(store, id, offset, dst, now, &mut sched)
}

/// [`read_into`] dispatching device I/O onto the caller's group
/// scheduler (sharded op execution).
pub fn read_into_with(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    dst: &mut [u8],
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<SimTime> {
    let len = dst.len() as u64;
    if len == 0 {
        return Ok(now);
    }
    let layout = store.object(id)?.layout.clone();
    store.object(id)?.check_aligned(offset, len)?;
    match layout.at_offset(offset).clone() {
        Layout::Raid { data, parity, unit, tier } if !layout.compressed() => {
            let g = RaidGeom { data, parity, unit, tier };
            read_raid_into_with(store, id, offset, dst, now, g, sched)
        }
        _ => {
            // compressed / mirrored layouts: cold path through `read`
            let (data, t) = read_with(store, id, offset, len, now, sched)?;
            dst.copy_from_slice(&data);
            Ok(t)
        }
    }
}

fn read_mirror(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<(Vec<u8>, SimTime)> {
    // mirrors: serve from block map, cost = one replica read (failover
    // to any surviving replica), dispatched on the replica's shard
    let mut out = vec![0u8; len as usize];
    read_logical_into(store.object(id)?, offset, &mut out);
    let dev = store
        .object(id)?
        .placed_units()
        .find(|u| !store.cluster.devices[u.device].failed)
        .map(|u| u.device);
    let Some(d) = dev else {
        return Err(SageError::Unavailable(
            "all mirror replicas failed".into(),
        ));
    };
    sched.submit(d, now, len, IoOp::Read, Access::Seq);
    let t = now.max(sched.drain(&mut store.cluster.devices));
    Ok((out, t))
}

fn read_raid_into_with(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    dst: &mut [u8],
    now: SimTime,
    g: RaidGeom,
    sched: &mut IoScheduler,
) -> Result<SimTime> {
    let len = dst.len() as u64;
    if len == 0 {
        return Ok(now);
    }
    let width = g.stripe_width();
    let ups = g.units_per_stripe() as usize;
    let first_stripe = offset / width;
    let last_stripe = (offset + len - 1) / width;
    let plan = build_plan(store, id, first_stripe, last_stripe, g)?;

    // Degraded only if a *placed data* unit OVERLAPPING the requested
    // range sits on a failed device — failures of parity units or of
    // data units outside [offset, offset+len) don't affect this read.
    let mut degraded = false;
    'scan: for stripe in first_stripe..=last_stripe {
        let sbase = stripe * width;
        let punits = &plan[(stripe - first_stripe) as usize * ups..][..ups];
        for u in 0..g.data {
            let ustart = sbase + u as u64 * g.unit;
            let uend = ustart + g.unit;
            if offset.max(ustart) >= (offset + len).min(uend) {
                continue;
            }
            let pu = punits[u as usize];
            if pu.placed && pu.failed {
                degraded = true;
                break 'scan;
            }
        }
    }

    if !degraded {
        // ---- healthy fast path: ONE bulk copy from the block map ----
        read_logical_into(store.object(id)?, offset, dst);
        // sharded device-time accounting: every overlapping placed data
        // unit is dispatched to its home shard in one pass (coalescing
        // into one accounting run per device), then the shards drain
        for stripe in first_stripe..=last_stripe {
            let sbase = stripe * width;
            let punits = &plan[(stripe - first_stripe) as usize * ups..][..ups];
            for u in 0..g.data {
                let ustart = sbase + u as u64 * g.unit;
                let uend = ustart + g.unit;
                if offset.max(ustart) >= (offset + len).min(uend) {
                    continue;
                }
                let pu = punits[u as usize];
                if pu.placed {
                    sched.submit(pu.device, now, g.unit, IoOp::Read, Access::Seq);
                }
            }
        }
        let t_done = sched.drain(&mut store.cluster.devices);
        return Ok(now.max(t_done));
    }

    // ---- degraded path (scheduler-driven recovery plane): plan every
    // stripe's survivor reads up front, submit them to per-device
    // shards in ONE pass, drain once, then XOR-reconstruct from the
    // completed buffers. Reconstructions of different stripes overlap
    // in virtual time instead of chaining behind each other; the read
    // completes at the max over the rebuilds' survivor frontiers plus
    // their XOR cost.
    dst.fill(0); // reconstruct-to-None (phantom) regions read as zeros

    // One lost data unit awaiting its survivor reads.
    struct Rebuild {
        // destination byte range in `dst`
        dst_range: std::ops::Range<usize>,
        // source byte range inside the reconstructed unit
        src_range: std::ops::Range<usize>,
        // reconstructed bytes (None for phantom objects)
        payload: Option<Vec<u8>>,
        // survivor-read tickets the rebuild waits on
        tickets: Vec<Ticket>,
    }
    let mut rebuilds: Vec<Rebuild> = Vec::new();
    for stripe in first_stripe..=last_stripe {
        let sbase = stripe * width;
        let punits = &plan[(stripe - first_stripe) as usize * ups..][..ups];
        for u in 0..g.data {
            let ustart = sbase + u as u64 * g.unit;
            let uend = ustart + g.unit;
            let ov_start = offset.max(ustart);
            let ov_end = (offset + len).min(uend);
            if ov_start >= ov_end {
                continue;
            }
            // never written: sparse zeros, no device I/O
            let pu = punits[u as usize];
            if !pu.placed {
                continue;
            }
            if !pu.failed {
                // healthy unit: copy straight from the block map and
                // account the unit read on its home shard
                sched.submit(pu.device, now, g.unit, IoOp::Read, Access::Seq);
                read_logical_into(
                    store.object(id)?,
                    ov_start,
                    &mut dst[(ov_start - offset) as usize
                        ..(ov_end - offset) as usize],
                );
                continue;
            }
            if g.parity == 0 {
                return Err(SageError::Unavailable(format!(
                    "unit ({stripe},{u}) lost and no parity"
                )));
            }
            let sp = plan_reconstruct(store, id, stripe, u, g)?;
            // reconstruction traffic is Repair-class (§3.2.1 repair
            // throttling): a QoS-carrying scheduler caps the survivor
            // reads' share; healthy-unit reads above stay Foreground
            let tickets = sched.with_class(TrafficClass::Repair, |s| {
                sp.devices
                    .iter()
                    .map(|&d| s.submit(d, now, g.unit, IoOp::Read, Access::Seq))
                    .collect()
            });
            rebuilds.push(Rebuild {
                dst_range: (ov_start - offset) as usize
                    ..(ov_end - offset) as usize,
                src_range: (ov_start - ustart) as usize
                    ..(ov_end - ustart) as usize,
                payload: sp.payload,
                tickets,
            });
        }
    }
    let mut t_done = now.max(sched.drain(&mut store.cluster.devices));
    let t_xor = g.unit as f64 * g.data as f64 / XOR_BW;
    for rb in rebuilds {
        let t_read = rb
            .tickets
            .iter()
            .fold(now, |t, &tk| t.max(sched.completion(tk)));
        t_done = t_done.max(t_read + t_xor);
        if let Some(b) = rb.payload {
            dst[rb.dst_range].copy_from_slice(&b[rb.src_range]);
        }
    }
    Ok(t_done)
}

/// Survivor-read plan for rebuilding one lost data unit: the devices
/// whose unit reads the rebuild must wait on, plus the bytes
/// XOR-recovered from the block map / parity payloads. Pure planning —
/// NO device time is accounted here: the sharded engine submits the
/// reads to an `IoScheduler`, the `sns_serial` oracle chains `io()`
/// calls over `devices` — so both engines reconstruct byte-identically
/// from one code path and differ only in scheduling.
pub(crate) struct SurvivorPlan {
    /// Home devices of the alive units (data + parity) to read.
    pub(crate) devices: Vec<usize>,
    /// Reconstructed bytes (None when the object is phantom).
    pub(crate) payload: Option<Vec<u8>>,
}

/// Plan the reconstruction of lost unit (`stripe`, `lost`): validate
/// recoverability (XOR parity tolerates ONE lost data unit per stripe)
/// and compute the recovered bytes.
pub(crate) fn plan_reconstruct(
    store: &MeroStore,
    id: ObjectId,
    stripe: u64,
    lost: u32,
    g: RaidGeom,
) -> Result<SurvivorPlan> {
    let obj = store.object(id)?;
    let mut have_all_payloads = obj.real_blocks() > 0;
    let mut alive = 0;
    let mut lost_data_units = 1; // `lost` itself is a data unit
    let mut devices = Vec::new();
    let sbase = stripe * g.stripe_width();
    // §Perf (ISSUE 8): survivors fold into ONE accumulator as the loop
    // walks the stripe instead of materializing a `Vec<Vec<u8>>` — one
    // `acc` allocation, one reusable `scratch` for data units, and
    // parity units XOR straight from the borrowed unit view (no
    // `to_vec`). XOR is commutative, so the payload is bit-identical
    // to the old collect-then-`cpu_parity` shape.
    let take = g.data as usize; // k survivors suffice for XOR codes
    let mut folded = 0usize;
    let mut acc: Vec<u8> = Vec::new();
    let mut scratch: Vec<u8> = Vec::new();
    for u in 0..g.units_per_stripe() {
        if u == lost {
            continue;
        }
        let pu = *obj
            .placement(stripe, u)
            .ok_or_else(|| SageError::Unavailable("missing placement".into()))?;
        if store.cluster.devices[pu.device].failed {
            if u < g.data {
                lost_data_units += 1;
            }
            continue;
        }
        alive += 1;
        devices.push(pu.device);
        if !have_all_payloads {
            continue;
        }
        if u < g.data {
            // surviving data unit: logical bytes from the block map
            if folded < take {
                if folded == 0 {
                    acc = read_logical(obj, sbase + u as u64 * g.unit, g.unit);
                } else {
                    scratch.resize(g.unit as usize, 0);
                    read_logical_into(
                        obj,
                        sbase + u as u64 * g.unit,
                        &mut scratch,
                    );
                    cpu_parity_slices_into(&[&scratch[..]], &mut acc);
                }
                folded += 1;
            }
        } else {
            // parity unit payload (a missing view voids the payload
            // even past `take`, matching the old collect semantics)
            match obj.get_unit(stripe, u) {
                Some(b) if folded < take => {
                    if folded == 0 {
                        acc = b.to_vec();
                    } else {
                        cpu_parity_slices_into(&[b], &mut acc);
                    }
                    folded += 1;
                }
                Some(_) => {}
                None => have_all_payloads = false,
            }
        }
    }
    // XOR parity (even duplicated) recovers at most ONE lost data unit.
    if alive < g.data || lost_data_units > 1 {
        return Err(SageError::Unavailable(format!(
            "stripe {stripe}: {lost_data_units} data units lost, {alive} live \
             (XOR parity tolerates one data loss)"
        )));
    }
    // XOR of the K surviving units (data+parity, minus duplicates beyond
    // the first parity — single-parity reconstruction uses k units).
    let payload = (have_all_payloads && folded > 0).then_some(acc);
    Ok(SurvivorPlan { devices, payload })
}

/// Phantom read: time accounting without materializing data
/// (self-contained op: private scheduler).
pub fn read_phantom(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
) -> Result<SimTime> {
    let mut sched = IoScheduler::new();
    read_phantom_with(store, id, offset, len, now, &mut sched)
}

/// [`read_phantom`] dispatching device I/O onto the caller's group
/// scheduler (used by the batched HSM migration path).
pub fn read_phantom_with(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<SimTime> {
    let layout = store.object(id)?.layout.clone();
    match layout.at_offset(offset).clone() {
        Layout::Raid { data, parity, unit, tier } => {
            let g = RaidGeom { data, parity, unit, tier };
            let mut buf = vec![0u8; len.min(1 << 30) as usize];
            read_raid_into_with(store, id, offset, &mut buf, now, g, sched)
        }
        _ => {
            let (_, t) = read_with(store, id, offset, len, now, sched)?;
            Ok(t)
        }
    }
}

/// Rebuild every unit that lived on `failed_dev` onto other devices of
/// the same tier, as a self-contained op (private scheduler). Returns
/// (bytes rebuilt, completion time). Driven by the HA subsystem's
/// repair decisions (§3.2.1).
pub fn repair(
    store: &mut MeroStore,
    objects: &[ObjectId],
    failed_dev: usize,
    now: SimTime,
) -> Result<(u64, SimTime)> {
    let mut sched = IoScheduler::new();
    repair_with(store, objects, failed_dev, now, &mut sched)
}

/// [`repair`] dispatching ALL device I/O onto the caller's group
/// scheduler (scheduler-driven recovery plane): phase A plans every
/// lost unit across every object and submits the survivor reads to
/// their home shards in one pass; phase B allocates replacement homes
/// and submits each rebuild write at its unit's reconstruction
/// frontier. Rebuild writes therefore stream onto target devices while
/// survivor reads of later stripes are still in flight, and one slow
/// survivor only delays the stripes that queue on it. Bytes and
/// placements are identical to the `sns_serial::repair` serial-fold
/// oracle (`tests/prop_repair.rs`); completion is never later.
///
/// All of the rebuild's I/O — survivor reads and replacement writes —
/// dispatches as [`TrafficClass::Repair`], so a scheduler carrying a
/// QoS split caps its per-device share against foreground traffic
/// (§3.2.1 repair throttling; `IoScheduler::new` enforces no split).
pub fn repair_with(
    store: &mut MeroStore,
    objects: &[ObjectId],
    failed_dev: usize,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<(u64, SimTime)> {
    sched.with_class(TrafficClass::Repair, |sched| {
        repair_with_inner(store, objects, failed_dev, now, sched)
    })
}

fn repair_with_inner(
    store: &mut MeroStore,
    objects: &[ObjectId],
    failed_dev: usize,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<(u64, SimTime)> {
    // One planned rebuild: the lost unit, its recovered payload, and
    // the survivor-read tickets its rebuild write must wait on.
    struct PlannedRebuild {
        id: ObjectId,
        pu: PlacedUnit,
        g: RaidGeom,
        payload: Option<Vec<u8>>,
        tickets: Vec<Ticket>,
    }

    // ---- phase A: plan + submit every survivor read in ONE pass ----
    let mut work: Vec<PlannedRebuild> = Vec::new();
    for &id in objects {
        let lost: Vec<PlacedUnit> = store
            .object(id)?
            .placed_units()
            .filter(|u| u.device == failed_dev)
            .copied()
            .collect();
        if lost.is_empty() {
            continue;
        }
        let layout = store.object(id)?.layout.clone();
        let Layout::Raid { data, parity, unit, tier } =
            layout.at_offset(0).clone()
        else {
            continue;
        };
        let g = RaidGeom { data, parity, unit, tier };
        for pu in lost {
            // reconstruct (for data units) or recompute (parity units)
            let (payload, tickets) = if pu.unit < g.data {
                let sp = plan_reconstruct(store, id, pu.stripe, pu.unit, g)?;
                let tickets = sp
                    .devices
                    .iter()
                    .map(|&d| {
                        sched.submit(d, now, g.unit, IoOp::Read, Access::Seq)
                    })
                    .collect();
                (sp.payload, tickets)
            } else {
                // recompute parity from the stripe's logical data
                // (block map — no survivor I/O, XOR cost only)
                let obj = store.object(id)?;
                let payload = if obj.real_blocks() > 0 {
                    let sbase = pu.stripe * g.stripe_width();
                    // §Perf (ISSUE 8): fold the stripe's data units
                    // into one accumulator (one scratch buffer, no
                    // per-unit Vec churn)
                    let mut acc = read_logical(obj, sbase, g.unit);
                    let mut scratch = vec![0u8; g.unit as usize];
                    for u in 1..g.data {
                        read_logical_into(
                            obj,
                            sbase + u as u64 * g.unit,
                            &mut scratch,
                        );
                        cpu_parity_slices_into(&[&scratch[..]], &mut acc);
                    }
                    Some(acc)
                } else {
                    None
                };
                (payload, Vec::new())
            };
            work.push(PlannedRebuild { id, pu, g, payload, tickets });
        }
    }
    if work.is_empty() {
        return Ok((0, now));
    }
    sched.drain(&mut store.cluster.devices);

    // ---- phase B: allocate replacement homes and submit the rebuild
    // writes, each at its own reconstruction frontier ----
    let mut rebuilt = 0u64;
    for w in work {
        let g = w.g;
        let t_rec = w
            .tickets
            .iter()
            .fold(now, |t, &tk| t.max(sched.completion(tk)))
            + g.unit as f64 * g.data as f64 / XOR_BW;
        // allocate a fresh home, excluding the stripe's other devices
        let exclude: Vec<usize> = store
            .object(w.id)?
            .placed_units()
            .filter(|u| u.stripe == w.pu.stripe)
            .map(|u| u.device)
            .collect();
        let new_dev =
            store.pools.allocate(&mut store.cluster, g.tier, g.unit, &exclude)?;
        sched.submit(new_dev, t_rec, g.unit, IoOp::Write, Access::Seq);
        store.object_mut(w.id)?.place_unit(PlacedUnit {
            device: new_dev,
            ..w.pu
        });
        // only parity payloads live in unit_data; reconstructed
        // data units are already represented by the block map
        if w.pu.unit >= g.data {
            if let Some(b) = w.payload {
                store.object_mut(w.id)?.put_unit(w.pu.stripe, w.pu.unit, b);
            }
        }
        rebuilt += g.unit;
    }
    let t_done = now.max(sched.drain(&mut store.cluster.devices));
    Ok((rebuilt, t_done))
}

/// Proactively drain a DEGRADING (still-live) device: move every unit
/// homed on `dev` across `objects` onto other devices of each
/// object's tier, as a self-contained op (private scheduler). Unlike
/// [`repair`] the source device still serves reads, so no parity
/// reconstruction is needed. Returns (bytes moved, completion time).
pub fn drain(
    store: &mut MeroStore,
    objects: &[ObjectId],
    dev: usize,
    now: SimTime,
) -> Result<(u64, SimTime)> {
    let mut sched = IoScheduler::new();
    drain_with(store, objects, dev, now, &mut sched)
}

/// [`drain`] dispatching ALL device I/O onto the caller's group
/// scheduler (scheduler-driven recovery plane; the executor of
/// `RepairAction::ProactiveDrain`, reusing [`repair_with`]'s
/// two-phase shape): phase A submits one read per resident unit to
/// the draining device's shard in ONE pass; phase B allocates a
/// replacement home outside the unit's stripe and submits the rewrite
/// at that unit's own read frontier — rewrites stream onto target
/// devices while later units are still being read off the drain
/// source. Placements move; logical bytes (block map) and parity
/// payloads are untouched, so the object reads back identically and
/// keeps full redundancy once the drain completes.
///
/// Like [`repair_with`], every unit moved dispatches as
/// [`TrafficClass::Repair`] — a QoS-carrying scheduler caps the
/// drain's per-device share against foreground traffic.
pub fn drain_with(
    store: &mut MeroStore,
    objects: &[ObjectId],
    dev: usize,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<(u64, SimTime)> {
    sched.with_class(TrafficClass::Repair, |sched| {
        drain_with_inner(store, objects, dev, now, sched)
    })
}

fn drain_with_inner(
    store: &mut MeroStore,
    objects: &[ObjectId],
    dev: usize,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<(u64, SimTime)> {
    if store.cluster.devices[dev].failed {
        return Err(SageError::Invalid(format!(
            "drain targets a live device; device {dev} has failed (use repair)"
        )));
    }
    // One unit leaving the draining device: its rewrite waits on its
    // own read ticket, not on the whole phase.
    struct Move {
        id: ObjectId,
        pu: PlacedUnit,
        ticket: Ticket,
    }

    // ---- phase A: read every resident unit off the draining device --
    let mut moves: Vec<Move> = Vec::new();
    for &id in objects {
        let resident: Vec<PlacedUnit> = store
            .object(id)?
            .placed_units()
            .filter(|u| u.device == dev)
            .copied()
            .collect();
        for pu in resident {
            let ticket = sched.submit(dev, now, pu.size, IoOp::Read, Access::Seq);
            moves.push(Move { id, pu, ticket });
        }
    }
    if moves.is_empty() {
        return Ok((0, now));
    }
    sched.drain(&mut store.cluster.devices);

    // ---- phase B: re-home each unit at its own read frontier --------
    let mut bytes = 0u64;
    for m in moves {
        let t_read = sched.completion(m.ticket);
        let tier = store.object(m.id)?.layout.tier();
        // exclude the stripe's current homes (the drain source among
        // them), preserving one-device-per-stripe-unit placement
        let exclude: Vec<usize> = store
            .object(m.id)?
            .placed_units()
            .filter(|u| u.stripe == m.pu.stripe)
            .map(|u| u.device)
            .collect();
        let new_dev =
            store.pools.allocate(&mut store.cluster, tier, m.pu.size, &exclude)?;
        // `allocate` relaxes the exclusion when the pool is narrower
        // than the stripe (matching the write path) — but a drain that
        // "re-homes" a unit onto the drain source itself makes no
        // progress while claiming success. Fail loudly instead.
        if new_dev == dev {
            store.pools.release(&mut store.cluster, new_dev, m.pu.size);
            return Err(SageError::NoSpace(format!(
                "drain of device {dev}: no other {tier:?} device has space"
            )));
        }
        sched.submit(new_dev, t_read, m.pu.size, IoOp::Write, Access::Seq);
        store.object_mut(m.id)?.place_unit(PlacedUnit {
            device: new_dev,
            ..m.pu
        });
        store.pools.release(&mut store.cluster, dev, m.pu.size);
        bytes += m.pu.size;
    }
    let t_done = now.max(sched.drain(&mut store.cluster.devices));
    Ok((bytes, t_done))
}

/// Rebalance onto a newly-attached device as a self-contained op
/// (private scheduler). See [`rebalance_onto_with`].
pub fn rebalance_onto(
    store: &mut MeroStore,
    objects: &[ObjectId],
    dev: usize,
    now: SimTime,
) -> Result<(u64, SimTime)> {
    let mut sched = IoScheduler::new();
    rebalance_onto_with(store, objects, dev, now, &mut sched)
}

/// Rebalance onto a newly-attached device: the INVERSE of
/// [`drain_with`], completing the elastic-pool story — after
/// `MeroStore::attach_device` registers fresh capacity, this moves
/// existing placements onto it so the pool's load levels out instead
/// of only new writes landing there.
///
/// Two-phase like a drain, with source and target swapped: phase A
/// walks `objects` in caller order and plans one move per eligible
/// unit — eligible when the unit's tier matches `dev`'s kind, its
/// stripe has no unit on `dev` yet (one-device-per-stripe-unit is
/// preserved), its source device is live, and moving it still leaves
/// the source more utilized than the target (each move must improve
/// balance, so the plan terminates at the pool mean) — submitting the
/// source read in ONE pass. Phase B rewrites each planned unit on
/// `dev` at its own read frontier and re-points its placement.
///
/// Placements of every object the plan does not touch are unchanged —
/// placement equivalence, pinned by `tests/prop_storm.rs`. Logical
/// bytes (block map) and parity payloads never move, so objects read
/// back identically.
///
/// Every I/O dispatches as [`TrafficClass::Migration`] — a rebalance
/// is background data movement, capped by the QoS split's
/// `migration_share` against foreground traffic (the Clovis session
/// stages it as a Migration-class op).
pub fn rebalance_onto_with(
    store: &mut MeroStore,
    objects: &[ObjectId],
    dev: usize,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<(u64, SimTime)> {
    sched.with_class(TrafficClass::Migration, |sched| {
        rebalance_onto_inner(store, objects, dev, now, sched)
    })
}

fn rebalance_onto_inner(
    store: &mut MeroStore,
    objects: &[ObjectId],
    dev: usize,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<(u64, SimTime)> {
    if store.cluster.devices[dev].failed {
        return Err(SageError::Invalid(format!(
            "rebalance targets a live device; device {dev} has failed"
        )));
    }
    let kind = store.cluster.devices[dev].profile.kind;
    let cap = store.cluster.devices[dev].profile.capacity.max(1);

    // One unit moving onto the new device: its rewrite waits on its
    // own source-read ticket, not on the whole phase.
    struct Move {
        id: ObjectId,
        pu: PlacedUnit,
        ticket: Ticket,
    }

    // ---- phase A: plan against projected utilizations and submit the
    // source reads in one pass ----
    let mut dst_used = store.cluster.devices[dev].used;
    let mut src_used: std::collections::BTreeMap<usize, u64> =
        std::collections::BTreeMap::new();
    let mut moves: Vec<Move> = Vec::new();
    for &id in objects {
        if store.object(id)?.layout.tier() != kind {
            continue;
        }
        let units: Vec<PlacedUnit> =
            store.object(id)?.placed_units().copied().collect();
        let mut stripes_on_dev: std::collections::BTreeSet<u64> = units
            .iter()
            .filter(|u| u.device == dev)
            .map(|u| u.stripe)
            .collect();
        for pu in units {
            if pu.device == dev || stripes_on_dev.contains(&pu.stripe) {
                continue;
            }
            let src = &store.cluster.devices[pu.device];
            if src.failed {
                continue; // failed sources are repair's job
            }
            if dst_used + pu.size > cap {
                break; // target full: the plan is done
            }
            let su = *src_used
                .entry(pu.device)
                .or_insert(src.used);
            // each move must improve balance: after it, the target is
            // still no fuller than the source was — the plan converges
            // to the pool mean and never overshoots
            let dst_after = (dst_used + pu.size) as f64 / cap as f64;
            let src_before = su as f64 / src.profile.capacity.max(1) as f64;
            if dst_after >= src_before {
                continue;
            }
            let ticket =
                sched.submit(pu.device, now, pu.size, IoOp::Read, Access::Seq);
            dst_used += pu.size;
            *src_used.get_mut(&pu.device).unwrap() =
                su.saturating_sub(pu.size);
            stripes_on_dev.insert(pu.stripe);
            moves.push(Move { id, pu, ticket });
        }
    }
    if moves.is_empty() {
        return Ok((0, now));
    }
    sched.drain(&mut store.cluster.devices);

    // ---- phase B: rewrite each unit on the new device at its own
    // read frontier ----
    let mut bytes = 0u64;
    for m in moves {
        let t_read = sched.completion(m.ticket);
        sched.submit(dev, t_read, m.pu.size, IoOp::Write, Access::Seq);
        store.cluster.devices[dev].used += m.pu.size;
        store.object_mut(m.id)?.place_unit(PlacedUnit {
            device: dev,
            ..m.pu
        });
        store.pools.release(&mut store.cluster, m.pu.device, m.pu.size);
        bytes += m.pu.size;
    }
    let t_done = now.max(sched.drain(&mut store.cluster.devices));
    Ok((bytes, t_done))
}

// ------------------------------------------------------------ compression

/// Deflate (compressed layouts) via the in-tree run codec. Header =
/// [orig_len u64 | comp_len u64] so inflate can slice the token stream
/// out of the zero padding that unit alignment adds.
fn deflate(data: &[u8]) -> Vec<u8> {
    let z = crate::util::compress::compress(data);
    let mut out = Vec::with_capacity(16 + z.len());
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    out.extend_from_slice(&(z.len() as u64).to_le_bytes());
    out.extend_from_slice(&z);
    out
}

fn inflate(data: &[u8]) -> Vec<u8> {
    if data.len() < 16 {
        return Vec::new();
    }
    let orig = u64::from_le_bytes(data[0..8].try_into().unwrap()) as usize;
    let clen = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
    let body = &data[16..(16 + clen).min(data.len())];
    let mut out = crate::util::compress::decompress(body);
    out.truncate(orig);
    out
}

/// Phantom compression estimate (typical 2x on scientific data).
fn estimate_compressed(len: u64) -> u64 {
    (len / 2).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;
    use crate::mero::MeroStore;
    use crate::sim::rng::SimRng;

    fn store() -> MeroStore {
        MeroStore::new(Testbed::sage_prototype().build_cluster())
    }

    fn raid_obj(s: &mut MeroStore, k: u32, p: u32) -> ObjectId {
        s.create_object(
            4096,
            Layout::Raid { data: k, parity: p, unit: 16384, tier: DeviceKind::Ssd },
        )
        .unwrap()
    }

    fn random_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = SimRng::new(seed);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    #[test]
    fn roundtrip_full_stripes() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 3, 1); // 3 full stripes
        let t = s.write_object(id, 0, &data, 0.0, None).unwrap();
        assert!(t > 0.0);
        let (back, _) = s.read_object(id, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn roundtrip_partial_stripe_rmw() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let full = random_bytes(4 * 16384, 2);
        s.write_object(id, 0, &full, 0.0, None).unwrap();
        // overwrite one block in the middle
        let patch = random_bytes(4096, 3);
        s.write_object(id, 8192, &patch, 1.0, None).unwrap();
        let (back, _) = s.read_object(id, 0, full.len() as u64, 2.0).unwrap();
        assert_eq!(&back[8192..8192 + 4096], &patch[..]);
        assert_eq!(&back[..8192], &full[..8192]);
        assert_eq!(&back[8192 + 4096..], &full[8192 + 4096..]);
    }

    #[test]
    fn degraded_read_reconstructs() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 4);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        // fail the device holding data unit 1 of stripe 0
        let dev = s.object(id).unwrap().placement(0, 1).unwrap().device;
        s.cluster.fail_device(dev);
        let (back, t) = s.read_object(id, 0, data.len() as u64, 1.0).unwrap();
        assert_eq!(back, data, "parity reconstruction must restore bytes");
        assert!(t > 1.0);
    }

    #[test]
    fn double_failure_without_enough_parity_fails() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 5);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let d0 = s.object(id).unwrap().placement(0, 0).unwrap().device;
        let d1 = s.object(id).unwrap().placement(0, 1).unwrap().device;
        s.cluster.fail_device(d0);
        s.cluster.fail_device(d1);
        assert!(matches!(
            s.read_object(id, 0, data.len() as u64, 1.0),
            Err(SageError::Unavailable(_))
        ));
    }

    #[test]
    fn repair_restores_redundancy() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 2, 6);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 2).unwrap().device;
        s.cluster.fail_device(dev);
        let (rebuilt, _t) = repair(&mut s, &[id], dev, 1.0).unwrap();
        assert!(rebuilt >= 16384);
        // after repair, a second failure elsewhere is survivable
        let dev2 = s.object(id).unwrap().placement(0, 0).unwrap().device;
        assert_ne!(dev2, dev);
        s.cluster.fail_device(dev2);
        let (back, _) = s.read_object(id, 0, data.len() as u64, 2.0).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn drain_moves_every_resident_unit_and_keeps_redundancy() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 2, 21);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 0).unwrap().device;
        let resident = s
            .object(id)
            .unwrap()
            .placed_units()
            .filter(|u| u.device == dev)
            .count();
        assert!(resident > 0);
        let used_before = s.cluster.devices[dev].used;
        let (bytes, t) = drain(&mut s, &[id], dev, 1.0).unwrap();
        assert_eq!(bytes, resident as u64 * 16384);
        assert!(t > 1.0, "the drain takes virtual time");
        assert!(
            s.object(id).unwrap().placed_units().all(|u| u.device != dev),
            "no unit left on the drained device"
        );
        assert!(
            s.cluster.devices[dev].used < used_before,
            "pool space released on the drain source"
        );
        // per-stripe placement stays one-device-per-unit
        for pu in s.object(id).unwrap().placed_units() {
            let same_dev = s
                .object(id)
                .unwrap()
                .placed_units()
                .filter(|o| o.stripe == pu.stripe && o.device == pu.device)
                .count();
            assert_eq!(same_dev, 1, "stripe units stay on distinct devices");
        }
        // bytes unchanged, and redundancy survives the (now-empty)
        // device hard-failing afterwards
        s.cluster.fail_device(dev);
        let (back, _) = s.read_object(id, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data);
        // …and a real failure elsewhere is still reconstructible
        let other = s.object(id).unwrap().placement(0, 1).unwrap().device;
        s.cluster.fail_device(other);
        let (back2, _) = s.read_object(id, 0, data.len() as u64, t + 1.0).unwrap();
        assert_eq!(back2, data, "parity still covers one loss after drain");
    }

    #[test]
    fn drain_with_no_alternative_home_errors_instead_of_faking_progress() {
        // a tier with ONE device: the allocator's relaxed fallback
        // would hand the unit straight back to the drain source —
        // drain must refuse (NoSpace), not report bytes "moved"
        use crate::cluster::{Cluster, EnclosureCompute};
        use crate::sim::network::NetworkModel;
        let mut c = Cluster::new(NetworkModel::fdr_infiniband());
        c.add_node(
            vec![crate::sim::device::DeviceProfile::ssd(1 << 30)],
            EnclosureCompute { cores: 8, flops: 1e10 },
        );
        let mut s = MeroStore::new(c);
        let id = s
            .create_object(
                4096,
                Layout::Raid { data: 1, parity: 0, unit: 16384, tier: DeviceKind::Ssd },
            )
            .unwrap();
        let data = random_bytes(16384, 23);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 0).unwrap().device;
        let used_before = s.cluster.devices[dev].used;
        assert!(matches!(
            drain(&mut s, &[id], dev, 1.0),
            Err(SageError::NoSpace(_))
        ));
        // the failed attempt did not leak pool space or placements
        assert_eq!(s.cluster.devices[dev].used, used_before);
        assert_eq!(
            s.object(id).unwrap().placement(0, 0).unwrap().device,
            dev,
            "placement untouched on a refused drain"
        );
        let (back, _) = s.read_object(id, 0, data.len() as u64, 2.0).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn drain_rejects_failed_devices_and_empty_drains_are_noops() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 22);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 0).unwrap().device;
        // a failed device cannot be drained (that is repair's job)
        s.cluster.fail_device(dev);
        assert!(matches!(
            drain(&mut s, &[id], dev, 1.0),
            Err(SageError::Invalid(_))
        ));
        s.cluster.replace_device(dev);
        // draining a device that holds nothing completes at `now`
        let empty = (0..s.cluster.devices.len())
            .find(|&d| {
                !s.cluster.devices[d].failed
                    && s.object(id).unwrap().placed_units().all(|u| u.device != d)
            })
            .expect("some device holds no unit of this object");
        let (bytes, t) = drain(&mut s, &[id], empty, 5.0).unwrap();
        assert_eq!(bytes, 0);
        assert_eq!(t, 5.0);
    }

    #[test]
    fn rebalance_moves_units_onto_fresh_capacity() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let other = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 4, 31);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let odata = random_bytes(4 * 16384, 32);
        s.write_object(other, 0, &odata, 0.0, None).unwrap();
        let before_other: Vec<PlacedUnit> =
            s.object(other).unwrap().placed_units().copied().collect();
        let src = s.object(id).unwrap().placement(0, 0).unwrap().device;
        let prof = s.cluster.devices[src].profile.clone();
        let dev = s.attach_device(1, prof).unwrap();
        let (bytes, t) = rebalance_onto(&mut s, &[id], dev, 1.0).unwrap();
        assert!(bytes >= 16384, "fresh capacity attracts at least one unit");
        assert_eq!(bytes % 16384, 0);
        assert!(t > 1.0, "the rebalance takes virtual time");
        assert_eq!(s.cluster.devices[dev].used, bytes);
        // per-stripe placement stays one-device-per-unit
        for pu in s.object(id).unwrap().placed_units() {
            let same = s
                .object(id)
                .unwrap()
                .placed_units()
                .filter(|o| o.stripe == pu.stripe && o.device == pu.device)
                .count();
            assert_eq!(same, 1, "stripe units stay on distinct devices");
        }
        // bytes unchanged…
        let (back, _) = s.read_object(id, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data);
        // …and redundancy holds: the newcomer itself can fail
        s.cluster.fail_device(dev);
        let (back2, _) =
            s.read_object(id, 0, data.len() as u64, t + 1.0).unwrap();
        assert_eq!(back2, data, "parity covers losing the new device");
        // placement equivalence for the object the plan never touched
        let after_other: Vec<PlacedUnit> =
            s.object(other).unwrap().placed_units().copied().collect();
        assert_eq!(before_other, after_other, "untouched object unmoved");
    }

    #[test]
    fn rebalance_rejects_failed_target_and_converges_to_noop() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 4, 33);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let src = s.object(id).unwrap().placement(0, 0).unwrap().device;
        let prof = s.cluster.devices[src].profile.clone();
        let dev = s.attach_device(2, prof).unwrap();
        s.cluster.fail_device(dev);
        assert!(matches!(
            rebalance_onto(&mut s, &[id], dev, 1.0),
            Err(SageError::Invalid(_))
        ));
        s.cluster.replace_device(dev);
        let (bytes, t) = rebalance_onto(&mut s, &[id], dev, 1.0).unwrap();
        assert!(bytes > 0);
        // the plan runs to its balance fixpoint: an immediate second
        // pass has nothing left to move
        let (again, t2) = rebalance_onto(&mut s, &[id], dev, t).unwrap();
        assert_eq!(again, 0);
        assert_eq!(t2, t);
    }

    #[test]
    fn mirror_roundtrip_and_failover() {
        let mut s = store();
        let id = s
            .create_object(
                4096,
                Layout::Mirror { copies: 2, tier: DeviceKind::Ssd },
            )
            .unwrap();
        let data = random_bytes(16384, 7);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 0).unwrap().device;
        s.cluster.fail_device(dev);
        let (back, _) = s.read_object(id, 0, data.len() as u64, 1.0).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn compressed_roundtrip() {
        let mut s = store();
        let id = s
            .create_object(
                4096,
                Layout::Compressed { inner: Box::new(Layout::default()) },
            )
            .unwrap();
        // compressible payload
        let mut data = vec![42u8; 64 * 1024];
        data[1000] = 7;
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let (back, _) = s.read_object(id, 0, data.len() as u64, 1.0).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn cpu_parity_is_xor() {
        let a = vec![0b1010u8; 8];
        let b = vec![0b0110u8; 8];
        let p = cpu_parity(&[a.clone(), b.clone()]);
        assert_eq!(p, vec![0b1100u8; 8]);
        // self-inverse
        assert_eq!(cpu_parity(&[p, b]), a);
    }

    #[test]
    fn phantom_write_accounts_time_without_memory() {
        let mut s = store();
        let id = raid_obj(&mut s, 8, 1);
        let t = s
            .write_object_phantom(id, 0, 1 << 28, 0.0) // 256 MiB
            .unwrap();
        assert!(t > 0.0);
        assert_eq!(s.object(id).unwrap().real_blocks(), 0);
        let t2 = s.read_object_phantom(id, 0, 1 << 28, t).unwrap();
        assert!(t2 > t);
    }

    // ------------------------------------------------ §Perf engine tests

    #[test]
    fn owned_write_roundtrip_persist_by_move() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 2, 11);
        let t = s
            .write_object_owned(id, 0, data.clone(), 0.0, None)
            .unwrap();
        assert!(t > 0.0);
        let (back, _) = s.read_object(id, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn read_into_matches_read_including_sparse_gaps() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 12);
        // leave stripe 0 sparse; write stripe 1 only
        s.write_object(id, 4 * 16384, &data, 0.0, None).unwrap();
        let total = 2 * 4 * 16384u64;
        let (via_read, _) = s.read_object(id, 0, total, 1.0).unwrap();
        // dirty destination proves every byte is (re)written
        let mut via_into = vec![0xAAu8; total as usize];
        s.read_object_into(id, 0, &mut via_into, 1.0).unwrap();
        assert_eq!(via_read, via_into);
        assert_eq!(&via_into[..4 * 16384], &vec![0u8; 4 * 16384][..]);
        assert_eq!(&via_into[4 * 16384..], &data[..]);
    }

    #[test]
    fn read_into_reconstructs_under_failure() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 13);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 2).unwrap().device;
        s.cluster.fail_device(dev);
        let mut back = vec![0xEEu8; data.len()];
        let t = s.read_object_into(id, 0, &mut back, 1.0).unwrap();
        assert_eq!(back, data);
        assert!(t > 1.0);
    }

    #[test]
    fn read_not_touching_failed_unit_stays_on_fast_path() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 17);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        // fail the device of data unit 3; read only unit 0's bytes
        let dev = s.object(id).unwrap().placement(0, 3).unwrap().device;
        s.cluster.fail_device(dev);
        let mut buf = vec![0u8; 16384];
        s.read_object_into(id, 0, &mut buf, 1.0).unwrap();
        assert_eq!(buf, &data[..16384]);
        // reading the failed unit itself still reconstructs
        let mut buf3 = vec![0u8; 16384];
        s.read_object_into(id, 3 * 16384, &mut buf3, 2.0).unwrap();
        assert_eq!(buf3, &data[3 * 16384..]);
    }

    #[test]
    fn parity_units_share_one_payload() {
        let mut s = store();
        let id = raid_obj(&mut s, 2, 2);
        let data = random_bytes(2 * 16384, 14);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let obj = s.object(id).unwrap();
        let p0 = obj.get_unit(0, 2).expect("first parity payload");
        let p1 = obj.get_unit(0, 3).expect("second parity payload");
        assert_eq!(p0, p1);
        // same allocation, not a deep clone (§Perf satellite)
        assert_eq!(p0.as_ptr(), p1.as_ptr());
    }

    #[test]
    fn parity_views_share_one_buffer_across_stripes() {
        // §Perf: a multi-stripe write computes ALL its parity into one
        // buffer; per-stripe parity units are adjacent views into it
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 3, 18); // 3 full stripes
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let obj = s.object(id).unwrap();
        let p0 = obj.get_unit(0, 4).expect("stripe 0 parity");
        let p1 = obj.get_unit(1, 4).expect("stripe 1 parity");
        let p2 = obj.get_unit(2, 4).expect("stripe 2 parity");
        assert_eq!(p0.len(), 16384);
        assert_eq!(p0.as_ptr() as usize + 16384, p1.as_ptr() as usize);
        assert_eq!(p1.as_ptr() as usize + 16384, p2.as_ptr() as usize);
        // and each view holds the XOR of its stripe's data units
        let units: Vec<Vec<u8>> =
            (0..4).map(|u| data[u * 16384..(u + 1) * 16384].to_vec()).collect();
        assert_eq!(p0, &cpu_parity(&units)[..]);
    }

    #[test]
    fn sharded_write_batches_device_accounting() {
        // full-stripe batch: every stripe's writes carry the same
        // submit time, so each device's submissions coalesce into ONE
        // accounting run (§Perf: one io() per device-contiguous run)
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 4, 19); // 4 full stripes
        let mut sched = IoScheduler::new();
        write_with(
            &mut s,
            id,
            0,
            Payload::Real(&data),
            0.0,
            None,
            &mut sched,
        )
        .unwrap();
        assert_eq!(sched.ios(), 4 * 5, "5 unit writes per stripe");
        assert_eq!(
            sched.io_calls(),
            sched.shard_count() as u64,
            "one accounting run per touched device"
        );
        assert!(sched.io_calls() < sched.ios());
        assert!(sched.wait_all() > 0.0);
    }

    #[test]
    fn sharded_execution_is_deterministic() {
        let run = || {
            let mut s = store();
            let id = raid_obj(&mut s, 4, 2);
            let data = random_bytes(4 * 16384 * 2, 20);
            let t1 = s.write_object(id, 0, &data, 0.0, None).unwrap();
            // partial overwrite exercises the two-phase RMW dispatch
            let patch = random_bytes(16384, 21);
            let t2 = s.write_object(id, 8192, &patch, t1, None).unwrap();
            let (back, t3) =
                s.read_object(id, 0, data.len() as u64, t2).unwrap();
            (back, t1.to_bits(), t2.to_bits(), t3.to_bits())
        };
        assert_eq!(run(), run(), "same seed, same bytes, same virtual times");
    }

    // ---------------------------------------- recovery-plane tests

    #[test]
    fn degraded_read_dispatches_through_scheduler() {
        // survivor reads of a degraded read ride the shards: nothing
        // pending after the call, and the batch accounted at least the
        // healthy-unit reads plus the lost unit's survivor reads
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 41);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 1).unwrap().device;
        s.cluster.fail_device(dev);
        let mut sched = IoScheduler::new();
        let mut back = vec![0u8; data.len()];
        let t = read_into_with(&mut s, id, 0, &mut back, 1.0, &mut sched)
            .unwrap();
        assert_eq!(back, data);
        assert!(t > 1.0);
        assert_eq!(sched.pending(), 0, "degraded read drains its shards");
        // 3 healthy overlapping data units + 4 survivor reads (3 data
        // + 1 parity) for the lost unit
        assert_eq!(sched.ios(), 7);
        assert!(sched.io_calls() <= sched.ios());
    }

    #[test]
    fn repair_with_dispatches_only_scheduler_io() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 2, 42);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 2).unwrap().device;
        s.cluster.fail_device(dev);
        let mut sched = IoScheduler::new();
        let (bytes, t) =
            repair_with(&mut s, &[id], dev, 1.0, &mut sched).unwrap();
        assert!(bytes >= 16384, "the failed device's units rebuilt");
        assert!(t > 1.0);
        assert_eq!(sched.pending(), 0, "both phases drained");
        // every rebuilt unit wrote once; data units also read survivors
        assert!(sched.ios() > 2, "survivor reads + rebuild writes");
        assert!(
            (t - sched.wait_all()).abs() < 1e-12,
            "completion is the max over per-device frontiers"
        );
        // redundancy restored: a second failure is survivable
        let dev2 = s.object(id).unwrap().placement(0, 0).unwrap().device;
        s.cluster.fail_device(dev2);
        let (back, _) = s.read_object(id, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn repair_on_shared_scheduler_overlaps_with_group() {
        // a repair and a foreground read can share one group scheduler:
        // the group completes at the max over per-device frontiers,
        // not at a serial fold of the two operations
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 43);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 3).unwrap().device;
        s.cluster.fail_device(dev);
        let mut sched = IoScheduler::new();
        let (_, t_repair) =
            repair_with(&mut s, &[id], dev, 1.0, &mut sched).unwrap();
        let mut buf = vec![0u8; 16384];
        let t_read =
            read_into_with(&mut s, id, 0, &mut buf, 1.0, &mut sched).unwrap();
        assert_eq!(buf, &data[..16384]);
        let group = sched.wait_all();
        assert!(group >= t_repair.max(t_read) - 1e-12);
    }

    #[test]
    fn rmw_scratch_reuse_keeps_bytes_exact() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let full = random_bytes(4 * 16384 * 3, 15);
        s.write_object(id, 0, &full, 0.0, None).unwrap();
        // one write spanning two partial stripes exercises scratch reuse
        let patch = random_bytes(4 * 16384, 16);
        let off = 2 * 16384u64; // middle of stripe 0 into stripe 1
        s.write_object(id, off, &patch, 1.0, None).unwrap();
        let mut want = full.clone();
        want[off as usize..off as usize + patch.len()].copy_from_slice(&patch);
        let (back, _) = s.read_object(id, 0, want.len() as u64, 2.0).unwrap();
        assert_eq!(back, want);
        // parity must match the patched data: degraded read proves it
        let dev = s.object(id).unwrap().placement(0, 1).unwrap().device;
        s.cluster.fail_device(dev);
        let (back2, _) = s.read_object(id, 0, want.len() as u64, 3.0).unwrap();
        assert_eq!(back2, want);
    }
}
