//! Server Network Striping: Mero's distributed RAID (§3.2.1).
//!
//! Objects with a [`Layout::Raid`] are split into stripes of `data`
//! units plus `parity` XOR units; units of one stripe land on distinct
//! devices of the layout's tier, with the parity position rotating per
//! stripe (RAID-5 style declustering). Reads reconstruct through parity
//! when devices have failed; [`repair`] rebuilds a failed device's
//! units onto survivors (driven by the HA subsystem).
//!
//! The parity hot-spot is the L1 Pallas kernel (`parity_k4`/`parity_k8`
//! artifacts) executed via PJRT when an [`Executor`] is supplied;
//! otherwise a CPU XOR fallback computes the same bytes. Virtual-time
//! cost is always modelled from the enclosure's compute capability —
//! wall-clock kernel time on the build machine is not a TPU proxy.

use crate::error::{Result, SageError};
use crate::mero::layout::Layout;
use crate::mero::object::{ObjectId, PlacedUnit};
use crate::mero::MeroStore;
use crate::runtime::Executor;
use crate::sim::clock::SimTime;
use crate::sim::device::{Access, DeviceKind, IoOp};

/// Real bytes or a phantom length (time/placement accounting only).
pub enum Payload<'a> {
    Real(&'a [u8]),
    Phantom(u64),
}

impl Payload<'_> {
    fn len(&self) -> u64 {
        match self {
            Payload::Real(d) => d.len() as u64,
            Payload::Phantom(l) => *l,
        }
    }
    fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_))
    }
}

/// XOR throughput of the in-enclosure compute path, bytes/s. Used for
/// virtual-time costing of parity generation and reconstruction.
const XOR_BW: f64 = 5.0e9;

/// Write `payload` at `offset` of object `id`. Returns completion time.
pub fn write(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    payload: Payload<'_>,
    now: SimTime,
    exec: Option<&Executor>,
) -> Result<SimTime> {
    let len = payload.len();
    if len == 0 {
        return Ok(now);
    }
    let (block_size, layout) = {
        let obj = store.object(id)?;
        obj.check_aligned(offset, len)?;
        (obj.block_size, obj.layout.clone())
    };
    if layout.compressed() && offset != 0 {
        return Err(SageError::Invalid(
            "compressed layouts support whole-object writes only".into(),
        ));
    }

    // Transparent compression: stripe the deflated bytes.
    let compressed;
    let payload = if layout.compressed() {
        match payload {
            Payload::Real(d) => {
                compressed = deflate(d);
                Payload::Real(&compressed)
            }
            Payload::Phantom(l) => Payload::Phantom(estimate_compressed(l)),
        }
    } else {
        payload
    };

    match layout.at_offset(offset).clone() {
        Layout::Raid { data, parity, unit, tier } => write_raid(
            store, id, offset, payload, now, exec,
            RaidGeom { data, parity, unit, tier }, block_size,
        ),
        Layout::Mirror { copies, tier } => {
            write_mirror(store, id, offset, payload, now, copies, tier)
        }
        other => Err(SageError::Invalid(format!(
            "unsupported write layout {other:?}"
        ))),
    }
}

#[derive(Clone, Copy)]
struct RaidGeom {
    data: u32,
    parity: u32,
    unit: u64,
    tier: DeviceKind,
}

impl RaidGeom {
    fn stripe_width(&self) -> u64 {
        self.data as u64 * self.unit
    }
    fn units_per_stripe(&self) -> u32 {
        self.data + self.parity
    }
    /// RAID-5 rotation: device-slot of logical unit `u` in `stripe`.
    fn rotate(&self, stripe: u64, u: u32) -> u32 {
        ((u as u64 + stripe) % self.units_per_stripe() as u64) as u32
    }
}

fn write_raid(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    payload: Payload<'_>,
    now: SimTime,
    exec: Option<&Executor>,
    g: RaidGeom,
    _block_size: u64,
) -> Result<SimTime> {
    let len = payload.len();
    let width = g.stripe_width();
    let first_stripe = offset / width;
    let last_stripe = (offset + len - 1) / width;
    let mut done = now;

    for stripe in first_stripe..=last_stripe {
        let sbase = stripe * width;
        let wstart = offset.max(sbase);
        let wend = (offset + len).min(sbase + width);
        let full_stripe = wstart == sbase && wend == sbase + width;

        // ---- parity over the stripe's data units ------------------------
        // Full stripes: XOR directly over slices of the caller's buffer
        // (no unit copies — the §Perf hot-path fix). Partial stripes:
        // assemble patched units from the block map (RMW).
        let parity_unit: Option<Vec<u8>> = if payload.is_real() && g.parity > 0 {
            let data = match &payload {
                Payload::Real(d) => *d,
                _ => unreachable!(),
            };
            if full_stripe {
                let slices: Vec<&[u8]> = (0..g.data)
                    .map(|u| {
                        let ustart = (sbase + u as u64 * g.unit - offset) as usize;
                        &data[ustart..ustart + g.unit as usize]
                    })
                    .collect();
                Some(compute_parity_slices(&slices, exec)?)
            } else {
                let mut units: Vec<Vec<u8>> = Vec::with_capacity(g.data as usize);
                for u in 0..g.data {
                    let ustart = sbase + u as u64 * g.unit;
                    let uend = ustart + g.unit;
                    // read-modify-write: start from the old logical bytes
                    let mut buf =
                        read_logical(store.object(id)?, ustart, g.unit);
                    let ov_start = wstart.max(ustart);
                    let ov_end = wend.min(uend);
                    if ov_start < ov_end {
                        buf[(ov_start - ustart) as usize
                            ..(ov_end - ustart) as usize]
                            .copy_from_slice(
                                &data[(ov_start - offset) as usize
                                    ..(ov_end - offset) as usize],
                            );
                    }
                    units.push(buf);
                }
                Some(compute_parity(&units, exec)?)
            }
        } else {
            None
        };

        // ---- placement (first touch) -----------------------------------
        ensure_placement(store, id, stripe, g)?;

        // ---- RMW read cost for partial stripes --------------------------
        let mut t_stripe = now;
        if !full_stripe {
            // must read old data units + parity to recompute parity
            let mut t_read = now;
            for u in 0..g.units_per_stripe() {
                let dev = store.object(id)?.placement(stripe, u).unwrap().device;
                if !store.cluster.devices[dev].failed {
                    let t = store.cluster.io(dev, now, g.unit, IoOp::Read, Access::Random);
                    t_read = t_read.max(t);
                }
            }
            t_stripe = t_read;
        }

        // ---- parity compute cost ----------------------------------------
        if g.parity > 0 {
            let node = {
                let dev = store.object(id)?.placement(stripe, 0).unwrap().device;
                store.cluster.node_of(dev).unwrap_or(0)
            };
            let _ = node;
            t_stripe += (g.data as u64 * g.unit) as f64 / XOR_BW;
        }

        // ---- unit writes (parallel across distinct devices) -------------
        let mut t_done = t_stripe;
        for u in 0..g.units_per_stripe() {
            let pu = *store.object(id)?.placement(stripe, u).unwrap();
            if store.cluster.devices[pu.device].failed {
                continue; // degraded write: skip failed device
            }
            let t_net = store.cluster.net.pt2pt(g.unit);
            let t = store
                .cluster
                .io(pu.device, t_stripe + t_net, g.unit, IoOp::Write, Access::Seq);
            t_done = t_done.max(t);
        }

        // ---- persist parity (data units live in the block map) ---------
        if let Some(p) = parity_unit {
            let obj = store.object_mut(id)?;
            for pi in 0..g.parity {
                if pi + 1 == g.parity {
                    obj.put_unit(stripe, g.data + pi, p);
                    break;
                }
                obj.put_unit(stripe, g.data + pi, p.clone());
            }
        }

        done = done.max(t_done);
    }

    // update logical size + store real blocks for block-granular access
    if let Payload::Real(data) = payload {
        let obj = store.object_mut(id)?;
        let bs = obj.block_size;
        for (i, chunk) in data.chunks(bs as usize).enumerate() {
            let mut block = chunk.to_vec();
            block.resize(bs as usize, 0);
            obj.put_block(offset / bs + i as u64, block);
        }
    } else {
        let obj = store.object_mut(id)?;
        obj.size = obj.size.max(offset + len);
    }

    Ok(done)
}

fn write_mirror(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    payload: Payload<'_>,
    now: SimTime,
    copies: u32,
    tier: DeviceKind,
) -> Result<SimTime> {
    let len = payload.len();
    // placement: one pseudo-stripe per written extent, keyed by offset
    let stripe = offset;
    let mut devs = Vec::new();
    for u in 0..copies {
        if store.object(id)?.placement(stripe, u).is_none() {
            let d = store
                .pools
                .allocate(&mut store.cluster, tier, len, &devs)?;
            store.object_mut(id)?.place_unit(PlacedUnit {
                stripe,
                unit: u,
                device: d,
                size: len,
                is_parity: false,
            });
        }
        let d = store.object(id)?.placement(stripe, u).unwrap().device;
        devs.push(d);
    }
    let mut t_done = now;
    for &d in &devs {
        if store.cluster.devices[d].failed {
            continue;
        }
        let t_net = store.cluster.net.pt2pt(len);
        let t = store.cluster.io(d, now + t_net, len, IoOp::Write, Access::Seq);
        t_done = t_done.max(t);
    }
    if let Payload::Real(data) = payload {
        let obj = store.object_mut(id)?;
        let bs = obj.block_size;
        for (i, chunk) in data.chunks(bs as usize).enumerate() {
            let mut block = chunk.to_vec();
            block.resize(bs as usize, 0);
            obj.put_block(offset / bs + i as u64, block);
        }
    }
    Ok(t_done)
}

/// Ensure all units of `stripe` have device placements.
fn ensure_placement(
    store: &mut MeroStore,
    id: ObjectId,
    stripe: u64,
    g: RaidGeom,
) -> Result<()> {
    if store.object(id)?.placement(stripe, 0).is_some() {
        return Ok(());
    }
    let mut used = Vec::new();
    for u in 0..g.units_per_stripe() {
        let slot = g.rotate(stripe, u);
        let _ = slot; // slot rotation folds into allocation order
        let d = store.pools.allocate(&mut store.cluster, g.tier, g.unit, &used)?;
        used.push(d);
        store.object_mut(id)?.place_unit(PlacedUnit {
            stripe,
            unit: u,
            device: d,
            size: g.unit,
            is_parity: u >= g.data,
        });
    }
    Ok(())
}

/// Compute XOR parity over data units — via the AOT Pallas kernel when
/// a matching artifact variant is loaded, else the CPU fallback (same
/// bytes either way; pytest + integration tests assert equivalence).
pub fn compute_parity(units: &[Vec<u8>], exec: Option<&Executor>) -> Result<Vec<u8>> {
    if let Some(e) = exec {
        if let Some(p) = e.parity(units)? {
            return Ok(p);
        }
    }
    Ok(cpu_parity(units))
}

/// Borrowed-slice variant (full-stripe fast path; avoids unit copies
/// when the kernel path is not engaged).
pub fn compute_parity_slices(units: &[&[u8]], exec: Option<&Executor>) -> Result<Vec<u8>> {
    if let Some(e) = exec {
        let owned: Vec<Vec<u8>> = units.iter().map(|u| u.to_vec()).collect();
        if let Some(p) = e.parity(&owned)? {
            return Ok(p);
        }
    }
    Ok(cpu_parity_slices(units))
}

/// Read a logical byte range from the object's block map (sparse
/// blocks read as zeros). The block map is the single store for data;
/// SNS unit payloads exist only for parity.
fn read_logical(obj: &crate::mero::object::Mobject, offset: u64, len: u64) -> Vec<u8> {
    let mut out = vec![0u8; len as usize];
    read_logical_into(obj, offset, &mut out);
    out
}

/// Copy a logical byte range directly into `dst` (zero-copy read path:
/// no intermediate unit buffer).
fn read_logical_into(obj: &crate::mero::object::Mobject, offset: u64, dst: &mut [u8]) {
    let bs = obj.block_size;
    let len = dst.len() as u64;
    if len == 0 {
        return;
    }
    let first = offset / bs;
    let last = (offset + len - 1) / bs;
    for b in first..=last {
        let bstart = b * bs;
        let ov_start = offset.max(bstart);
        let ov_end = (offset + len).min(bstart + bs);
        if let Some(block) = obj.block_ref(b) {
            dst[(ov_start - offset) as usize..(ov_end - offset) as usize]
                .copy_from_slice(
                    &block[(ov_start - bstart) as usize
                        ..(ov_end - bstart) as usize],
                );
        }
    }
}

/// Pure-CPU XOR parity (u64-lane main loop; byte tail).
pub fn cpu_parity(units: &[Vec<u8>]) -> Vec<u8> {
    let slices: Vec<&[u8]> = units.iter().map(|u| u.as_slice()).collect();
    cpu_parity_slices(&slices)
}

/// XOR parity over borrowed unit slices (the full-stripe write path
/// computes parity directly from the caller's buffer — no unit copies).
///
/// Perf note (§Perf in EXPERIMENTS.md): the naive byte loop is KEPT on
/// purpose — rustc auto-vectorizes it to AVX-512 (measured 37.7 GB/s);
/// a hand-rolled u64-lane version measured 4.2x *slower* (8.9 GB/s)
/// because the `from_ne_bytes`/`copy_from_slice` round-trip blocks
/// vectorization. Tried and reverted.
pub fn cpu_parity_slices(units: &[&[u8]]) -> Vec<u8> {
    let mut out = units[0].to_vec();
    for u in &units[1..] {
        // zip elides bounds checks => rustc vectorizes this loop
        for (o, b) in out.iter_mut().zip(u.iter()) {
            *o ^= b;
        }
    }
    out
}

/// Read `len` bytes at `offset`, reconstructing lost units via parity.
pub fn read(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
) -> Result<(Vec<u8>, SimTime)> {
    let (block_size, layout, size) = {
        let o = store.object(id)?;
        (o.block_size, o.layout.clone(), o.size)
    };
    let _ = size;
    store.object(id)?.check_aligned(offset, len)?;

    match layout.at_offset(offset).clone() {
        Layout::Raid { data, parity, unit, tier } => {
            let g = RaidGeom { data, parity, unit, tier };
            if layout.compressed() {
                // compressed extents are whole-object: read the stored
                // (physical) extent, inflate, return the logical bytes
                let phys = store.object(id)?.size;
                let (buf, t) = read_raid(store, id, 0, phys.max(len), now, g)?;
                let mut raw = inflate(&buf);
                raw.resize(len as usize, 0);
                return Ok((raw, t));
            }
            let (buf, t) = read_raid(store, id, offset, len, now, g)?;
            Ok((buf, t))
        }
        Layout::Mirror { .. } => {
            // mirrors: serve from block map, cost = one replica read
            let mut out = Vec::with_capacity(len as usize);
            let obj = store.object(id)?;
            for b in (offset / block_size)..((offset + len) / block_size) {
                out.extend_from_slice(&obj.get_block(b));
            }
            let dev = store
                .object(id)?
                .placed_units()
                .find(|u| !store.cluster.devices[u.device].failed)
                .map(|u| u.device);
            let t = match dev {
                Some(d) => store.cluster.io(d, now, len, IoOp::Read, Access::Seq),
                None => {
                    return Err(SageError::Unavailable(
                        "all mirror replicas failed".into(),
                    ))
                }
            };
            Ok((out, t))
        }
        other => Err(SageError::Invalid(format!(
            "unsupported read layout {other:?}"
        ))),
    }
}

fn read_raid(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
    g: RaidGeom,
) -> Result<(Vec<u8>, SimTime)> {
    let width = g.stripe_width();
    let mut out = vec![0u8; len as usize];
    let mut t_done = now;

    let first_stripe = offset / width;
    let last_stripe = (offset + len - 1) / width;
    for stripe in first_stripe..=last_stripe {
        let sbase = stripe * width;
        for u in 0..g.data {
            let ustart = sbase + u as u64 * g.unit;
            let uend = ustart + g.unit;
            let ov_start = offset.max(ustart);
            let ov_end = (offset + len).min(uend);
            if ov_start >= ov_end {
                continue;
            }
            // never written: sparse zeros, no device I/O
            let placed = store.object(id)?.placement(stripe, u).copied();
            let Some(pu) = placed else { continue };

            let failed = store.cluster.devices[pu.device].failed;
            if !failed {
                // healthy fast path: copy straight from the block map
                // into the output (no intermediate unit buffer, §Perf)
                let t =
                    store
                        .cluster
                        .io(pu.device, now, g.unit, IoOp::Read, Access::Seq);
                let obj = store.object(id)?;
                if obj.real_blocks() > 0 {
                    read_logical_into(
                        obj,
                        ov_start,
                        &mut out[(ov_start - offset) as usize
                            ..(ov_end - offset) as usize],
                    );
                }
                t_done = t_done.max(t);
                continue;
            }
            let (bytes, t) = {
                if g.parity == 0 {
                    return Err(SageError::Unavailable(format!(
                        "unit ({stripe},{u}) lost and no parity"
                    )));
                }
                reconstruct_unit(store, id, stripe, u, now, g)?
            };
            if let Some(b) = bytes {
                let dst = (ov_start - offset) as usize..(ov_end - offset) as usize;
                let src = (ov_start - ustart) as usize..(ov_end - ustart) as usize;
                out[dst].copy_from_slice(&b[src]);
            }
            t_done = t_done.max(t);
        }
    }
    Ok((out, t_done))
}

/// Rebuild one lost data unit from survivors + parity.
/// Returns (payload if real data exists, completion time).
fn reconstruct_unit(
    store: &mut MeroStore,
    id: ObjectId,
    stripe: u64,
    lost: u32,
    now: SimTime,
    g: RaidGeom,
) -> Result<(Option<Vec<u8>>, SimTime)> {
    let mut t_read = now;
    let mut survivors: Vec<Vec<u8>> = Vec::new();
    let mut have_all_payloads = store.object(id)?.real_blocks() > 0;
    let mut alive = 0;
    let mut lost_data_units = 1; // `lost` itself is a data unit
    let sbase = stripe * g.stripe_width();
    for u in 0..g.units_per_stripe() {
        if u == lost {
            continue;
        }
        let pu = *store
            .object(id)?
            .placement(stripe, u)
            .ok_or_else(|| SageError::Unavailable("missing placement".into()))?;
        if store.cluster.devices[pu.device].failed {
            if u < g.data {
                lost_data_units += 1;
            }
            continue;
        }
        alive += 1;
        let t = store
            .cluster
            .io(pu.device, now, g.unit, IoOp::Read, Access::Seq);
        t_read = t_read.max(t);
        if !have_all_payloads {
            continue;
        }
        if u < g.data {
            // surviving data unit: logical bytes from the block map
            let obj = store.object(id)?;
            survivors.push(read_logical(obj, sbase + u as u64 * g.unit, g.unit));
        } else {
            // parity unit payload
            match store.object(id)?.get_unit(stripe, u) {
                Some(b) => survivors.push(b.to_vec()),
                None => have_all_payloads = false,
            }
        }
    }
    // XOR parity (even duplicated) recovers at most ONE lost data unit.
    if alive < g.data || lost_data_units > 1 {
        return Err(SageError::Unavailable(format!(
            "stripe {stripe}: {lost_data_units} data units lost, {alive} live \
             (XOR parity tolerates one data loss)"
        )));
    }
    let t = t_read + g.unit as f64 * g.data as f64 / XOR_BW;
    // XOR of the K surviving units (data+parity, minus duplicates beyond
    // the first parity — single-parity reconstruction uses k units).
    let payload = if have_all_payloads && !survivors.is_empty() {
        let take = g.data as usize; // k survivors suffice for XOR codes
        Some(cpu_parity(&survivors[..take.min(survivors.len())]))
    } else {
        None
    };
    Ok((payload, t))
}

/// Phantom read: time accounting without materializing data.
pub fn read_phantom(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
) -> Result<SimTime> {
    let layout = store.object(id)?.layout.clone();
    match layout.at_offset(offset).clone() {
        Layout::Raid { data, parity, unit, tier } => {
            let g = RaidGeom { data, parity, unit, tier };
            let (_, t) = read_raid(store, id, offset, len.min(1 << 30), now, g)?;
            Ok(t)
        }
        _ => {
            let (_, t) = read(store, id, offset, len, now)?;
            Ok(t)
        }
    }
}

/// Rebuild every unit that lived on `failed_dev` onto other devices of
/// the same tier. Returns (bytes rebuilt, completion time). Driven by
/// the HA subsystem's repair decisions (§3.2.1).
pub fn repair(
    store: &mut MeroStore,
    objects: &[ObjectId],
    failed_dev: usize,
    now: SimTime,
) -> Result<(u64, SimTime)> {
    let mut rebuilt = 0u64;
    let mut t_done = now;
    for &id in objects {
        let lost: Vec<PlacedUnit> = store
            .object(id)?
            .placed_units()
            .filter(|u| u.device == failed_dev)
            .copied()
            .collect();
        let layout = store.object(id)?.layout.clone();
        let Layout::Raid { data, parity, unit, tier } =
            layout.at_offset(0).clone()
        else {
            continue;
        };
        let g = RaidGeom { data, parity, unit, tier };
        for pu in lost {
            // reconstruct (for data units) or recompute (parity units)
            let (payload, t_rec) = if pu.unit < g.data {
                reconstruct_unit(store, id, pu.stripe, pu.unit, t_done, g)?
            } else {
                // recompute parity from the stripe's logical data
                let obj = store.object(id)?;
                let ok = obj.real_blocks() > 0;
                let payload = if ok {
                    let sbase = pu.stripe * g.stripe_width();
                    let datas: Vec<Vec<u8>> = (0..g.data)
                        .map(|u| {
                            read_logical(obj, sbase + u as u64 * g.unit, g.unit)
                        })
                        .collect();
                    Some(cpu_parity(&datas))
                } else {
                    None
                };
                let t = t_done + g.unit as f64 * g.data as f64 / XOR_BW;
                (payload, t)
            };
            // allocate a fresh home, excluding the stripe's other devices
            let exclude: Vec<usize> = store
                .object(id)?
                .placed_units()
                .filter(|u| u.stripe == pu.stripe)
                .map(|u| u.device)
                .collect();
            let new_dev =
                store.pools.allocate(&mut store.cluster, g.tier, g.unit, &exclude)?;
            let t_w = store
                .cluster
                .io(new_dev, t_rec, g.unit, IoOp::Write, Access::Seq);
            store.object_mut(id)?.place_unit(PlacedUnit {
                device: new_dev,
                ..pu
            });
            // only parity payloads live in unit_data; reconstructed
            // data units are already represented by the block map
            if pu.unit >= g.data {
                if let Some(b) = payload {
                    store.object_mut(id)?.put_unit(pu.stripe, pu.unit, b);
                }
            }
            rebuilt += g.unit;
            t_done = t_done.max(t_w);
        }
    }
    Ok((rebuilt, t_done))
}

// ------------------------------------------------------------ compression

/// Deflate (compressed layouts). Header = [orig_len u64 | comp_len u64]
/// so inflate can slice the zlib stream out of the zero padding that
/// unit alignment adds.
fn deflate(data: &[u8]) -> Vec<u8> {
    use flate2::write::ZlibEncoder;
    use flate2::Compression;
    use std::io::Write as _;
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(data).unwrap();
    let z = enc.finish().unwrap();
    let mut out = Vec::with_capacity(16 + z.len());
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    out.extend_from_slice(&(z.len() as u64).to_le_bytes());
    out.extend_from_slice(&z);
    out
}

fn inflate(data: &[u8]) -> Vec<u8> {
    use flate2::read::ZlibDecoder;
    use std::io::Read as _;
    if data.len() < 16 {
        return Vec::new();
    }
    let orig = u64::from_le_bytes(data[0..8].try_into().unwrap()) as usize;
    let clen = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
    let body = &data[16..(16 + clen).min(data.len())];
    let mut dec = ZlibDecoder::new(body);
    let mut out = Vec::with_capacity(orig);
    dec.read_to_end(&mut out).ok();
    out.truncate(orig);
    out
}

/// Phantom compression estimate (typical 2x on scientific data).
fn estimate_compressed(len: u64) -> u64 {
    (len / 2).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;
    use crate::mero::MeroStore;
    use crate::sim::rng::SimRng;

    fn store() -> MeroStore {
        MeroStore::new(Testbed::sage_prototype().build_cluster())
    }

    fn raid_obj(s: &mut MeroStore, k: u32, p: u32) -> ObjectId {
        s.create_object(
            4096,
            Layout::Raid { data: k, parity: p, unit: 16384, tier: DeviceKind::Ssd },
        )
        .unwrap()
    }

    fn random_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = SimRng::new(seed);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    #[test]
    fn roundtrip_full_stripes() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 3, 1); // 3 full stripes
        let t = s.write_object(id, 0, &data, 0.0, None).unwrap();
        assert!(t > 0.0);
        let (back, _) = s.read_object(id, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn roundtrip_partial_stripe_rmw() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let full = random_bytes(4 * 16384, 2);
        s.write_object(id, 0, &full, 0.0, None).unwrap();
        // overwrite one block in the middle
        let patch = random_bytes(4096, 3);
        s.write_object(id, 8192, &patch, 1.0, None).unwrap();
        let (back, _) = s.read_object(id, 0, full.len() as u64, 2.0).unwrap();
        assert_eq!(&back[8192..8192 + 4096], &patch[..]);
        assert_eq!(&back[..8192], &full[..8192]);
        assert_eq!(&back[8192 + 4096..], &full[8192 + 4096..]);
    }

    #[test]
    fn degraded_read_reconstructs() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 4);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        // fail the device holding data unit 1 of stripe 0
        let dev = s.object(id).unwrap().placement(0, 1).unwrap().device;
        s.cluster.fail_device(dev);
        let (back, t) = s.read_object(id, 0, data.len() as u64, 1.0).unwrap();
        assert_eq!(back, data, "parity reconstruction must restore bytes");
        assert!(t > 1.0);
    }

    #[test]
    fn double_failure_without_enough_parity_fails() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384, 5);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let d0 = s.object(id).unwrap().placement(0, 0).unwrap().device;
        let d1 = s.object(id).unwrap().placement(0, 1).unwrap().device;
        s.cluster.fail_device(d0);
        s.cluster.fail_device(d1);
        assert!(matches!(
            s.read_object(id, 0, data.len() as u64, 1.0),
            Err(SageError::Unavailable(_))
        ));
    }

    #[test]
    fn repair_restores_redundancy() {
        let mut s = store();
        let id = raid_obj(&mut s, 4, 1);
        let data = random_bytes(4 * 16384 * 2, 6);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 2).unwrap().device;
        s.cluster.fail_device(dev);
        let (rebuilt, _t) = repair(&mut s, &[id], dev, 1.0).unwrap();
        assert!(rebuilt >= 16384);
        // after repair, a second failure elsewhere is survivable
        let dev2 = s.object(id).unwrap().placement(0, 0).unwrap().device;
        assert_ne!(dev2, dev);
        s.cluster.fail_device(dev2);
        let (back, _) = s.read_object(id, 0, data.len() as u64, 2.0).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn mirror_roundtrip_and_failover() {
        let mut s = store();
        let id = s
            .create_object(
                4096,
                Layout::Mirror { copies: 2, tier: DeviceKind::Ssd },
            )
            .unwrap();
        let data = random_bytes(16384, 7);
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let dev = s.object(id).unwrap().placement(0, 0).unwrap().device;
        s.cluster.fail_device(dev);
        let (back, _) = s.read_object(id, 0, data.len() as u64, 1.0).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn compressed_roundtrip() {
        let mut s = store();
        let id = s
            .create_object(
                4096,
                Layout::Compressed { inner: Box::new(Layout::default()) },
            )
            .unwrap();
        // compressible payload
        let mut data = vec![42u8; 64 * 1024];
        data[1000] = 7;
        s.write_object(id, 0, &data, 0.0, None).unwrap();
        let (back, _) = s.read_object(id, 0, data.len() as u64, 1.0).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn cpu_parity_is_xor() {
        let a = vec![0b1010u8; 8];
        let b = vec![0b0110u8; 8];
        let p = cpu_parity(&[a.clone(), b.clone()]);
        assert_eq!(p, vec![0b1100u8; 8]);
        // self-inverse
        assert_eq!(cpu_parity(&[p, b]), a);
    }

    #[test]
    fn phantom_write_accounts_time_without_memory() {
        let mut s = store();
        let id = raid_obj(&mut s, 8, 1);
        let t = s
            .write_object_phantom(id, 0, 1 << 28, 0.0) // 256 MiB
            .unwrap();
        assert!(t > 0.0);
        assert_eq!(s.object(id).unwrap().real_blocks(), 0);
        let t2 = s.read_object_phantom(id, 0, 1 << 28, t).unwrap();
        assert!(t2 > t);
    }
}
