//! Distributed Transaction Management (§3.2.1).
//!
//! "Distributed transactions are groups of updates … guaranteed to be
//! atomic with respect to failures. … traditional RDMS-style
//! transactions are known not to scale. To address this problem, Mero
//! separates transaction control proper from other issues usually
//! linked with it, such as concurrency control and isolation."
//!
//! The implementation follows that split:
//! * **Transaction control** — epoch-based group commit: transactions
//!   buffer their updates; an epoch close makes a whole batch durable
//!   with one log force. Atomicity w.r.t. failures comes from the redo
//!   log; no locks are held during the buffering phase.
//! * **Concurrency control (separate)** — optimistic validation at
//!   commit: a transaction aborts if a key it *read* was overwritten by
//!   a transaction that committed after its snapshot epoch.
//!
//! The ablation baseline [`TwoPhaseLocking`] models the RDBMS-style
//! alternative the paper argues against: per-key lock RPCs held across
//!   the transaction, with distributed deadlock avoidance (wound-wait).
//!
//! At the Clovis layer a whole transaction (begin + buffered writes +
//! commit) can be staged as ONE session op (`Session::tx`): the commit
//! completes one log force after the op's dispatch frontier, so
//! independent transaction ops of one session group-commit
//! concurrently instead of serializing through the client clock —
//! exactly the epoch group-commit story above, surfaced through the
//! one asynchronous op interface (ISSUE 4).

use std::collections::{BTreeMap, BTreeSet};

use crate::error::{Result, SageError};
use crate::sim::clock::SimTime;

/// Transaction identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TxId(pub u64);

/// A buffered update (key-value granularity; object writes are recorded
/// as (object-id, block) keys by the Clovis layer).
#[derive(Debug, Clone, PartialEq)]
pub struct TxUpdate {
    pub key: Vec<u8>,
    pub value: Vec<u8>,
}

/// State of one transaction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TxState {
    Open,
    Committed,
    Aborted,
}

#[derive(Debug)]
struct Tx {
    state: TxState,
    snapshot_epoch: u64,
    reads: BTreeSet<Vec<u8>>,
    writes: Vec<TxUpdate>,
}

/// Per-I/O cost of a log force, seconds (NVRAM-class log device).
const LOG_FORCE: f64 = 20e-6;
/// Cost of one lock RPC round-trip (2PL baseline), seconds.
const LOCK_RPC: f64 = 5e-6;

/// Epoch-based distributed transaction manager.
#[derive(Debug)]
pub struct DtmManager {
    epoch: u64,
    txns: BTreeMap<TxId, Tx>,
    next_tx: u64,
    /// Committed key versions: key -> epoch of last commit.
    versions: BTreeMap<Vec<u8>, u64>,
    /// The durable store: applied key-value state.
    store: BTreeMap<Vec<u8>, Vec<u8>>,
    /// Redo log of committed-but-unapplied epochs (crash recovery).
    redo: Vec<(u64, Vec<TxUpdate>)>,
    /// Counters.
    pub committed: u64,
    pub aborted: u64,
}

impl Default for DtmManager {
    fn default() -> Self {
        Self::new()
    }
}

impl DtmManager {
    /// Fresh manager at epoch 1.
    pub fn new() -> Self {
        DtmManager {
            epoch: 1,
            txns: BTreeMap::new(),
            next_tx: 1,
            versions: BTreeMap::new(),
            store: BTreeMap::new(),
            redo: Vec::new(),
            committed: 0,
            aborted: 0,
        }
    }

    /// Begin a transaction; its snapshot is the current epoch.
    pub fn begin(&mut self) -> TxId {
        let id = TxId(self.next_tx);
        self.next_tx += 1;
        self.txns.insert(
            id,
            Tx {
                state: TxState::Open,
                snapshot_epoch: self.epoch,
                reads: BTreeSet::new(),
                writes: Vec::new(),
            },
        );
        id
    }

    /// Record a read (returns committed value; tracks the dependency).
    pub fn read(&mut self, tx: TxId, key: &[u8]) -> Result<Option<Vec<u8>>> {
        let t = self.tx_mut(tx)?;
        t.reads.insert(key.to_vec());
        // read-your-writes
        if let Some(u) = t.writes.iter().rev().find(|u| u.key == key) {
            return Ok(Some(u.value.clone()));
        }
        Ok(self.store.get(key).cloned())
    }

    /// Buffer a write (no locks taken — transaction control separated
    /// from concurrency control).
    pub fn write(&mut self, tx: TxId, key: Vec<u8>, value: Vec<u8>) -> Result<()> {
        self.tx_mut(tx)?.writes.push(TxUpdate { key, value });
        Ok(())
    }

    /// Commit: optimistic validation + epoch group commit. Returns the
    /// completion time (one log force amortized over the epoch batch).
    pub fn commit(&mut self, tx: TxId, now: SimTime) -> Result<SimTime> {
        let t = self.tx_mut(tx)?;
        if t.state != TxState::Open {
            return Err(SageError::TxAborted(format!("{tx:?} not open")));
        }
        let snapshot = t.snapshot_epoch;
        let reads: Vec<Vec<u8>> = t.reads.iter().cloned().collect();
        // validation: no read key committed after our snapshot
        for k in &reads {
            if let Some(&v) = self.versions.get(k) {
                if v > snapshot {
                    self.tx_mut(tx)?.state = TxState::Aborted;
                    self.aborted += 1;
                    return Err(SageError::TxAborted(format!(
                        "{tx:?}: read-write conflict on {:?}",
                        String::from_utf8_lossy(k)
                    )));
                }
            }
        }
        // group commit: bump epoch, log, apply
        self.epoch += 1;
        let epoch = self.epoch;
        let t = self.tx_mut(tx)?;
        t.state = TxState::Committed;
        let writes = std::mem::take(&mut t.writes);
        self.redo.push((epoch, writes.clone()));
        for u in &writes {
            self.versions.insert(u.key.clone(), epoch);
            self.store.insert(u.key.clone(), u.value.clone());
        }
        self.committed += 1;
        Ok(now + LOG_FORCE)
    }

    /// Abort: drop buffered updates.
    pub fn abort(&mut self, tx: TxId) -> Result<()> {
        let t = self.tx_mut(tx)?;
        t.state = TxState::Aborted;
        t.writes.clear();
        self.aborted += 1;
        Ok(())
    }

    /// Committed value of `key` (outside any transaction).
    pub fn get(&self, key: &[u8]) -> Option<&Vec<u8>> {
        self.store.get(key)
    }

    /// Crash-recovery: rebuild store state from the redo log alone.
    /// Returns the number of epochs replayed. Atomicity check: the
    /// rebuilt state must equal the live state (tests assert this).
    pub fn recover(&self) -> BTreeMap<Vec<u8>, Vec<u8>> {
        let mut rebuilt = BTreeMap::new();
        for (_, updates) in &self.redo {
            for u in updates {
                rebuilt.insert(u.key.clone(), u.value.clone());
            }
        }
        rebuilt
    }

    /// Current epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn tx_mut(&mut self, tx: TxId) -> Result<&mut Tx> {
        self.txns
            .get_mut(&tx)
            .ok_or_else(|| SageError::NotFound(format!("{tx:?}")))
    }
}

// ------------------------------------------------------------------------
// Ablation baseline: RDBMS-style two-phase locking
// ------------------------------------------------------------------------

/// 2PL baseline for the DTM ablation (DESIGN.md Tbl C): every key
/// touched costs a lock RPC; locks are held to commit; wound-wait kills
/// younger transactions on conflict. Time cost grows linearly with
/// locks taken — the behaviour the paper's "known not to scale" refers
/// to.
#[derive(Debug, Default)]
pub struct TwoPhaseLocking {
    locks: BTreeMap<Vec<u8>, TxId>,
    store: BTreeMap<Vec<u8>, Vec<u8>>,
    next_tx: u64,
    held: BTreeMap<TxId, Vec<Vec<u8>>>,
    pub committed: u64,
    pub aborted: u64,
}

impl TwoPhaseLocking {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn begin(&mut self) -> TxId {
        self.next_tx += 1;
        let id = TxId(self.next_tx);
        self.held.insert(id, Vec::new());
        id
    }

    /// Acquire a lock + write. Returns new time; errors on conflict
    /// with an *older* transaction (wound-wait: younger aborts).
    pub fn write(
        &mut self,
        tx: TxId,
        key: Vec<u8>,
        value: Vec<u8>,
        now: SimTime,
    ) -> Result<SimTime> {
        match self.locks.get(&key) {
            Some(&holder) if holder != tx => {
                if holder.0 < tx.0 {
                    // younger dies
                    self.abort(tx);
                    return Err(SageError::TxAborted(format!(
                        "{tx:?} wounded by {holder:?}"
                    )));
                }
                // wound the younger holder
                self.abort(holder);
            }
            _ => {}
        }
        self.locks.insert(key.clone(), tx);
        if let Some(held) = self.held.get_mut(&tx) {
            held.push(key.clone());
        }
        self.store.insert(key, value);
        Ok(now + LOCK_RPC)
    }

    /// Commit: release locks, one log force per transaction (no group
    /// commit in the baseline).
    pub fn commit(&mut self, tx: TxId, now: SimTime) -> SimTime {
        if let Some(keys) = self.held.remove(&tx) {
            for k in keys {
                if self.locks.get(&k) == Some(&tx) {
                    self.locks.remove(&k);
                }
            }
        }
        self.committed += 1;
        now + LOG_FORCE
    }

    fn abort(&mut self, tx: TxId) {
        if let Some(keys) = self.held.remove(&tx) {
            for k in keys {
                if self.locks.get(&k) == Some(&tx) {
                    self.locks.remove(&k);
                }
            }
        }
        self.aborted += 1;
    }

    pub fn get(&self, key: &[u8]) -> Option<&Vec<u8>> {
        self.store.get(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_applies_atomically() {
        let mut m = DtmManager::new();
        let tx = m.begin();
        m.write(tx, b"a".to_vec(), b"1".to_vec()).unwrap();
        m.write(tx, b"b".to_vec(), b"2".to_vec()).unwrap();
        assert_eq!(m.get(b"a"), None, "not visible before commit");
        m.commit(tx, 0.0).unwrap();
        assert_eq!(m.get(b"a"), Some(&b"1".to_vec()));
        assert_eq!(m.get(b"b"), Some(&b"2".to_vec()));
    }

    #[test]
    fn abort_discards() {
        let mut m = DtmManager::new();
        let tx = m.begin();
        m.write(tx, b"a".to_vec(), b"1".to_vec()).unwrap();
        m.abort(tx).unwrap();
        assert_eq!(m.get(b"a"), None);
        assert!(m.commit(tx, 0.0).is_err(), "aborted tx cannot commit");
    }

    #[test]
    fn read_your_writes() {
        let mut m = DtmManager::new();
        let tx = m.begin();
        m.write(tx, b"a".to_vec(), b"1".to_vec()).unwrap();
        assert_eq!(m.read(tx, b"a").unwrap(), Some(b"1".to_vec()));
    }

    #[test]
    fn conflicting_reader_aborts() {
        let mut m = DtmManager::new();
        let t1 = m.begin();
        let t2 = m.begin();
        // t1 reads a; t2 writes a and commits first
        assert_eq!(m.read(t1, b"a").unwrap(), None);
        m.write(t2, b"a".to_vec(), b"x".to_vec()).unwrap();
        m.commit(t2, 0.0).unwrap();
        // t1 writes something based on its stale read -> must abort
        m.write(t1, b"b".to_vec(), b"y".to_vec()).unwrap();
        assert!(matches!(m.commit(t1, 0.0), Err(SageError::TxAborted(_))));
        assert_eq!(m.get(b"b"), None, "aborted writes invisible");
    }

    #[test]
    fn blind_writes_do_not_conflict() {
        let mut m = DtmManager::new();
        let t1 = m.begin();
        let t2 = m.begin();
        m.write(t1, b"a".to_vec(), b"1".to_vec()).unwrap();
        m.write(t2, b"a".to_vec(), b"2".to_vec()).unwrap();
        m.commit(t2, 0.0).unwrap();
        // t1 never read "a", so last-writer-wins is allowed
        m.commit(t1, 0.0).unwrap();
        assert_eq!(m.get(b"a"), Some(&b"1".to_vec()));
    }

    #[test]
    fn recovery_matches_live_state() {
        let mut m = DtmManager::new();
        for i in 0..10u8 {
            let tx = m.begin();
            m.write(tx, vec![i], vec![i * 2]).unwrap();
            if i % 3 == 0 {
                m.abort(tx).unwrap();
            } else {
                m.commit(tx, 0.0).unwrap();
            }
        }
        let rebuilt = m.recover();
        for (k, v) in &rebuilt {
            assert_eq!(m.get(k), Some(v));
        }
        assert_eq!(rebuilt.len(), 6, "only committed txns replay");
    }

    #[test]
    fn twopl_wound_wait() {
        let mut l = TwoPhaseLocking::new();
        let old = l.begin();
        let young = l.begin();
        l.write(old, b"k".to_vec(), b"1".to_vec(), 0.0).unwrap();
        // younger conflicts -> aborted
        assert!(l.write(young, b"k".to_vec(), b"2".to_vec(), 0.0).is_err());
        l.commit(old, 0.0);
        assert_eq!(l.aborted, 1);
        assert_eq!(l.committed, 1);
    }

    #[test]
    fn twopl_old_wounds_young_holder() {
        let mut l = TwoPhaseLocking::new();
        let young = {
            let _ = l.begin(); // id 1 (older, unused)
            l.begin() // id 2
        };
        let old = TxId(1);
        l.write(young, b"k".to_vec(), b"2".to_vec(), 0.0).unwrap();
        // older tx takes the lock by wounding the younger
        l.write(old, b"k".to_vec(), b"1".to_vec(), 0.0).unwrap();
        assert_eq!(l.aborted, 1);
    }
}
