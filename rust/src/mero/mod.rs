//! Mero: the Exascale object-storage core at the base of the SAGE stack
//! (§3.2.1).
//!
//! Feature inventory (each in its own module):
//! * [`object`] — objects as arrays of power-of-2-sized blocks
//! * [`kvs`] — key-value indices (GET/PUT/DEL/NEXT)
//! * [`container`] — object grouping with performance/format labels
//! * [`layout`] — RAID / mirrored / compressed / composite layouts
//! * [`sns`] — Server Network Striping (distributed RAID + repair)
//! * [`dtm`] — scalable distributed transactions (epoch-based)
//! * [`ha`] — high-availability: event monitoring + repair decisions
//! * [`pool`] — tiered device pools and allocation
//!
//! [`MeroStore`] composes them into the single store instance the
//! Clovis layer talks to. All time-bearing calls take a `now` virtual
//! timestamp and return the completion time, so any number of simulated
//! ranks can drive one store.

pub mod container;
pub mod dtm;
pub mod ha;
pub mod kvs;
pub mod layout;
pub mod object;
pub mod pool;
pub mod sns;
#[doc(hidden)]
pub mod sns_baseline;
#[doc(hidden)]
pub mod sns_serial;

use std::collections::BTreeMap;

use crate::cluster::Cluster;
use crate::error::{Result, SageError};
use crate::sim::clock::SimTime;
use crate::sim::device::DeviceKind;
use crate::sim::sched::IoScheduler;

pub use container::{Container, ContainerId};
pub use kvs::{IndexId, KvIndex};
pub use layout::Layout;
pub use object::{Mobject, ObjectId};
pub use pool::{CongestionView, PoolSet};

/// The Mero store: objects + indices + containers over a cluster.
pub struct MeroStore {
    pub cluster: Cluster,
    pub pools: PoolSet,
    pub dtm: dtm::DtmManager,
    pub ha: ha::HaSubsystem,
    objects: BTreeMap<ObjectId, Mobject>,
    indices: BTreeMap<IndexId, KvIndex>,
    containers: BTreeMap<ContainerId, Container>,
    next_id: u64,
}

impl MeroStore {
    /// A store over `cluster`, with pools built from the cluster's
    /// device inventory (one pool per device kind).
    pub fn new(cluster: Cluster) -> Self {
        let pools = PoolSet::from_cluster(&cluster);
        MeroStore {
            cluster,
            pools,
            dtm: dtm::DtmManager::new(),
            ha: ha::HaSubsystem::new(),
            objects: BTreeMap::new(),
            indices: BTreeMap::new(),
            containers: BTreeMap::new(),
            next_id: 1,
        }
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Elastic pool membership: attach a fresh device to enclosure
    /// `node` and register it with its tier's pool, all under load —
    /// allocations (foreground writes, repairs, drains) see the new
    /// capacity immediately; existing placements are untouched until a
    /// Migration-class rebalance session moves units onto it
    /// (`sns::rebalance_onto_with`, the inverse of `sns::drain_with`).
    /// Returns the new device's id.
    pub fn attach_device(
        &mut self,
        node: crate::cluster::NodeId,
        profile: crate::sim::device::DeviceProfile,
    ) -> Result<crate::cluster::DeviceId> {
        if node >= self.cluster.nodes.len() {
            return Err(SageError::Invalid(format!(
                "attach_device: no node {node}"
            )));
        }
        let dev = self.cluster.attach_device(node, profile);
        self.pools.register(&self.cluster, dev);
        Ok(dev)
    }

    /// Objects whose redundancy no longer covers their device losses:
    /// a RAID stripe with more than one data unit on failed devices
    /// (XOR parity reconstructs at most one), or with fewer live units
    /// than `data`; a mirror with every replica failed. This is the
    /// same arithmetic `sns::plan_reconstruct` errors with — the
    /// recovery plane uses it to turn a beyond-parity storm into a
    /// typed data-loss verdict (`clovis::RecoveryVerdict::DataLoss`)
    /// instead of a panic or silent corruption.
    pub fn unrecoverable_objects(&self, objects: &[ObjectId]) -> Vec<ObjectId> {
        let mut out = Vec::new();
        for &id in objects {
            let Ok(obj) = self.object(id) else { continue };
            let lost = match obj.layout.at_offset(0) {
                Layout::Raid { data, .. } => {
                    let data = *data;
                    // per stripe: (data units on failed devices, live units)
                    let mut per_stripe: BTreeMap<u64, (u32, u32)> =
                        BTreeMap::new();
                    for pu in obj.placed_units() {
                        let e = per_stripe.entry(pu.stripe).or_insert((0, 0));
                        if self.cluster.devices[pu.device].failed {
                            if pu.unit < data {
                                e.0 += 1;
                            }
                        } else {
                            e.1 += 1;
                        }
                    }
                    per_stripe
                        .values()
                        .any(|&(lost_data, alive)| {
                            lost_data > 1 || (lost_data > 0 && alive < data)
                        })
                }
                Layout::Mirror { .. } => {
                    let mut placed = false;
                    let mut all_failed = true;
                    for pu in obj.placed_units() {
                        placed = true;
                        if !self.cluster.devices[pu.device].failed {
                            all_failed = false;
                        }
                    }
                    placed && all_failed
                }
                _ => false,
            };
            if lost {
                out.push(id);
            }
        }
        out
    }

    // ----------------------------------------------------------- objects

    /// Create an object with the given block size (must be a power of
    /// two, §3.2.2) and layout.
    pub fn create_object(
        &mut self,
        block_size: u64,
        layout: Layout,
    ) -> Result<ObjectId> {
        layout.validate()?;
        if !crate::util::is_pow2(block_size) {
            return Err(SageError::Invalid(format!(
                "block size {block_size} is not a power of two"
            )));
        }
        let id = ObjectId(self.fresh_id());
        self.objects.insert(id, Mobject::new(id, block_size, layout));
        Ok(id)
    }

    /// Borrow an object.
    pub fn object(&self, id: ObjectId) -> Result<&Mobject> {
        self.objects
            .get(&id)
            .ok_or_else(|| SageError::NotFound(format!("object {id:?}")))
    }

    /// Mutably borrow an object.
    pub fn object_mut(&mut self, id: ObjectId) -> Result<&mut Mobject> {
        self.objects
            .get_mut(&id)
            .ok_or_else(|| SageError::NotFound(format!("object {id:?}")))
    }

    /// Delete an object at end-of-life, releasing pool space.
    pub fn delete_object(&mut self, id: ObjectId) -> Result<()> {
        let obj = self
            .objects
            .remove(&id)
            .ok_or_else(|| SageError::NotFound(format!("object {id:?}")))?;
        for unit in obj.placed_units() {
            self.pools.release(&mut self.cluster, unit.device, unit.size);
        }
        Ok(())
    }

    /// Write `data` at `offset` through the SNS engine; returns
    /// completion time. Offset and length must be block-aligned.
    pub fn write_object(
        &mut self,
        id: ObjectId,
        offset: u64,
        data: &[u8],
        now: SimTime,
        exec: Option<&crate::runtime::Executor>,
    ) -> Result<SimTime> {
        sns::write(self, id, offset, sns::Payload::Real(data), now, exec)
    }

    /// Write an owned buffer through the SNS engine (§Perf
    /// persist-by-move: the buffer becomes the object's block storage
    /// without a copy). Returns completion time.
    pub fn write_object_owned(
        &mut self,
        id: ObjectId,
        offset: u64,
        data: Vec<u8>,
        now: SimTime,
        exec: Option<&crate::runtime::Executor>,
    ) -> Result<SimTime> {
        sns::write(self, id, offset, sns::Payload::Owned(data), now, exec)
    }

    /// Phantom write: account placement + time for `len` bytes without
    /// materializing them (used by paper-scale benchmarks).
    pub fn write_object_phantom(
        &mut self,
        id: ObjectId,
        offset: u64,
        len: u64,
        now: SimTime,
    ) -> Result<SimTime> {
        sns::write(self, id, offset, sns::Payload::Phantom(len), now, None)
    }

    /// Read `len` bytes at `offset`; reconstructs through parity if
    /// devices have failed. Returns (data, completion time).
    pub fn read_object(
        &mut self,
        id: ObjectId,
        offset: u64,
        len: u64,
        now: SimTime,
    ) -> Result<(Vec<u8>, SimTime)> {
        sns::read(self, id, offset, len, now)
    }

    /// Read `dst.len()` bytes at `offset` directly into `dst` (§Perf:
    /// the healthy RAID path performs no allocation; the caller can
    /// reuse one buffer across reads). Returns completion time.
    pub fn read_object_into(
        &mut self,
        id: ObjectId,
        offset: u64,
        dst: &mut [u8],
        now: SimTime,
    ) -> Result<SimTime> {
        sns::read_into(self, id, offset, dst, now)
    }

    // ------------------------------------------- sharded group variants
    //
    // The `*_with` variants dispatch device I/O onto an external
    // [`IoScheduler`] — the per-device shards shared by a whole Clovis
    // op group (`OpGroup::sched`). Ops of the group overlap in virtual
    // time across devices; the group completes at the max over
    // per-device completion frontiers (`IoScheduler::wait_all`).

    /// [`MeroStore::write_object`] onto a shared group scheduler.
    pub fn write_object_with(
        &mut self,
        id: ObjectId,
        offset: u64,
        data: &[u8],
        now: SimTime,
        exec: Option<&crate::runtime::Executor>,
        sched: &mut IoScheduler,
    ) -> Result<SimTime> {
        sns::write_with(self, id, offset, sns::Payload::Real(data), now, exec, sched)
    }

    /// [`MeroStore::write_object_owned`] onto a shared group scheduler.
    pub fn write_object_owned_with(
        &mut self,
        id: ObjectId,
        offset: u64,
        data: Vec<u8>,
        now: SimTime,
        exec: Option<&crate::runtime::Executor>,
        sched: &mut IoScheduler,
    ) -> Result<SimTime> {
        sns::write_with(self, id, offset, sns::Payload::Owned(data), now, exec, sched)
    }

    /// [`MeroStore::read_object`] onto a shared group scheduler.
    pub fn read_object_with(
        &mut self,
        id: ObjectId,
        offset: u64,
        len: u64,
        now: SimTime,
        sched: &mut IoScheduler,
    ) -> Result<(Vec<u8>, SimTime)> {
        sns::read_with(self, id, offset, len, now, sched)
    }

    /// [`MeroStore::read_object_into`] onto a shared group scheduler.
    pub fn read_object_into_with(
        &mut self,
        id: ObjectId,
        offset: u64,
        dst: &mut [u8],
        now: SimTime,
        sched: &mut IoScheduler,
    ) -> Result<SimTime> {
        sns::read_into_with(self, id, offset, dst, now, sched)
    }

    /// Phantom read: time accounting only.
    pub fn read_object_phantom(
        &mut self,
        id: ObjectId,
        offset: u64,
        len: u64,
        now: SimTime,
    ) -> Result<SimTime> {
        sns::read_phantom(self, id, offset, len, now)
    }

    // ----------------------------------------------------------- indices

    /// Create a KV index.
    pub fn create_index(&mut self) -> IndexId {
        let id = IndexId(self.fresh_id());
        self.indices.insert(id, KvIndex::new(id));
        id
    }

    /// Borrow an index.
    pub fn index(&self, id: IndexId) -> Result<&KvIndex> {
        self.indices
            .get(&id)
            .ok_or_else(|| SageError::NotFound(format!("index {id:?}")))
    }

    /// Mutably borrow an index.
    pub fn index_mut(&mut self, id: IndexId) -> Result<&mut KvIndex> {
        self.indices
            .get_mut(&id)
            .ok_or_else(|| SageError::NotFound(format!("index {id:?}")))
    }

    /// Delete an index.
    pub fn delete_index(&mut self, id: IndexId) -> Result<()> {
        self.indices
            .remove(&id)
            .map(|_| ())
            .ok_or_else(|| SageError::NotFound(format!("index {id:?}")))
    }

    // -------------------------------------------------------- containers

    /// Create a container with a label and an optional tier hint.
    pub fn create_container(
        &mut self,
        label: &str,
        tier_hint: Option<DeviceKind>,
    ) -> ContainerId {
        let id = ContainerId(self.fresh_id());
        self.containers.insert(id, Container::new(id, label, tier_hint));
        id
    }

    /// Borrow a container.
    pub fn container(&self, id: ContainerId) -> Result<&Container> {
        self.containers
            .get(&id)
            .ok_or_else(|| SageError::NotFound(format!("container {id:?}")))
    }

    /// Mutably borrow a container.
    pub fn container_mut(&mut self, id: ContainerId) -> Result<&mut Container> {
        self.containers
            .get_mut(&id)
            .ok_or_else(|| SageError::NotFound(format!("container {id:?}")))
    }

    /// Objects grouped in `container`.
    pub fn container_objects(&self, id: ContainerId) -> Result<Vec<ObjectId>> {
        Ok(self.container(id)?.objects().to_vec())
    }

    /// Number of live objects (metadata).
    pub fn object_count(&self) -> usize {
        self.objects.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    fn store() -> MeroStore {
        MeroStore::new(Testbed::blackdog().build_cluster())
    }

    #[test]
    fn create_requires_pow2_blocks() {
        let mut s = store();
        assert!(s.create_object(4096, Layout::default()).is_ok());
        assert!(s.create_object(1000, Layout::default()).is_err());
    }

    #[test]
    fn object_lifecycle() {
        let mut s = store();
        let id = s.create_object(4096, Layout::default()).unwrap();
        assert!(s.object(id).is_ok());
        s.delete_object(id).unwrap();
        assert!(s.object(id).is_err());
        assert!(s.delete_object(id).is_err());
    }

    #[test]
    fn attach_device_registers_with_pools() {
        use crate::sim::device::DeviceProfile;
        let mut s = store();
        let before = s.pools.devices(DeviceKind::Ssd).len();
        let d = s.attach_device(0, DeviceProfile::ssd(1 << 34)).unwrap();
        assert_eq!(s.pools.devices(DeviceKind::Ssd).len(), before + 1);
        assert_eq!(s.cluster.node_of(d), Some(0));
        assert!(matches!(
            s.attach_device(99, DeviceProfile::ssd(1 << 34)),
            Err(SageError::Invalid(_))
        ));
    }

    #[test]
    fn unrecoverable_objects_applies_parity_arithmetic() {
        use crate::mero::object::PlacedUnit;
        let mut s = store();
        let id = s.create_object(4096, Layout::default()).unwrap();
        // hand-place one 4+1 stripe: unit 0 on device 0, the rest on 1
        for (unit, device) in [(0, 0), (1, 1), (2, 1), (3, 1), (4, 1)] {
            s.object_mut(id).unwrap().place_unit(PlacedUnit {
                stripe: 0,
                unit,
                device,
                size: 65536,
                is_parity: unit == 4,
            });
        }
        assert!(s.unrecoverable_objects(&[id]).is_empty(), "healthy");
        s.cluster.fail_device(0);
        // one data unit lost, 4 live units >= data=4: reconstructable
        assert!(s.unrecoverable_objects(&[id]).is_empty());
        s.cluster.fail_device(1);
        // beyond XOR tolerance now
        assert_eq!(s.unrecoverable_objects(&[id]), vec![id]);
        // mirrors: lost only when EVERY replica is on a failed device
        s.cluster.replace_device(0);
        let m = s
            .create_object(
                4096,
                Layout::Mirror { copies: 2, tier: DeviceKind::Hdd },
            )
            .unwrap();
        for (unit, device) in [(0, 0), (1, 1)] {
            s.object_mut(m).unwrap().place_unit(PlacedUnit {
                stripe: 0,
                unit,
                device,
                size: 4096,
                is_parity: false,
            });
        }
        assert!(s.unrecoverable_objects(&[m]).is_empty(), "one replica lives");
        s.cluster.fail_device(0);
        assert_eq!(s.unrecoverable_objects(&[m]), vec![m]);
        // unknown ids are skipped, not errors
        assert!(s.unrecoverable_objects(&[ObjectId(999)]).is_empty());
    }

    #[test]
    fn index_lifecycle() {
        let mut s = store();
        let id = s.create_index();
        s.index_mut(id).unwrap().put(b"k".to_vec(), b"v".to_vec());
        assert_eq!(s.index(id).unwrap().get(b"k"), Some(b"v".as_ref()));
        s.delete_index(id).unwrap();
        assert!(s.index(id).is_err());
    }
}
