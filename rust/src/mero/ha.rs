//! High-Availability subsystem (§3.2.1).
//!
//! "The HA subsystem monitors failure events … Then, on the basis of
//! the collected events, the HA system decides whether to take action.
//! The HA subsystem does not consider events in isolation but
//! quantifies, over the recent history of the cluster, a quasi-ordered
//! set of events to determine which repair procedure to engage, if
//! any."
//!
//! Concretely: events accumulate in a sliding history window. Decision
//! rules over the *set* (not single events):
//! * a hard device failure → immediate SNS repair of that device;
//! * ≥ `transient_threshold` transients on one device within the window
//!   → proactive repair (the device is dying);
//! * correlated transients across many devices of one node within the
//!   window → node-level alert (repair deferred to operator policy);
//! * isolated transient → no action.

use std::collections::BTreeMap;

use crate::cluster::failure::{FailureEvent, FailureKind};
use crate::cluster::DeviceId;
use crate::sim::clock::SimTime;

/// Repair procedures the HA subsystem can engage.
#[derive(Debug, Clone, PartialEq)]
pub enum RepairAction {
    /// Rebuild all units of this device onto spares (SNS repair).
    /// Executed by `Client::repair_with` (a recovery-plane session).
    RebuildDevice(DeviceId),
    /// Proactively drain a degrading device before it hard-fails.
    /// Executed by `Client::drain_with` (a recovery-plane session:
    /// units are read off the still-live device and re-homed at their
    /// own read frontiers — no reconstruction needed).
    ProactiveDrain(DeviceId),
    /// Too many correlated events on one node: flag for operator.
    NodeAlert { node: usize, events: usize },
    /// No action (event set below thresholds).
    None,
}

/// Sliding-window failure-event analyzer.
#[derive(Debug)]
pub struct HaSubsystem {
    /// History window length, seconds of virtual time.
    pub window: SimTime,
    /// Transients on one device within the window that trigger a drain.
    pub transient_threshold: usize,
    /// Events on one *node* within the window that trigger an alert.
    pub node_threshold: usize,
    history: Vec<FailureEvent>,
    /// Devices already being repaired (suppress duplicate actions).
    in_repair: BTreeMap<DeviceId, SimTime>,
    /// Completed recovery actions — device rebuilds AND proactive
    /// drains — as (device, engaged at, completed at) in virtual time.
    /// The completion stamp is the recovery plane's scheduler
    /// completion (`IoScheduler::wait_all` over the repair's op
    /// group), threaded in via [`HaSubsystem::repair_done`].
    pub repair_log: Vec<(DeviceId, SimTime, SimTime)>,
    /// Counters for ADDB.
    pub repairs_started: u64,
    pub drains_started: u64,
    pub alerts: u64,
    /// Recoveries retracted before completing: error paths AND
    /// abort-and-restart when a device re-fails while its recovery
    /// session is in flight (storm overlap; see
    /// [`HaSubsystem::reopen_last`]).
    pub repairs_aborted: u64,
}

impl Default for HaSubsystem {
    fn default() -> Self {
        Self::new()
    }
}

impl HaSubsystem {
    /// Defaults: 1 h window, 3 transients → drain, 8 node events → alert.
    pub fn new() -> Self {
        HaSubsystem {
            window: 3600.0,
            transient_threshold: 3,
            node_threshold: 8,
            history: Vec::new(),
            in_repair: BTreeMap::new(),
            repair_log: Vec::new(),
            repairs_started: 0,
            drains_started: 0,
            alerts: 0,
            repairs_aborted: 0,
        }
    }

    /// Ingest one failure event; returns the repair decision.
    /// `node_of` maps devices to nodes for correlation analysis.
    pub fn observe<F: Fn(DeviceId) -> Option<usize>>(
        &mut self,
        ev: FailureEvent,
        node_of: F,
    ) -> RepairAction {
        self.history.push(ev);
        self.prune(ev.at);

        match ev.kind {
            FailureKind::Device(d) => {
                if self.in_repair.contains_key(&d) {
                    return RepairAction::None;
                }
                self.in_repair.insert(d, ev.at);
                self.repairs_started += 1;
                RepairAction::RebuildDevice(d)
            }
            FailureKind::Transient(d) => {
                if self.in_repair.contains_key(&d) {
                    return RepairAction::None;
                }
                // per-device transient count over the window
                let dev_count = self
                    .history
                    .iter()
                    .filter(|e| matches!(e.kind, FailureKind::Transient(x) if x == d))
                    .count();
                if dev_count >= self.transient_threshold {
                    self.in_repair.insert(d, ev.at);
                    self.drains_started += 1;
                    return RepairAction::ProactiveDrain(d);
                }
                // node-correlated events
                if let Some(node) = node_of(d) {
                    let node_count = self
                        .history
                        .iter()
                        .filter(|e| node_of(e.kind.device()) == Some(node))
                        .count();
                    if node_count >= self.node_threshold {
                        self.alerts += 1;
                        return RepairAction::NodeAlert {
                            node,
                            events: node_count,
                        };
                    }
                }
                RepairAction::None
            }
        }
    }

    /// Mark a repair finished at `completed_at` — the repair op
    /// group's scheduler completion (`IoScheduler::wait_all`), carried
    /// here by the recovery plane (`Client::repair_with`). The device
    /// may be observed again, and the repair interval is appended to
    /// [`HaSubsystem::repair_log`].
    pub fn repair_done(&mut self, dev: DeviceId, completed_at: SimTime) {
        if let Some(engaged_at) = self.in_repair.remove(&dev) {
            self.repair_log.push((dev, engaged_at, completed_at));
        }
    }

    /// A recovery action that FAILED to complete (e.g. a drain with no
    /// spare capacity) or was preempted by a re-failure: un-engage the
    /// device WITHOUT logging a repair interval, so future failure
    /// events on it decide fresh actions instead of being suppressed
    /// by the in-repair check forever. Called by the recovery plane's
    /// error paths and its storm-overlap handling.
    pub fn repair_aborted(&mut self, dev: DeviceId) {
        if self.in_repair.remove(&dev).is_some() {
            self.repairs_aborted += 1;
        }
    }

    /// Retract the most recent LOGGED recovery of `dev` and re-engage
    /// the device as in-flight from that recovery's original
    /// engagement time. The storm-hardened feed consumer calls this
    /// when a device RE-FAILS at a virtual time before its previous
    /// recovery's completion stamp: that recovery never really
    /// finished, so its interval must not count — the consumer retracts
    /// it here, then aborts the re-engaged attempt
    /// ([`HaSubsystem::repair_aborted`]) and lets the re-failure event
    /// decide a fresh repair. Returns the retracted
    /// `(engaged_at, completed_at)` interval, or `None` when `dev` has
    /// no logged recovery (nothing to retract).
    pub fn reopen_last(&mut self, dev: DeviceId) -> Option<(SimTime, SimTime)> {
        let idx = self.repair_log.iter().rposition(|(d, _, _)| *d == dev)?;
        let (_, engaged_at, completed_at) = self.repair_log.remove(idx);
        self.in_repair.insert(dev, engaged_at);
        Some((engaged_at, completed_at))
    }

    /// Mean duration of completed recovery actions in virtual time
    /// (0.0 when none have completed) — the "how fast does the cluster
    /// heal" telemetry the §3.2.1 HA narrative asks for. Includes
    /// proactive drains, executed by the recovery plane as sessions
    /// (`Client::drain_with` → `sns::drain_with`, the
    /// `RepairAction::ProactiveDrain` executor).
    pub fn mean_repair_time(&self) -> SimTime {
        if self.repair_log.is_empty() {
            return 0.0;
        }
        self.repair_log
            .iter()
            .map(|(_, from, to)| (to - from).max(0.0))
            .sum::<f64>()
            / self.repair_log.len() as f64
    }

    /// Devices currently under repair.
    pub fn repairing(&self) -> Vec<DeviceId> {
        self.in_repair.keys().copied().collect()
    }

    fn prune(&mut self, now: SimTime) {
        let cutoff = now - self.window;
        self.history.retain(|e| e.at >= cutoff);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: SimTime, kind: FailureKind) -> FailureEvent {
        FailureEvent { at, kind }
    }

    #[test]
    fn hard_failure_triggers_rebuild_once() {
        let mut ha = HaSubsystem::new();
        let a = ha.observe(ev(1.0, FailureKind::Device(3)), |_| Some(0));
        assert_eq!(a, RepairAction::RebuildDevice(3));
        // duplicate event while repairing: suppressed
        let a2 = ha.observe(ev(2.0, FailureKind::Device(3)), |_| Some(0));
        assert_eq!(a2, RepairAction::None);
        ha.repair_done(3, 2.5);
        let a3 = ha.observe(ev(3.0, FailureKind::Device(3)), |_| Some(0));
        assert_eq!(a3, RepairAction::RebuildDevice(3));
        // the completion stamp landed in the repair log
        assert_eq!(ha.repair_log, vec![(3, 1.0, 2.5)]);
        assert!((ha.mean_repair_time() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn aborted_recovery_re_arms_the_device() {
        // a drain/rebuild that errors out must not leave the device
        // "in repair" forever — the next failure event decides fresh
        let mut ha = HaSubsystem::new();
        for i in 0..3 {
            ha.observe(ev(i as f64, FailureKind::Transient(5)), |_| Some(0));
        }
        assert_eq!(ha.repairing(), vec![5], "drain engaged");
        ha.repair_aborted(5);
        assert!(ha.repairing().is_empty());
        assert!(ha.repair_log.is_empty(), "no interval logged for a failure");
        let a = ha.observe(ev(4.0, FailureKind::Device(5)), |_| Some(0));
        assert_eq!(
            a,
            RepairAction::RebuildDevice(5),
            "the hard failure is acted on, not suppressed"
        );
    }

    #[test]
    fn reopen_last_retracts_the_stamp_and_reengages() {
        let mut ha = HaSubsystem::new();
        assert_eq!(ha.observe(ev(1.0, FailureKind::Device(3)), |_| Some(0)),
            RepairAction::RebuildDevice(3));
        ha.repair_done(3, 10.0);
        // the device re-fails at t=5.0 < completion 10.0: the consumer
        // retracts the stamp and aborts the re-engaged attempt
        assert_eq!(ha.reopen_last(3), Some((1.0, 10.0)));
        assert!(ha.repair_log.is_empty(), "interval retracted");
        assert_eq!(ha.repairing(), vec![3], "re-engaged as in-flight");
        ha.repair_aborted(3);
        assert_eq!(ha.repairs_aborted, 1);
        assert!(ha.repairing().is_empty());
        // the re-failure decides a FRESH repair, counted again
        assert_eq!(ha.observe(ev(5.0, FailureKind::Device(3)), |_| Some(0)),
            RepairAction::RebuildDevice(3));
        assert_eq!(ha.repairs_started, 2);
        ha.repair_done(3, 12.0);
        assert_eq!(ha.repair_log, vec![(3, 5.0, 12.0)], "one interval, not two");
        // nothing to retract on a device with no logged recovery
        assert_eq!(ha.reopen_last(99), None);
        // aborting an unengaged device is a no-op, not a double count
        ha.repair_aborted(99);
        assert_eq!(ha.repairs_aborted, 1);
    }

    #[test]
    fn isolated_transient_no_action() {
        let mut ha = HaSubsystem::new();
        let a = ha.observe(ev(1.0, FailureKind::Transient(5)), |_| Some(0));
        assert_eq!(a, RepairAction::None);
    }

    #[test]
    fn repeated_transients_trigger_drain() {
        let mut ha = HaSubsystem::new();
        ha.observe(ev(1.0, FailureKind::Transient(5)), |_| Some(0));
        ha.observe(ev(2.0, FailureKind::Transient(5)), |_| Some(0));
        let a = ha.observe(ev(3.0, FailureKind::Transient(5)), |_| Some(0));
        assert_eq!(a, RepairAction::ProactiveDrain(5));
        assert_eq!(ha.drains_started, 1);
    }

    #[test]
    fn window_expiry_forgets_old_transients() {
        let mut ha = HaSubsystem::new();
        ha.window = 10.0;
        ha.observe(ev(1.0, FailureKind::Transient(5)), |_| Some(0));
        ha.observe(ev(2.0, FailureKind::Transient(5)), |_| Some(0));
        // third transient arrives after the window slid past the others
        let a = ha.observe(ev(50.0, FailureKind::Transient(5)), |_| Some(0));
        assert_eq!(a, RepairAction::None);
    }

    #[test]
    fn node_correlation_alerts() {
        let mut ha = HaSubsystem::new();
        ha.node_threshold = 4;
        // transients on different devices of the same node
        for (i, d) in [10, 11, 12].iter().enumerate() {
            let a = ha.observe(
                ev(i as f64, FailureKind::Transient(*d)),
                |_| Some(7),
            );
            assert_eq!(a, RepairAction::None);
        }
        let a = ha.observe(ev(4.0, FailureKind::Transient(13)), |_| Some(7));
        assert_eq!(a, RepairAction::NodeAlert { node: 7, events: 4 });
    }
}
