//! The de-sharded op-execution path, preserved as the **serial-fold
//! oracle** for the sharded scheduler (ISSUE 2 tentpole; see
//! ARCHITECTURE.md and `sim::sched`).
//!
//! Differential contract, enforced by `tests/prop_sched.rs`,
//! `tests/prop_repair.rs` and the in-bench asserts of
//! `benches/ablate_sched.rs` / `benches/ablate_repair.rs`:
//!
//! * **bytes** — this engine persists byte-identical state to the
//!   sharded engine (same block segments via [`sns::persist_extent`],
//!   same parity bytes) and reads reconstruct identically (shared
//!   [`sns::plan_reconstruct`] planner), so either engine can read the
//!   other's objects;
//! * **time** — completion is a *serial fold*: [`writev`]/[`readv`]
//!   thread ONE timeline through the batch (op `i+1` submits when op
//!   `i` completes) and every unit I/O inside an op chains on that
//!   timeline with its own `io()` call. One slow device therefore
//!   pushes completion for every later unit and op in the group —
//!   exactly the serialization the sharded engine removes. Sharded
//!   completion must be <= this oracle's on every geometry.
//! * **recovery** — [`repair`] preserves the serial-fold rebuild (one
//!   lost unit after another, survivor reads and rebuild write chained
//!   with direct `io()` calls) as the oracle for the scheduler-driven
//!   recovery plane (`sns::repair_with`, sharded degraded reads).
//!
//! Plain RAID layouts only (the hot path under measurement), like
//! `sns_baseline` — which remains the *allocation* baseline for the
//! PR-1 zero-copy work, while this module is the *scheduling* baseline
//! for the PR-2 sharding work.
//!
//! [`sns::persist_extent`]: super::sns
//! [`sns::plan_reconstruct`]: super::sns

use std::sync::Arc;

use crate::error::{Result, SageError};
use crate::mero::layout::Layout;
use crate::mero::object::{Mobject, ObjectId, PlacedUnit};
use crate::mero::MeroStore;
use crate::runtime::Executor;
use crate::sim::clock::SimTime;
use crate::sim::device::{Access, IoOp};

use super::sns::{
    compute_parity, compute_parity_slices, cpu_parity, persist_extent,
    plan_reconstruct, Payload, RaidGeom,
};

/// XOR costing constant (mirror of the engine's).
const XOR_BW: f64 = 5.0e9;

fn geom(store: &MeroStore, id: ObjectId, offset: u64) -> Result<RaidGeom> {
    let layout = store.object(id)?.layout.clone();
    if layout.compressed() {
        return Err(SageError::Invalid(
            "sns_serial: plain RAID layouts only".into(),
        ));
    }
    match layout.at_offset(offset) {
        Layout::Raid { data, parity, unit, tier } => Ok(RaidGeom {
            data: *data,
            parity: *parity,
            unit: *unit,
            tier: *tier,
        }),
        _ => Err(SageError::Invalid(
            "sns_serial: plain RAID layouts only".into(),
        )),
    }
}

fn ensure_placement(
    store: &mut MeroStore,
    id: ObjectId,
    stripe: u64,
    g: RaidGeom,
) -> Result<()> {
    if store.object(id)?.placement(stripe, 0).is_some() {
        return Ok(());
    }
    let mut used = Vec::new();
    for u in 0..g.units_per_stripe() {
        let d = store.pools.allocate(&mut store.cluster, g.tier, g.unit, &used)?;
        used.push(d);
        store.object_mut(id)?.place_unit(PlacedUnit {
            stripe,
            unit: u,
            device: d,
            size: g.unit,
            is_parity: u >= g.data,
        });
    }
    Ok(())
}

fn read_logical(obj: &Mobject, offset: u64, len: u64) -> Vec<u8> {
    let mut out = vec![0u8; len as usize];
    obj.read_range_into(offset, &mut out);
    out
}

/// Serial-timing reconstruction of one lost data unit: every survivor
/// read is accounted with a direct `io()` call submitted at `now` (the
/// de-sharded semantics the recovery plane replaces). Bytes come from
/// the shared `sns::plan_reconstruct` planner, so both engines
/// reconstruct identically and differ only in scheduling.
fn reconstruct_unit(
    store: &mut MeroStore,
    id: ObjectId,
    stripe: u64,
    lost: u32,
    now: SimTime,
    g: RaidGeom,
) -> Result<(Option<Vec<u8>>, SimTime)> {
    let plan = plan_reconstruct(store, id, stripe, lost, g)?;
    let mut t_read = now;
    for &d in &plan.devices {
        let t = store.cluster.io(d, now, g.unit, IoOp::Read, Access::Seq);
        t_read = t_read.max(t);
    }
    Ok((plan.payload, t_read + g.unit as f64 * g.data as f64 / XOR_BW))
}

/// Serial-fold repair oracle: lost units rebuild one after another —
/// each unit's survivor reads start at the previous unit's rebuild
/// completion and the rebuild write chains behind its reconstruction
/// via direct `io()` calls (the pre-recovery-plane semantics). The
/// sharded `sns::repair` must produce identical bytes and placements
/// and complete no later (`tests/prop_repair.rs`,
/// `benches/ablate_repair.rs`).
pub fn repair(
    store: &mut MeroStore,
    objects: &[ObjectId],
    failed_dev: usize,
    now: SimTime,
) -> Result<(u64, SimTime)> {
    let mut rebuilt = 0u64;
    let mut t_done = now;
    for &id in objects {
        let lost: Vec<PlacedUnit> = store
            .object(id)?
            .placed_units()
            .filter(|u| u.device == failed_dev)
            .copied()
            .collect();
        let layout = store.object(id)?.layout.clone();
        let Layout::Raid { data, parity, unit, tier } =
            layout.at_offset(0).clone()
        else {
            continue;
        };
        let g = RaidGeom { data, parity, unit, tier };
        for pu in lost {
            // reconstruct (for data units) or recompute (parity units)
            let (payload, t_rec) = if pu.unit < g.data {
                reconstruct_unit(store, id, pu.stripe, pu.unit, t_done, g)?
            } else {
                // recompute parity from the stripe's logical data
                let obj = store.object(id)?;
                let payload = if obj.real_blocks() > 0 {
                    let sbase = pu.stripe * g.stripe_width();
                    let datas: Vec<Vec<u8>> = (0..g.data)
                        .map(|u| {
                            read_logical(obj, sbase + u as u64 * g.unit, g.unit)
                        })
                        .collect();
                    Some(cpu_parity(&datas))
                } else {
                    None
                };
                let t = t_done + g.unit as f64 * g.data as f64 / XOR_BW;
                (payload, t)
            };
            // allocate a fresh home, excluding the stripe's other devices
            let exclude: Vec<usize> = store
                .object(id)?
                .placed_units()
                .filter(|u| u.stripe == pu.stripe)
                .map(|u| u.device)
                .collect();
            let new_dev =
                store.pools.allocate(&mut store.cluster, g.tier, g.unit, &exclude)?;
            let t_w = store
                .cluster
                .io(new_dev, t_rec, g.unit, IoOp::Write, Access::Seq);
            store.object_mut(id)?.place_unit(PlacedUnit {
                device: new_dev,
                ..pu
            });
            // only parity payloads live in unit_data; reconstructed
            // data units are already represented by the block map
            if pu.unit >= g.data {
                if let Some(b) = payload {
                    store.object_mut(id)?.put_unit(pu.stripe, pu.unit, b);
                }
            }
            rebuilt += g.unit;
            t_done = t_done.max(t_w);
        }
    }
    Ok((rebuilt, t_done))
}

/// Serial-fold write: unit I/Os chain on one timeline; returns the
/// time the LAST unit completes. Stored bytes are identical to the
/// sharded engine's.
pub fn write(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    data: &[u8],
    now: SimTime,
    exec: Option<&Executor>,
) -> Result<SimTime> {
    let len = data.len() as u64;
    if len == 0 {
        return Ok(now);
    }
    store.object(id)?.check_aligned(offset, len)?;
    let g = geom(store, id, offset)?;
    let width = g.stripe_width();
    let first_stripe = offset / width;
    let last_stripe = (offset + len - 1) / width;
    let mut t = now;

    for stripe in first_stripe..=last_stripe {
        ensure_placement(store, id, stripe, g)?;
        let sbase = stripe * width;
        let wstart = offset.max(sbase);
        let wend = (offset + len).min(sbase + width);
        let full_stripe = wstart == sbase && wend == sbase + width;

        // ---- parity bytes (same values as the sharded engine) ----------
        let parity_unit: Option<Vec<u8>> = if g.parity > 0 {
            if full_stripe {
                let slices: Vec<&[u8]> = (0..g.data)
                    .map(|u| {
                        let ustart = (sbase + u as u64 * g.unit - offset) as usize;
                        &data[ustart..ustart + g.unit as usize]
                    })
                    .collect();
                Some(compute_parity_slices(&slices, exec)?)
            } else {
                let mut units: Vec<Vec<u8>> = Vec::with_capacity(g.data as usize);
                for u in 0..g.data {
                    let ustart = sbase + u as u64 * g.unit;
                    let uend = ustart + g.unit;
                    let mut buf =
                        read_logical(store.object(id)?, ustart, g.unit);
                    let ov_start = wstart.max(ustart);
                    let ov_end = wend.min(uend);
                    if ov_start < ov_end {
                        buf[(ov_start - ustart) as usize
                            ..(ov_end - ustart) as usize]
                            .copy_from_slice(
                                &data[(ov_start - offset) as usize
                                    ..(ov_end - offset) as usize],
                            );
                    }
                    units.push(buf);
                }
                Some(compute_parity(&units, exec)?)
            }
        } else {
            None
        };

        // ---- RMW reads: SERIAL chain (each starts when the previous
        // completes, even on a different device) ------------------------
        if !full_stripe {
            for u in 0..g.units_per_stripe() {
                let pu = *store.object(id)?.placement(stripe, u).unwrap();
                if !store.cluster.devices[pu.device].failed {
                    t = store
                        .cluster
                        .io(pu.device, t, g.unit, IoOp::Read, Access::Random);
                }
            }
        }

        if g.parity > 0 {
            t += (g.data as u64 * g.unit) as f64 / XOR_BW;
        }

        // ---- unit writes: SERIAL chain ---------------------------------
        for u in 0..g.units_per_stripe() {
            let pu = *store.object(id)?.placement(stripe, u).unwrap();
            if store.cluster.devices[pu.device].failed {
                continue;
            }
            let t_net = store.cluster.net.pt2pt(g.unit);
            t = store
                .cluster
                .io(pu.device, t + t_net, g.unit, IoOp::Write, Access::Seq);
        }

        // ---- persist parity (Arc-shared across the stripe's copies) ----
        if let Some(p) = parity_unit {
            let shared: Arc<Vec<u8>> = Arc::new(p);
            let obj = store.object_mut(id)?;
            for pi in 0..g.parity {
                obj.put_unit(stripe, g.data + pi, shared.clone());
            }
        }
    }

    persist_extent(store, id, offset, Payload::Real(data))?;
    Ok(t)
}

/// Serial-fold read: overlapping unit I/Os chain on one timeline.
/// Returns (bytes, completion) — bytes identical to the sharded
/// engine's, including parity reconstruction under failures.
pub fn read(
    store: &mut MeroStore,
    id: ObjectId,
    offset: u64,
    len: u64,
    now: SimTime,
) -> Result<(Vec<u8>, SimTime)> {
    if len == 0 {
        return Ok((Vec::new(), now));
    }
    store.object(id)?.check_aligned(offset, len)?;
    let g = geom(store, id, offset)?;
    let width = g.stripe_width();
    let mut out = vec![0u8; len as usize];
    let mut t = now;

    let first_stripe = offset / width;
    let last_stripe = (offset + len - 1) / width;
    for stripe in first_stripe..=last_stripe {
        let sbase = stripe * width;
        for u in 0..g.data {
            let ustart = sbase + u as u64 * g.unit;
            let uend = ustart + g.unit;
            let ov_start = offset.max(ustart);
            let ov_end = (offset + len).min(uend);
            if ov_start >= ov_end {
                continue;
            }
            let placed = store.object(id)?.placement(stripe, u).copied();
            let Some(pu) = placed else { continue }; // sparse zeros
            if !store.cluster.devices[pu.device].failed {
                t = store
                    .cluster
                    .io(pu.device, t, g.unit, IoOp::Read, Access::Seq);
                store.object(id)?.read_range_into(
                    ov_start,
                    &mut out[(ov_start - offset) as usize
                        ..(ov_end - offset) as usize],
                );
                continue;
            }
            if g.parity == 0 {
                return Err(SageError::Unavailable(format!(
                    "unit ({stripe},{u}) lost and no parity"
                )));
            }
            // reconstruction chains on the same timeline
            let (bytes, tr) = reconstruct_unit(store, id, stripe, u, t, g)?;
            if let Some(b) = bytes {
                let d = (ov_start - offset) as usize..(ov_end - offset) as usize;
                let s = (ov_start - ustart) as usize..(ov_end - ustart) as usize;
                out[d].copy_from_slice(&b[s]);
            }
            t = t.max(tr);
        }
    }
    Ok((out, t))
}

/// Serial-fold batch write: op `i+1` submits when op `i` completes —
/// the group-level serialization the sharded `Client::writev` removes.
pub fn writev(
    store: &mut MeroStore,
    id: ObjectId,
    extents: &[(u64, &[u8])],
    now: SimTime,
    exec: Option<&Executor>,
) -> Result<SimTime> {
    let mut t = now;
    for (off, data) in extents {
        t = write(store, id, *off, data, t, exec)?;
    }
    Ok(t)
}

/// Serial-fold batch read over `(offset, len)` extents.
pub fn readv(
    store: &mut MeroStore,
    id: ObjectId,
    extents: &[(u64, u64)],
    now: SimTime,
) -> Result<(Vec<Vec<u8>>, SimTime)> {
    let mut t = now;
    let mut out = Vec::with_capacity(extents.len());
    for (off, len) in extents {
        let (d, tt) = read(store, id, *off, *len, t)?;
        t = tt;
        out.push(d);
    }
    Ok((out, t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;
    use crate::sim::device::DeviceKind;
    use crate::sim::rng::SimRng;

    fn stores() -> (MeroStore, MeroStore) {
        (
            MeroStore::new(Testbed::sage_prototype().build_cluster()),
            MeroStore::new(Testbed::sage_prototype().build_cluster()),
        )
    }

    fn raid(s: &mut MeroStore, k: u32, p: u32) -> ObjectId {
        s.create_object(
            4096,
            Layout::Raid { data: k, parity: p, unit: 16384, tier: DeviceKind::Ssd },
        )
        .unwrap()
    }

    fn random_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = SimRng::new(seed);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    #[test]
    fn serial_and_sharded_engines_store_identical_bytes() {
        let (mut a, mut b) = stores();
        let ida = raid(&mut a, 4, 1);
        let idb = raid(&mut b, 4, 1);
        let data = random_bytes(4 * 16384 * 2, 31);
        write(&mut a, ida, 0, &data, 0.0, None).unwrap();
        b.write_object(idb, 0, &data, 0.0, None).unwrap();
        // cross-engine reads: each engine reads the other's state
        let (cross_a, _) = b.read_object(idb, 0, data.len() as u64, 1.0).unwrap();
        let (cross_b, _) = read(&mut a, ida, 0, data.len() as u64, 1.0).unwrap();
        assert_eq!(cross_a, data);
        assert_eq!(cross_b, data);
        // parity bytes agree too (degraded read through each engine)
        let da = a.object(ida).unwrap().placement(0, 1).unwrap().device;
        let db = b.object(idb).unwrap().placement(0, 1).unwrap().device;
        a.cluster.fail_device(da);
        b.cluster.fail_device(db);
        let (ra, _) = read(&mut a, ida, 0, data.len() as u64, 2.0).unwrap();
        let (rb, _) = b.read_object(idb, 0, data.len() as u64, 2.0).unwrap();
        assert_eq!(ra, rb, "reconstruction must agree between engines");
    }

    #[test]
    fn serial_fold_chains_the_batch() {
        // two single-stripe ops on the serial path take strictly longer
        // than the later op alone: the fold pushes op 2 behind op 1
        let (mut a, _) = stores();
        let id = raid(&mut a, 4, 1);
        let data = random_bytes(4 * 16384, 32);
        let t_one = write(&mut a, id, 0, &data, 0.0, None).unwrap();
        let t_batch = writev(
            &mut a,
            id,
            &[(0, &data[..]), (4 * 16384, &data[..])],
            100.0,
            None,
        )
        .unwrap();
        assert!(t_batch - 100.0 > t_one, "serial fold accumulates");
    }

    #[test]
    fn serial_repair_oracle_matches_sharded_repair() {
        let (mut a, mut b) = stores();
        let ida = raid(&mut a, 4, 1);
        let idb = raid(&mut b, 4, 1);
        let data = random_bytes(4 * 16384 * 2, 33);
        write(&mut a, ida, 0, &data, 0.0, None).unwrap();
        b.write_object(idb, 0, &data, 0.0, None).unwrap();
        let da = a.object(ida).unwrap().placement(0, 1).unwrap().device;
        let db = b.object(idb).unwrap().placement(0, 1).unwrap().device;
        assert_eq!(da, db, "identical write order => identical placements");
        a.cluster.fail_device(da);
        b.cluster.fail_device(db);
        let (ra, ta) = repair(&mut a, &[ida], da, 100.0).unwrap();
        let (rb, tb) =
            crate::mero::sns::repair(&mut b, &[idb], db, 100.0).unwrap();
        assert_eq!(ra, rb, "same bytes rebuilt");
        assert!(
            tb <= ta * (1.0 + 1e-9),
            "sharded repair never later: {tb} vs {ta}"
        );
        let (va, _) = read(&mut a, ida, 0, data.len() as u64, 2.0 * ta).unwrap();
        let (vb, _) =
            b.read_object(idb, 0, data.len() as u64, 2.0 * ta).unwrap();
        assert_eq!(va, data);
        assert_eq!(vb, data);
    }

    #[test]
    fn serial_rejects_non_raid() {
        let (mut a, _) = stores();
        let id = a
            .create_object(4096, Layout::Mirror { copies: 2, tier: DeviceKind::Ssd })
            .unwrap();
        assert!(write(&mut a, id, 0, &[0u8; 4096], 0.0, None).is_err());
    }
}
