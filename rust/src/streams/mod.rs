//! MPI streams: decoupling simulation from I/O and post-processing
//! (§3.2.4, §4.2, Fig 7).
//!
//! "Streams are a continuous sequence of fine-grained data structures
//! that move from a set of processes, called data producers, to another
//! set of processes, called data consumers. … Stream elements are
//! processed online such that they are discarded as soon as they are
//! consumed by the attached computation."
//!
//! [`StreamSim`] hosts P producers and C consumers (the paper's config
//! is one consumer per 15 producers). Producers push bursts of elements
//! and continue computing — the send is asynchronous and cheap;
//! consumers overlap the attached computation (post-processing + file
//! I/O) with the producers' next steps. Backpressure: a bounded queue
//! of in-flight bursts per consumer; a producer blocks only when its
//! consumer's queue is full. This overlap is exactly why the streaming
//! model wins at scale over collective I/O ([`collective`] baseline).
//!
//! Module map (ARCHITECTURE.md §Module map rows `streams/`):
//!
//! * this module — [`StreamSim`]: producer/consumer rank clocks,
//!   bounded in-flight queues, attached computation, and the Fig 7
//!   measurement surface (`benches/fig7_streams.rs`,
//!   `examples/ipic3d_streams.rs` drive it with the iPIC3D particle
//!   workload from `apps/ipic3d`);
//! * [`collective`] — the collective-I/O baseline the paper compares
//!   streaming against (every rank synchronizes, then writes).
//!
//! Consumer-side file I/O costs device time on the simulated storage
//! targets, so stream post-processing contends with the rest of the
//! stack exactly as §3.2.4 intends; ARCHITECTURE.md (§Sharded
//! scheduler, §QoS plane) maps how that device time is scheduled and
//! split against recovery traffic.

pub mod collective;

use std::collections::VecDeque;

use crate::config::Testbed;
use crate::error::{Result, SageError};
use crate::sim::clock::{RankClocks, SimTime};
use crate::sim::device::{Access, Device, DeviceKind, IoOp};
use crate::sim::network::NetworkModel;

/// One stream element: the paper's iPIC3D particle record — "eight
/// scalar values: position (x,y,z), velocity (u,v,w), charge q and an
/// identifier ID" (§4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamElement {
    pub x: f32,
    pub y: f32,
    pub z: f32,
    pub u: f32,
    pub v: f32,
    pub w: f32,
    pub q: f32,
    pub id: f32,
}

impl StreamElement {
    /// Serialized size (8 f32 scalars).
    pub const BYTES: u64 = 32;

    /// Flatten to the (n, 8) f32 row layout the kernels consume.
    pub fn to_row(&self) -> [f32; 8] {
        [self.x, self.y, self.z, self.u, self.v, self.w, self.q, self.id]
    }

    /// Kinetic energy (same formula as the L1 kernel / ref oracle).
    pub fn energy(&self) -> f32 {
        0.5 * self.q.abs() * (self.u * self.u + self.v * self.v + self.w * self.w)
    }
}

/// Stream topology + behaviour knobs.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    pub producers: usize,
    pub consumers: usize,
    /// In-flight bursts tolerated per consumer before producers block.
    pub queue_depth: usize,
    /// Consumer-side processing throughput for attached computations,
    /// bytes/s (post-processing, VTK conversion).
    pub consume_bw: f64,
}

impl StreamConfig {
    /// The paper's ratio: one consumer per 15 producers. The receive
    /// queue must hold a few bursts from *each* of the ~15 producers a
    /// consumer serves, or producers serialize needlessly.
    pub fn paper_ratio(producers: usize) -> Self {
        let consumers = (producers / 15).max(1);
        StreamConfig {
            producers,
            consumers,
            queue_depth: 4 * producers.div_ceil(consumers),
            consume_bw: 2.0e9,
        }
    }
}

struct ConsumerState {
    /// Completion times of in-flight bursts (front = oldest).
    inflight: VecDeque<SimTime>,
    /// Real elements delivered and not yet collected.
    inbox: Vec<StreamElement>,
    /// Totals.
    bytes_consumed: u64,
    /// Pushes served (rotates the flush target across PFS devices —
    /// consumers write file-per-consumer segments striped like Lustre).
    pushes: u64,
}

/// The stream world.
pub struct StreamSim {
    pub cfg: StreamConfig,
    /// Producer clocks [0..P), then consumer clocks [P..P+C).
    pub clocks: RankClocks,
    net: NetworkModel,
    consumers: Vec<ConsumerState>,
    /// PFS devices consumers flush to.
    pfs: Vec<Device>,
    pub elements_streamed: u64,
}

impl StreamSim {
    /// Build over a testbed.
    pub fn new(tb: &Testbed, cfg: StreamConfig) -> Self {
        let pfs: Vec<Device> = tb
            .storage
            .iter()
            .filter(|p| {
                matches!(p.kind, DeviceKind::LustreOst | DeviceKind::Hdd | DeviceKind::Ssd)
            })
            .map(|p| Device::new(p.clone()))
            .collect();
        let consumers = (0..cfg.consumers)
            .map(|_| ConsumerState {
                inflight: VecDeque::new(),
                inbox: Vec::new(),
                bytes_consumed: 0,
                pushes: 0,
            })
            .collect();
        StreamSim {
            clocks: RankClocks::new(cfg.producers + cfg.consumers),
            net: tb.net.clone(),
            consumers,
            pfs,
            elements_streamed: 0,
            cfg,
        }
    }

    /// The consumer assigned to a producer (contiguous blocks, as the
    /// MPIStream library maps them).
    pub fn consumer_of(&self, producer: usize) -> usize {
        producer * self.cfg.consumers / self.cfg.producers
    }

    /// Charge `seconds` of simulation compute to a producer.
    pub fn produce_compute(&mut self, producer: usize, seconds: f64) -> SimTime {
        self.clocks.advance(producer, seconds)
    }

    /// Producer pushes a burst of `elements` stream elements; returns
    /// the producer's new time. The send is asynchronous: the producer
    /// pays only the injection cost (+ blocking if the consumer queue
    /// is full). Consumer-side processing (attached computation + I/O
    /// flush of `flush_bytes`) is scheduled on the consumer's clock.
    pub fn push(
        &mut self,
        producer: usize,
        elements: u64,
        flush_bytes: u64,
    ) -> Result<SimTime> {
        if producer >= self.cfg.producers {
            return Err(SageError::Invalid(format!(
                "rank {producer} is not a producer"
            )));
        }
        let cons = self.consumer_of(producer);
        let cons_rank = self.cfg.producers + cons;
        let bytes = elements * StreamElement::BYTES;

        // ---- backpressure -------------------------------------------
        let mut now = self.clocks.now(producer);
        {
            let st = &mut self.consumers[cons];
            while st.inflight.len() >= self.cfg.queue_depth {
                let free_at = st.inflight.pop_front().unwrap();
                now = now.max(free_at);
            }
        }
        // ---- producer-side send (async injection) --------------------
        let t_send = self.net.pt2pt(bytes);
        let t_prod = self.clocks.wait_until(producer, now + t_send);

        // ---- consumer-side processing --------------------------------
        let arrive = t_prod; // rendezvous completes at send completion
        let start = self.clocks.now(cons_rank).max(arrive);
        let end_proc = start + bytes as f64 / self.cfg.consume_bw;
        // the attached computation occupies the consumer; the file flush
        // is asynchronous (page-cache write + background writeback) —
        // it occupies the device queue and bounds the burst's
        // *completion* (backpressure), but not the consumer's CPU
        let mut end_burst = end_proc;
        if flush_bytes > 0 && !self.pfs.is_empty() {
            // stripe consumer flushes across PFS devices round-robin
            let d = (cons as u64 + self.consumers[cons].pushes) as usize
                % self.pfs.len();
            end_burst =
            // sage-lint: allow(scheduler-discipline, "streams model: private PFS flush devices, not the shared Mero plane")
                self.pfs[d].io(end_proc, flush_bytes, IoOp::Write, Access::Seq);
        }
        self.clocks.wait_until(cons_rank, end_proc);
        let st = &mut self.consumers[cons];
        st.pushes += 1;
        st.inflight.push_back(end_burst);
        st.bytes_consumed += bytes;
        self.elements_streamed += elements;
        Ok(t_prod)
    }

    /// Push *real* elements (correctness paths: the consumer's attached
    /// computation will see exactly these). Time accounting identical
    /// to [`push`].
    pub fn push_real(
        &mut self,
        producer: usize,
        elems: &[StreamElement],
        flush_bytes: u64,
    ) -> Result<SimTime> {
        let t = self.push(producer, elems.len() as u64, flush_bytes)?;
        let cons = self.consumer_of(producer);
        self.consumers[cons].inbox.extend_from_slice(elems);
        Ok(t)
    }

    /// Collect the elements delivered to a consumer (clears the inbox).
    /// "Stream elements … are discarded as soon as they are consumed."
    pub fn collect(&mut self, consumer: usize) -> Vec<StreamElement> {
        std::mem::take(&mut self.consumers[consumer].inbox)
    }

    /// Drain: wait for all consumers to finish outstanding bursts, then
    /// barrier. Returns the total makespan.
    pub fn drain(&mut self) -> SimTime {
        for c in 0..self.cfg.consumers {
            let last = self.consumers[c].inflight.back().copied();
            if let Some(t) = last {
                self.clocks.wait_until(self.cfg.producers + c, t);
            }
            self.consumers[c].inflight.clear();
        }
        self.clocks
            .barrier(self.net.barrier(self.clocks.len()))
    }

    /// Total bytes consumed across consumers.
    pub fn bytes_consumed(&self) -> u64 {
        self.consumers.iter().map(|c| c.bytes_consumed).sum()
    }

    /// Makespan.
    pub fn elapsed(&self) -> SimTime {
        self.clocks.max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn elem(speed: f32, id: u32) -> StreamElement {
        StreamElement {
            x: 0.0,
            y: 0.0,
            z: 0.0,
            u: speed,
            v: 0.0,
            w: 0.0,
            q: 1.0,
            id: id as f32,
        }
    }

    #[test]
    fn mapping_is_balanced() {
        let tb = Testbed::beskow();
        let s = StreamSim::new(&tb, StreamConfig::paper_ratio(150));
        assert_eq!(s.cfg.consumers, 10);
        assert_eq!(s.consumer_of(0), 0);
        assert_eq!(s.consumer_of(149), 9);
        // each consumer serves exactly 15 producers
        let mut counts = vec![0; 10];
        for p in 0..150 {
            counts[s.consumer_of(p)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 15));
    }

    #[test]
    fn producers_overlap_consumer_io() {
        let tb = Testbed::beskow();
        let mut s = StreamSim::new(&tb, StreamConfig::paper_ratio(15));
        // produce 3 bursts with heavy consumer-side flushes
        for step in 0..3 {
            let _ = step;
            s.produce_compute(0, 0.01);
            s.push(0, 1000, 1 << 24).unwrap();
        }
        let producer_t = s.clocks.now(0);
        let consumer_t = s.clocks.now(15);
        assert!(
            producer_t < consumer_t,
            "producer must run ahead of the I/O consumer \
             (prod {producer_t}, cons {consumer_t})"
        );
    }

    #[test]
    fn backpressure_blocks_producers_eventually() {
        let tb = Testbed::beskow();
        let cfg = StreamConfig {
            producers: 1,
            consumers: 1,
            queue_depth: 2,
            consume_bw: 1e6, // very slow consumer
        };
        let mut s = StreamSim::new(&tb, cfg);
        for _ in 0..8 {
            s.push(0, 10_000, 0).unwrap();
        }
        let producer_t = s.clocks.now(0);
        // producer cannot be more than queue_depth bursts ahead
        let consumer_t = s.clocks.now(1);
        let burst = 10_000.0 * 32.0 / 1e6;
        assert!(
            consumer_t - producer_t < 3.0 * burst,
            "queue bound violated: prod {producer_t} cons {consumer_t}"
        );
    }

    #[test]
    fn real_elements_delivered_exactly_once() {
        let tb = Testbed::beskow();
        let mut s = StreamSim::new(&tb, StreamConfig::paper_ratio(15));
        let batch: Vec<StreamElement> = (0..10).map(|i| elem(1.0, i)).collect();
        s.push_real(3, &batch, 0).unwrap();
        let got = s.collect(s.consumer_of(3));
        assert_eq!(got.len(), 10);
        assert_eq!(got[5].id, 5.0);
        assert!(s.collect(s.consumer_of(3)).is_empty(), "discarded after consume");
    }

    #[test]
    fn energy_matches_kernel_formula() {
        let e = elem(3.0, 0);
        assert!((e.energy() - 4.5).abs() < 1e-6);
    }

    #[test]
    fn drain_waits_for_consumers() {
        let tb = Testbed::beskow();
        let mut s = StreamSim::new(&tb, StreamConfig::paper_ratio(15));
        s.push(0, 100_000, 1 << 26).unwrap();
        let before = s.clocks.now(0);
        let after = s.drain();
        assert!(after >= before);
        for r in 0..s.clocks.len() {
            assert_eq!(s.clocks.now(r), after);
        }
    }
}
