//! Collective-I/O baseline for Fig 7: "the current production version
//! of the iPIC3D code uses MPI collective I/O for saving snapshots"
//! (§4.2). Every rank participates in a blocking `MPI_File_write_all`
//! each snapshot step — simulation stalls while I/O completes, which is
//! what the streaming model eliminates.

use crate::config::Testbed;
use crate::pgas::mpiio::MpiIo;
use crate::sim::clock::SimTime;

/// Baseline world: all ranks simulate AND do collective I/O.
pub struct CollectiveIo {
    io: MpiIo,
    nranks: usize,
}

impl CollectiveIo {
    /// `nranks` simulation ranks.
    pub fn new(tb: &Testbed, nranks: usize) -> Self {
        CollectiveIo { io: MpiIo::new(tb, nranks), nranks }
    }

    /// One simulation step: compute then blocking collective snapshot.
    pub fn step(&mut self, compute_s: f64, snapshot_bytes_per_rank: u64) -> SimTime {
        for r in 0..self.nranks {
            self.io.clocks.advance(r, compute_s);
        }
        if snapshot_bytes_per_rank > 0 {
            self.io.write_all(snapshot_bytes_per_rank)
        } else {
            self.io.clocks.max()
        }
    }

    /// Makespan.
    pub fn elapsed(&self) -> SimTime {
        self.io.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_blocks_every_rank() {
        let tb = Testbed::beskow();
        let mut c = CollectiveIo::new(&tb, 64);
        let t1 = c.step(0.01, 0);
        let t2 = c.step(0.01, 1 << 20);
        assert!(t2 - t1 > 0.01, "I/O step must cost more than compute");
    }

    #[test]
    fn cost_grows_with_scale_at_fixed_per_rank_bytes() {
        let tb = Testbed::beskow();
        let mut small = CollectiveIo::new(&tb, 256);
        let mut big = CollectiveIo::new(&tb, 8192);
        let ts = small.step(0.01, 1 << 18);
        let tb2 = big.step(0.01, 1 << 18);
        assert!(tb2 > 2.0 * ts, "collective I/O serializes at scale: {ts} {tb2}");
    }
}
