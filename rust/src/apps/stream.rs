//! STREAM benchmark over MPI windows (Fig 3).
//!
//! "As files are mapped into the MPI window, STREAM is a convenient
//! benchmark to measure the access bandwidth to the MPI storage window
//! and compare it with the bandwidth achieved when using MPI windows in
//! memory" (§4.1). The four kernels (Copy/Scale/Add/Triad) become
//! chunked GET+PUT sweeps over three window-backed arrays; the timed
//! region follows the standard STREAM protocol (arrays initialized
//! before timing; best-of-N reported).

use crate::config::Testbed;
use crate::error::Result;
use crate::pgas::{PgasSim, WindowId, WindowKind};
use crate::sim::clock::SimTime;

/// Bytes per array element (STREAM uses f64).
pub const ELEM: u64 = 8;
/// Transfer chunk for window sweeps.
const CHUNK: u64 = 8 << 20;

/// Result for one kernel.
#[derive(Debug, Clone)]
pub struct StreamResult {
    pub kernel: &'static str,
    /// Best-of-reps bandwidth, bytes/s (STREAM convention byte counts).
    pub bandwidth: f64,
}

struct Arrays {
    a: WindowId,
    b: WindowId,
    c: WindowId,
    bytes: u64,
}

/// Run STREAM with `m_elems` million elements per array on 1 rank.
/// Returns (copy, scale, add, triad) results.
pub fn run(
    tb: &Testbed,
    kind: WindowKind,
    m_elems: u64,
    reps: u32,
) -> Result<Vec<StreamResult>> {
    let n = m_elems * 1_000_000;
    let bytes = n * ELEM;
    let mut sim = PgasSim::new(tb.clone(), 1);
    let arr = Arrays {
        a: sim.alloc_window(kind, bytes),
        b: sim.alloc_window(kind, bytes),
        c: sim.alloc_window(kind, bytes),
        bytes,
    };
    // STREAM protocol: initialize (untimed), then run kernels
    for w in [arr.a, arr.b, arr.c] {
        sim.warm(w, 0);
    }

    let kernels: [(&'static str, u64); 4] = [
        ("copy", 2 * bytes),  // c = a
        ("scale", 2 * bytes), // b = q*c
        ("add", 3 * bytes),   // c = a + b
        ("triad", 3 * bytes), // a = b + q*c
    ];
    let mut out = Vec::new();
    for (name, moved) in kernels {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            sim.reset_clocks();
            let t = run_kernel(&mut sim, &arr, name)?;
            best = best.min(t);
        }
        out.push(StreamResult { kernel: name, bandwidth: moved as f64 / best });
    }
    Ok(out)
}

fn run_kernel(sim: &mut PgasSim, arr: &Arrays, name: &str) -> Result<SimTime> {
    let t0 = sim.elapsed();
    let mut off = 0;
    while off < arr.bytes {
        let len = CHUNK.min(arr.bytes - off);
        match name {
            "copy" => {
                sim.get(arr.a, 0, 0, off, len, false)?;
                sim.put(arr.c, 0, 0, off, len, false)?;
            }
            "scale" => {
                sim.get(arr.c, 0, 0, off, len, false)?;
                sim.put(arr.b, 0, 0, off, len, false)?;
            }
            "add" => {
                sim.get(arr.a, 0, 0, off, len, false)?;
                sim.get(arr.b, 0, 0, off, len, false)?;
                sim.put(arr.c, 0, 0, off, len, false)?;
            }
            _ => {
                sim.get(arr.b, 0, 0, off, len, false)?;
                sim.get(arr.c, 0, 0, off, len, false)?;
                sim.put(arr.a, 0, 0, off, len, false)?;
            }
        }
        off += len;
    }
    Ok(sim.elapsed() - t0)
}

/// Raw read/write bandwidth sweep against a storage target (Fig 3b:
/// the asymmetric Lustre bandwidths). Returns (read_bw, write_bw).
pub fn rw_asymmetry(
    tb: &Testbed,
    target: crate::pgas::StorageTarget,
    bytes: u64,
) -> Result<(f64, f64)> {
    // deep readahead / writeback pipelines keep every OST busy on a
    // pure-bandwidth sweep, so use wide transfers
    const SWEEP: u64 = 64 << 20;
    // reads: cold cache (force device reads)
    let mut sim = PgasSim::new(tb.clone(), 1);
    let w = sim.alloc_window(WindowKind::Storage(target), bytes);
    let mut off = 0;
    while off < bytes {
        let len = SWEEP.min(bytes - off);
        sim.get(w, 0, 0, off, len, false)?;
        off += len;
    }
    let read_bw = bytes as f64 / sim.elapsed();

    // writes: write everything then force it out (sync)
    let mut sim = PgasSim::new(tb.clone(), 1);
    let w = sim.alloc_window(WindowKind::Storage(target), bytes);
    let mut off = 0;
    while off < bytes {
        let len = SWEEP.min(bytes - off);
        sim.put(w, 0, 0, off, len, false)?;
        off += len;
    }
    sim.win_sync(w, 0)?;
    let write_bw = bytes as f64 / sim.elapsed();
    Ok((read_bw, write_bw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pgas::StorageTarget;

    #[test]
    fn memory_stream_hits_dram_class_bandwidth() {
        let tb = Testbed::blackdog();
        let res = run(&tb, WindowKind::Memory, 100, 2).unwrap();
        let copy = &res[0];
        assert_eq!(copy.kernel, "copy");
        assert!(
            copy.bandwidth > 0.5 * tb.dram_bw && copy.bandwidth < 2.0 * tb.dram_bw,
            "copy bw {} vs dram {}",
            copy.bandwidth,
            tb.dram_bw
        );
    }

    #[test]
    fn fig3a_shape_blackdog_storage_close_to_memory() {
        let tb = Testbed::blackdog();
        let mem = run(&tb, WindowKind::Memory, 100, 2).unwrap();
        let sto = run(
            &tb,
            WindowKind::Storage(StorageTarget::Hdd),
            100,
            2,
        )
        .unwrap();
        for (m, s) in mem.iter().zip(sto.iter()) {
            let degradation = 1.0 - s.bandwidth / m.bandwidth;
            assert!(
                degradation < 0.5,
                "{}: storage window degraded {degradation:.2} — cached \
                 windows must stay in DRAM class",
                m.kernel
            );
        }
    }

    #[test]
    fn fig3c_shape_tegner_storage_collapses() {
        let tb = Testbed::tegner();
        let mem = run(&tb, WindowKind::Memory, 100, 1).unwrap();
        let sto =
            run(&tb, WindowKind::Storage(StorageTarget::Pfs), 100, 1).unwrap();
        let copy_deg = 1.0 - sto[0].bandwidth / mem[0].bandwidth;
        assert!(
            copy_deg > 0.6,
            "Lustre-backed STREAM must degrade heavily (got {copy_deg:.2})"
        );
    }

    #[test]
    fn fig3b_shape_lustre_asymmetry() {
        let tb = Testbed::tegner();
        let (r, w) =
            rw_asymmetry(&tb, StorageTarget::Pfs, 1 << 30).unwrap();
        assert!(r > 3.0 * w, "read {r} should far exceed write {w}");
    }
}
