//! Mini-iPIC3D: the particle-in-cell producer for the MPI-stream
//! experiments (Fig 6, Fig 7).
//!
//! Two modes:
//! * **real** ([`Simulation`]) — a small, genuine particle mover:
//!   particles drift under a model E×B field, energies rise for a
//!   resonant subset, and high-energy particles are streamed out every
//!   step ("once a particle reaches high energies, it is continuously
//!   tracked", §4.2). The consumer post-processes (PJRT kernel or CPU)
//!   and emits a legacy-VTK file — the Fig 6 artifact.
//! * **scale** ([`run_scaling`]) — the Fig 7 experiment: P simulation
//!   ranks for 100 steps, snapshotting particles every step through
//!   either MPI collective I/O or MPI streams (1 consumer per 15
//!   producers), returning both makespans.

use crate::clovis::{Client, Extent};
use crate::config::Testbed;
use crate::error::Result;
use crate::mero::ObjectId;
use crate::runtime::Executor;
use crate::sim::rng::SimRng;
use crate::streams::collective::CollectiveIo;
use crate::streams::{StreamConfig, StreamElement, StreamSim};

// ---------------------------------------------------------------- real

/// A real (small) particle-in-cell simulation.
pub struct Simulation {
    pub particles: Vec<StreamElement>,
    dt: f32,
    step: u64,
    /// Indices already flagged as high-energy ("continuously tracked").
    tracked: Vec<bool>,
}

impl Simulation {
    /// `n` particles with thermal velocities; a `resonant_frac`
    /// fraction sits on a resonance and gains energy over time.
    pub fn new(n: usize, resonant_frac: f64, seed: u64) -> Self {
        let mut rng = SimRng::new(seed);
        let particles = (0..n)
            .map(|i| {
                let resonant = rng.gen_f64() < resonant_frac;
                let scale = if resonant { 1.2 } else { 0.2 };
                StreamElement {
                    x: rng.gen_uniform(0.0, 1.0) as f32,
                    y: rng.gen_uniform(0.0, 1.0) as f32,
                    z: rng.gen_uniform(0.0, 1.0) as f32,
                    u: (rng.gen_normal() * scale) as f32,
                    v: (rng.gen_normal() * scale) as f32,
                    w: (rng.gen_normal() * scale) as f32,
                    q: if resonant { 1.5 } else { 1.0 },
                    id: i as f32,
                }
            })
            .collect();
        Simulation { particles, dt: 0.05, step: 0, tracked: vec![false; n] }
    }

    /// One mover step (Boris-like kick + drift, model fields).
    pub fn step(&mut self) {
        self.step += 1;
        for p in &mut self.particles {
            // E field accelerates heavier-charge (resonant) particles
            let kick = 0.02 * p.q * (p.q - 1.0).max(0.0);
            p.u += kick * (1.0 + p.x.sin() * 0.1);
            p.v += kick * 0.5 * (1.0 + p.y.cos() * 0.1);
            // drift with periodic wrap
            p.x = (p.x + p.u * self.dt).rem_euclid(1.0);
            p.y = (p.y + p.v * self.dt).rem_euclid(1.0);
            p.z = (p.z + p.w * self.dt).rem_euclid(1.0);
        }
    }

    /// High-energy particles this step: energy above `threshold`, plus
    /// everything already tracked (§4.2 tracking semantics).
    pub fn hot_particles(&mut self, threshold: f32) -> Vec<StreamElement> {
        let mut out = Vec::new();
        for (i, p) in self.particles.iter().enumerate() {
            if self.tracked[i] || p.energy() > threshold {
                self.tracked[i] = true;
                out.push(*p);
            }
        }
        out
    }

    /// Flat (n, 8) f32 rows for the kernels.
    pub fn rows(elems: &[StreamElement]) -> Vec<f32> {
        let mut out = Vec::with_capacity(elems.len() * 8);
        for e in elems {
            out.extend_from_slice(&e.to_row());
        }
        out
    }
}

/// Write particles as a legacy-VTK polydata file (the Fig 6 artifact
/// "prepared in file formats, such as VTK, that can be visualized
/// on-the-fly by the ParaView application").
pub fn write_vtk(path: &std::path::Path, elems: &[StreamElement]) -> Result<()> {
    use std::io::Write as _;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# vtk DataFile Version 3.0")?;
    writeln!(f, "SAGE mini-iPIC3D high-energy particles")?;
    writeln!(f, "ASCII")?;
    writeln!(f, "DATASET POLYDATA")?;
    writeln!(f, "POINTS {} float", elems.len())?;
    for e in elems {
        writeln!(f, "{} {} {}", e.x, e.y, e.z)?;
    }
    writeln!(f, "POINT_DATA {}", elems.len())?;
    writeln!(f, "SCALARS energy float 1")?;
    writeln!(f, "LOOKUP_TABLE default")?;
    for e in elems {
        writeln!(f, "{}", e.energy())?;
    }
    Ok(())
}

/// Run the real pipeline: simulate, stream hot particles, post-process
/// on the consumer (PJRT kernel when available), write VTK snapshots.
/// Returns (total hot particles streamed, VTK files written).
pub fn run_real_pipeline(
    tb: &Testbed,
    exec: Option<&Executor>,
    n_particles: usize,
    steps: u64,
    threshold: f32,
    vtk_dir: Option<&std::path::Path>,
) -> Result<(u64, u64)> {
    let mut sim = Simulation::new(n_particles, 0.05, 42);
    let mut streams = StreamSim::new(tb, StreamConfig::paper_ratio(15));
    let mut total_hot = 0u64;
    let mut files = 0u64;
    for step in 0..steps {
        sim.step();
        let hot = sim.hot_particles(threshold);
        total_hot += hot.len() as u64;
        if hot.is_empty() {
            continue;
        }
        streams.push_real(0, &hot, hot.len() as u64 * StreamElement::BYTES)?;
        // consumer side: attached computation
        let delivered = streams.collect(0);
        let rows = Simulation::rows(&delivered);
        let energies: Vec<f32> = if let Some(e) = exec {
            match e.postprocess(&rows, threshold)? {
                Some(out) => out.energies,
                None => delivered.iter().map(|p| p.energy()).collect(),
            }
        } else {
            delivered.iter().map(|p| p.energy()).collect()
        };
        debug_assert_eq!(energies.len(), delivered.len());
        if let Some(dir) = vtk_dir {
            let path = dir.join(format!("step_{step:04}.vtk"));
            write_vtk(&path, &delivered)?;
            files += 1;
        }
    }
    streams.drain();
    Ok((total_hot, files))
}

// ------------------------------------------ object-store checkpointing

/// Serialize one particle batch to LE f32 rows, zero-padded to `block`
/// alignment (the object store is block-granular, §3.2.2).
fn encode_batch(elems: &[StreamElement], block: u64) -> Vec<u8> {
    let mut out =
        Vec::with_capacity(elems.len() * StreamElement::BYTES as usize);
    for e in elems {
        for v in e.to_row() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    let rounded = crate::util::round_up(out.len() as u64, block) as usize;
    out.resize(rounded, 0);
    out
}

/// Checkpoint a group of hot-particle batches into `obj` starting at
/// byte `start`, as ONE session write op (`writev_owned`; §Perf
/// persist-by-move — one extent per step batch, no payload copies,
/// one ADDB/FDMI record for the whole flush; the group's unit I/Os
/// are dispatched to per-device shards so the step batches' stripes
/// overlap in virtual time — sharded op execution, `sim::sched`).
/// Step batches land at consecutive offsets, so cross-op coalescing
/// merges the whole flush into one striped op (no RMW envelopes).
/// Returns the `(offset, n_elems)` index entries for the batches
/// written plus the next free (block-aligned) offset.
pub fn checkpoint_hot_particles(
    client: &mut Client,
    obj: &ObjectId,
    start: u64,
    batches: &[Vec<StreamElement>],
) -> Result<(Vec<(u64, u64)>, u64)> {
    let block = client.store.object(*obj)?.block_size;
    let mut extents: Vec<(u64, Vec<u8>)> = Vec::with_capacity(batches.len());
    let mut index = Vec::with_capacity(batches.len());
    let mut off = start;
    for b in batches {
        if b.is_empty() {
            continue;
        }
        let bytes = encode_batch(b, block);
        index.push((off, b.len() as u64));
        let next = off + bytes.len() as u64;
        extents.push((off, bytes));
        off = next;
    }
    client.writev_owned(obj, extents)?;
    Ok((index, off))
}

/// Restore checkpointed batches through one session read op (`readv`)
/// for the whole index, sharded across the devices holding the
/// checkpoint stripes; adjacent index entries coalesce into one
/// striped read.
pub fn restore_checkpoint(
    client: &mut Client,
    obj: &ObjectId,
    index: &[(u64, u64)],
) -> Result<Vec<Vec<StreamElement>>> {
    let block = client.store.object(*obj)?.block_size;
    let exts: Vec<Extent> = index
        .iter()
        .map(|(off, n)| {
            Extent::new(
                *off,
                crate::util::round_up(n * StreamElement::BYTES, block),
            )
        })
        .collect();
    let bufs = client.readv(obj, &exts)?;
    let mut out = Vec::with_capacity(index.len());
    for ((_, n), buf) in index.iter().zip(bufs.iter()) {
        let payload = &buf[..(*n * StreamElement::BYTES) as usize];
        let mut batch = Vec::with_capacity(*n as usize);
        for row in payload.chunks_exact(StreamElement::BYTES as usize) {
            let f = |i: usize| {
                f32::from_le_bytes(row[i * 4..i * 4 + 4].try_into().unwrap())
            };
            batch.push(StreamElement {
                x: f(0),
                y: f(1),
                z: f(2),
                u: f(3),
                v: f(4),
                w: f(5),
                q: f(6),
                id: f(7),
            });
        }
        out.push(batch);
    }
    Ok(out)
}

/// The real pipeline with durable snapshots: simulate, track hot
/// particles, and flush every `flush_every` non-empty step batches to
/// a Mero object through the batched zero-copy write path. Returns
/// (total hot particles, checkpoint object, batch index).
pub fn run_checkpointed_pipeline(
    client: &mut Client,
    n_particles: usize,
    steps: u64,
    threshold: f32,
    flush_every: usize,
) -> Result<(u64, ObjectId, Vec<(u64, u64)>)> {
    let obj = client.create_object(4096)?;
    let mut sim = Simulation::new(n_particles, 0.05, 42);
    let mut pending: Vec<Vec<StreamElement>> = Vec::new();
    let mut index = Vec::new();
    let mut off = 0u64;
    let mut total_hot = 0u64;
    let flush_every = flush_every.max(1);
    for _ in 0..steps {
        sim.step();
        let hot = sim.hot_particles(threshold);
        total_hot += hot.len() as u64;
        if !hot.is_empty() {
            pending.push(hot);
        }
        if pending.len() >= flush_every {
            let (idx, next) =
                checkpoint_hot_particles(client, &obj, off, &pending)?;
            index.extend(idx);
            off = next;
            pending.clear();
        }
    }
    if !pending.is_empty() {
        let (idx, _) = checkpoint_hot_particles(client, &obj, off, &pending)?;
        index.extend(idx);
    }
    Ok((total_hot, obj, index))
}

// --------------------------------------------------------------- scale

/// Fig 7 outcome for one process count.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub procs: usize,
    pub t_collective: f64,
    pub t_streams: f64,
    /// Paper's "improvement": collective / streams.
    pub improvement: f64,
}

/// Per-step compute seconds per rank (fixed work per rank at each
/// scale, as in the paper's scaling runs).
const STEP_COMPUTE: f64 = 0.05;
/// Snapshot bytes per rank per step (particles of interest).
const SNAPSHOT_BYTES: u64 = 2 << 20;

/// Run the Fig 7 scaling experiment for one process count.
pub fn run_scaling(tb: &Testbed, procs: usize, steps: u64) -> ScalingPoint {
    // --- baseline: collective I/O every step ------------------------
    let mut coll = CollectiveIo::new(tb, procs);
    for _ in 0..steps {
        coll.step(STEP_COMPUTE, SNAPSHOT_BYTES);
    }
    let t_collective = coll.elapsed();

    // --- streaming: 1 consumer / 15 producers -----------------------
    let cfg = StreamConfig::paper_ratio(procs);
    let mut s = StreamSim::new(tb, cfg);
    let elems = SNAPSHOT_BYTES / StreamElement::BYTES;
    for _ in 0..steps {
        for p in 0..procs {
            s.produce_compute(p, STEP_COMPUTE);
        }
        for p in 0..procs {
            // consumers flush the converted VTK data asynchronously
            s.push(p, elems, SNAPSHOT_BYTES).unwrap();
        }
    }
    let t_streams = s.drain();

    ScalingPoint {
        procs,
        t_collective,
        t_streams,
        improvement: t_collective / t_streams,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mover_conserves_particle_count_and_bounds() {
        let mut s = Simulation::new(1000, 0.1, 1);
        for _ in 0..50 {
            s.step();
        }
        assert_eq!(s.particles.len(), 1000);
        for p in &s.particles {
            assert!((0.0..=1.0).contains(&p.x));
            assert!((0.0..=1.0).contains(&p.y));
            assert!((0.0..=1.0).contains(&p.z));
        }
    }

    #[test]
    fn resonant_particles_gain_energy() {
        let mut s = Simulation::new(2000, 0.1, 2);
        let e0: f32 = s.particles.iter().map(|p| p.energy()).sum();
        for _ in 0..100 {
            s.step();
        }
        let e1: f32 = s.particles.iter().map(|p| p.energy()).sum();
        assert!(e1 > 1.5 * e0, "heating: {e0} -> {e1}");
    }

    #[test]
    fn tracking_is_sticky() {
        let mut s = Simulation::new(500, 0.2, 3);
        for _ in 0..60 {
            s.step();
        }
        let hot1 = s.hot_particles(2.0).len();
        // next step: tracked set can only grow
        s.step();
        let hot2 = s.hot_particles(2.0).len();
        assert!(hot2 >= hot1, "{hot1} -> {hot2}");
    }

    #[test]
    fn vtk_file_is_wellformed() {
        let dir = std::env::temp_dir().join("sage_vtk_test");
        std::fs::create_dir_all(&dir).unwrap();
        let elems: Vec<StreamElement> = (0..5)
            .map(|i| StreamElement {
                x: 0.1,
                y: 0.2,
                z: 0.3,
                u: 1.0,
                v: 0.0,
                w: 0.0,
                q: 1.0,
                id: i as f32,
            })
            .collect();
        let path = dir.join("t.vtk");
        write_vtk(&path, &elems).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("# vtk DataFile"));
        assert!(text.contains("POINTS 5 float"));
        assert!(text.contains("SCALARS energy"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_pipeline_streams_hot_particles() {
        let tb = Testbed::beskow();
        let (hot, files) =
            run_real_pipeline(&tb, None, 2000, 30, 1.5, None).unwrap();
        assert!(hot > 0, "some particles must cross the threshold");
        assert_eq!(files, 0);
    }

    #[test]
    fn checkpoint_restore_roundtrip_bit_exact() {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let obj = c.create_object(4096).unwrap();
        let mut sim = Simulation::new(1500, 0.2, 7);
        let mut batches = Vec::new();
        for _ in 0..3 {
            for _ in 0..20 {
                sim.step();
            }
            batches.push(sim.hot_particles(1.0));
        }
        assert!(batches.iter().any(|b| !b.is_empty()));
        let (index, next) =
            checkpoint_hot_particles(&mut c, &obj, 0, &batches).unwrap();
        assert!(next % 4096 == 0, "offsets stay block-aligned");
        let restored = restore_checkpoint(&mut c, &obj, &index).unwrap();
        let nonempty: Vec<&Vec<StreamElement>> =
            batches.iter().filter(|b| !b.is_empty()).collect();
        assert_eq!(restored.len(), nonempty.len());
        for (r, b) in restored.iter().zip(nonempty.iter()) {
            assert_eq!(r, *b, "restored particles are bit-exact");
        }
    }

    #[test]
    fn checkpointed_pipeline_persists_every_hot_particle() {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let (hot, obj, index) =
            run_checkpointed_pipeline(&mut c, 2000, 30, 1.5, 8).unwrap();
        assert!(hot > 0, "some particles must cross the threshold");
        let restored = restore_checkpoint(&mut c, &obj, &index).unwrap();
        let total: u64 = restored.iter().map(|b| b.len() as u64).sum();
        assert_eq!(total, hot, "checkpoints account for every hot particle");
        // batched writes also advanced the virtual clock
        assert!(c.now > 0.0);
    }

    #[test]
    fn checkpointed_pipeline_is_deterministic() {
        // checkpoint flushes ride the sharded group scheduler; the
        // whole pipeline must reproduce bit-exact state AND virtual
        // time across runs with the same seed
        let run = || {
            let mut c = Client::new_sim(Testbed::sage_prototype());
            let (hot, _obj, index) =
                run_checkpointed_pipeline(&mut c, 1200, 20, 1.5, 4).unwrap();
            (hot, index, c.now.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn fig7_shape_improvement_grows_with_scale() {
        let tb = Testbed::beskow();
        let small = run_scaling(&tb, 64, 20);
        let large = run_scaling(&tb, 2048, 20);
        assert!(
            large.improvement > small.improvement,
            "streaming advantage grows with scale: {} -> {}",
            small.improvement,
            large.improvement
        );
        assert!(
            small.improvement > 0.7,
            "at small scale the approaches are comparable ({})",
            small.improvement
        );
        assert!(
            large.improvement > 1.5,
            "at scale streaming must clearly win ({})",
            large.improvement
        );
    }
}
