//! HACC I/O kernel (Fig 5): checkpoint / restart of a particle code.
//!
//! "HACC is a physics particle-based code simulating the trajectories
//! of trillions of particles. We use the HACC I/O kernel to mimic the
//! checkpointing and restart functionalities in the SAGE iPIC3D
//! application … We use 100 million particles in all the tests, while
//! increasing the number of processes (strong scaling). We ensure
//! synchronization both during check-pointing and restart for fair
//! comparison with MPI I/O" (§4.1).

use crate::clovis::{Client, Extent};
use crate::config::Testbed;
use crate::error::Result;
use crate::pgas::mpiio::MpiIo;
use crate::pgas::{PgasSim, StorageTarget, WindowKind};
use crate::sim::clock::SimTime;

/// HACC particle record: 9 floats + 1 int64 = 38 bytes... padded to 40
/// in the kernel's file layout; we use the canonical 38.
pub const PARTICLE_BYTES: u64 = 38;

/// Which I/O implementation performs the checkpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HaccImpl {
    /// Baseline: MPI collective I/O.
    MpiIo,
    /// MPI storage windows (+ win_sync for durability).
    StorageWindows(StorageTarget),
}

/// Checkpoint + restart of `total_particles` across `ranks`; returns
/// the synchronized execution time (one checkpoint, one restart).
pub fn run(
    tb: &Testbed,
    imp: HaccImpl,
    ranks: usize,
    total_particles: u64,
) -> Result<SimTime> {
    let bytes_per_rank =
        (total_particles / ranks as u64).max(1) * PARTICLE_BYTES;
    match imp {
        HaccImpl::MpiIo => {
            let mut io = MpiIo::new(tb, ranks);
            io.write_all(bytes_per_rank); // checkpoint
            io.read_all(bytes_per_rank); // restart
            Ok(io.elapsed())
        }
        HaccImpl::StorageWindows(target) => {
            let mut sim = PgasSim::new(tb.clone(), ranks);
            let w = sim.alloc_window(
                WindowKind::Storage(target),
                bytes_per_rank,
            );
            // checkpoint: each rank copies its particles into the
            // window (chunks), then a synchronized flush
            const CHUNK: u64 = 8 << 20;
            for r in 0..ranks {
                let mut off = 0;
                while off < bytes_per_rank {
                    let len = CHUNK.min(bytes_per_rank - off);
                    sim.put(w, r, r, off, len, false)?;
                    off += len;
                }
            }
            sim.fence(w)?; // ensure synchronization (paper's protocol)

            // restart: read everything back
            for r in 0..ranks {
                let mut off = 0;
                while off < bytes_per_rank {
                    let len = CHUNK.min(bytes_per_rank - off);
                    sim.get(w, r, r, off, len, false)?;
                    off += len;
                }
            }
            sim.fence(w)?;
            Ok(sim.elapsed())
        }
    }
}

/// Checkpoint + restart through the Clovis session API (ISSUE 4):
/// each rank's particle slab is one Mero object; ONE session stages a
/// write op per rank plus a read op per rank chained `.after` its own
/// rank's write — so every rank's restart read dispatches at that
/// rank's checkpoint frontier, not at a global barrier, and all slabs
/// overlap across the pool's device shards. Returns the virtual
/// makespan of the cycle. (Test/bench scale: slabs are materialized.)
pub fn run_clovis_sessions(
    client: &mut Client,
    ranks: usize,
    total_particles: u64,
) -> Result<SimTime> {
    let bytes_per_rank =
        (total_particles / ranks as u64).max(1) * PARTICLE_BYTES;
    let slab = crate::util::round_up(bytes_per_rank, 4096);
    let mut objs = Vec::with_capacity(ranks);
    for _ in 0..ranks {
        objs.push(client.create_object(4096)?);
    }
    let t0 = client.now;
    let mut s = client.session();
    for (r, obj) in objs.iter().enumerate() {
        let w = s.write_owned(obj, vec![(0, vec![r as u8; slab as usize])]);
        let rd = s.read(obj, &[Extent::new(0, slab)]);
        s.after(rd, w)?;
    }
    let report = s.run()?;
    Ok(report.completed_at - t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    const P100M: u64 = 100_000_000;

    #[test]
    fn fig5_shape_tegner_windows_win_at_scale() {
        let tb = Testbed::tegner();
        let ranks = 96;
        let t_mpiio = run(&tb, HaccImpl::MpiIo, ranks, P100M).unwrap();
        let t_win = run(
            &tb,
            HaccImpl::StorageWindows(StorageTarget::Pfs),
            ranks,
            P100M,
        )
        .unwrap();
        assert!(
            t_win < t_mpiio,
            "storage windows should beat MPI-IO at scale: {t_win} vs {t_mpiio}"
        );
    }

    #[test]
    fn fig5_shape_blackdog_comparable() {
        let tb = Testbed::blackdog();
        let ranks = 8;
        let t_mpiio = run(&tb, HaccImpl::MpiIo, ranks, P100M / 10).unwrap();
        let t_win = run(
            &tb,
            HaccImpl::StorageWindows(StorageTarget::Hdd),
            ranks,
            P100M / 10,
        )
        .unwrap();
        let ratio = t_win / t_mpiio;
        assert!(
            (0.4..2.5).contains(&ratio),
            "on a workstation the two approaches are comparable \
             (ratio {ratio:.2})"
        );
    }

    #[test]
    fn clovis_session_cycle_beats_sequential_per_rank_calls() {
        // one session (write+read per rank, chained per rank only)
        // vs the same traffic as strictly sequential legacy calls:
        // overlapping ranks across device shards must never be slower
        let ranks = 4;
        let particles = 400_000; // ~3.8 MB total at 38 B/particle
        let mut a = Client::new_sim(Testbed::sage_prototype());
        let t_session = run_clovis_sessions(&mut a, ranks, particles).unwrap();
        assert!(t_session > 0.0);

        let mut b = Client::new_sim(Testbed::sage_prototype());
        let bytes_per_rank =
            (particles / ranks as u64).max(1) * PARTICLE_BYTES;
        let slab = crate::util::round_up(bytes_per_rank, 4096);
        let t0 = b.now;
        let mut objs = Vec::new();
        for _ in 0..ranks {
            objs.push(b.create_object(4096).unwrap());
        }
        for (r, obj) in objs.iter().enumerate() {
            b.writev_owned(obj, vec![(0, vec![r as u8; slab as usize])])
                .unwrap();
            b.readv(obj, &[Extent::new(0, slab)]).unwrap();
        }
        let t_seq = b.now - t0;
        assert!(
            t_session <= t_seq * (1.0 + 1e-9),
            "session cycle must not exceed the sequential fold: \
             {t_session} vs {t_seq}"
        );
    }

    #[test]
    fn strong_scaling_reduces_per_rank_time() {
        let tb = Testbed::tegner();
        let t24 = run(&tb, HaccImpl::MpiIo, 24, P100M).unwrap();
        let t96 = run(&tb, HaccImpl::MpiIo, 96, P100M).unwrap();
        // same total bytes: device time dominates, so times stay within
        // the same regime (collective overhead grows slightly)
        assert!(t96 < 3.0 * t24 && t24 < 3.0 * t96);
    }
}
