//! ALF: analytics on data-consumption log files (§2, use case 6),
//! exercising function shipping (§3.2.1) end to end: log segments are
//! stored as Mero objects; the histogram computation ships to the
//! storage node instead of moving the raw logs.

use crate::clovis::{Client, FnOutput, FunctionKind};
use crate::error::Result;
use crate::mero::object::ObjectId;
use crate::sim::rng::SimRng;

/// Synthetic log record values: a lognormal-ish mixture of request
/// sizes (MB), matching data-consumption logs.
pub fn generate_log_values(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = SimRng::new(seed);
    (0..n)
        .map(|_| {
            let base = rng.gen_normal().mul_add(1.2, 2.5).exp() as f32; // lognormal
            base.min(1000.0)
        })
        .collect()
}

/// Store log values as an object (f32 LE bytes), padded to block
/// size. One session write op (`writev_owned`): the encoded buffer
/// persists by move (§Perf — no payload copy into block storage).
pub fn store_log(client: &mut Client, values: &[f32]) -> Result<ObjectId> {
    let obj = client.create_object(4096)?;
    let mut bytes = Vec::with_capacity(values.len() * 4);
    for v in values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    // pad to a full default stripe multiple (4 data units x 64 KiB)
    let stripe = 4 * 65536;
    let padded = bytes.len().div_ceil(stripe) * stripe;
    bytes.resize(padded, 0);
    client.writev_owned(&obj, vec![(0, bytes)])?;
    Ok(obj)
}

/// Analytics outcome: histogram + the data-movement comparison.
#[derive(Debug)]
pub struct AlfReport {
    pub counts: Vec<f32>,
    pub t_shipped: f64,
    pub t_moved: f64,
    pub net_bytes_shipped: u64,
    pub net_bytes_moved: u64,
}

/// Run the shipped histogram over a stored log object — a session
/// ship op (in-storage compute on the group's shards; stage
/// `Session::ship` next to foreground writes instead to overlap
/// analytics with I/O).
pub fn analyze(
    client: &mut Client,
    obj: ObjectId,
    lo: f32,
    hi: f32,
) -> Result<AlfReport> {
    let r = client.ship_to_object(obj, FunctionKind::Histogram { lo, hi })?;
    let counts = match r.output {
        FnOutput::Histogram(c) => c,
        _ => vec![],
    };
    Ok(AlfReport {
        counts,
        t_shipped: r.t_done,
        t_moved: r.t_move_data,
        net_bytes_shipped: r.net_bytes,
        net_bytes_moved: r.net_bytes_moved,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    #[test]
    fn log_values_have_expected_spread() {
        let v = generate_log_values(10_000, 1);
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean > 1.0 && mean < 200.0, "mean {mean}");
        assert!(v.iter().all(|&x| x >= 0.0 && x <= 1000.0));
    }

    #[test]
    fn shipped_histogram_counts_everything() {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let values = generate_log_values(16384, 2);
        let obj = store_log(&mut c, &values).unwrap();
        let rep = analyze(&mut c, obj, 0.0, 1024.0).unwrap();
        assert_eq!(rep.counts.len(), 64);
        // padding zeros land in bin 0; total >= n
        let total: f32 = rep.counts.iter().sum();
        assert!(total >= 16384.0, "total {total}");
        assert!(rep.net_bytes_shipped < rep.net_bytes_moved / 8);
    }
}
