//! Distributed Hash Table over MPI windows (Fig 4).
//!
//! "DHT mimics SAGE data-analytics applications that have random access
//! in distributed data structures. … each MPI process handles a part
//! of the DHT, named Local Volume … The processes also maintain an
//! overflow heap to store elements in case of collisions. … updates to
//! the DHT are handled using MPI one-sided operations" (§4.1).
//!
//! Both window allocations (local volume + overflow heap) can live in
//! memory or on storage; time comes from the PGAS simulation, and a
//! real (small-scale) hash table validates the semantics in tests.

use crate::config::Testbed;
use crate::error::Result;
use crate::pgas::{PgasSim, WindowKind};
use crate::sim::clock::SimTime;
use crate::sim::rng::SimRng;

/// Element size: key + value + chain pointer (paper-scale records).
pub const ELEM_BYTES: u64 = 64;
/// Overflow heap factor (paper: "conflict overflow of 4 per element").
pub const OVERFLOW: u64 = 4;
/// Software cost of issuing one MPI one-sided operation (descriptor
/// setup, progress engine) — paid on the origin regardless of target.
const MPI_OP_COST: f64 = 1.5e-6;

/// DHT run configuration.
#[derive(Debug, Clone)]
pub struct DhtConfig {
    pub ranks: usize,
    /// Local volume in elements per rank.
    pub local_volume: u64,
    /// Update operations issued per rank.
    pub ops_per_rank: u64,
    /// win_sync every this many ops (durability batches).
    pub sync_interval: u64,
}

impl DhtConfig {
    /// Paper-scale defaults (Fig 4): ops proportional to volume.
    pub fn paper(ranks: usize, m_elems_per_volume: u64) -> Self {
        DhtConfig {
            ranks,
            local_volume: m_elems_per_volume * 1_000_000,
            ops_per_rank: (m_elems_per_volume * 1_000_000 / 10).max(1),
            sync_interval: 100_000,
        }
    }
}

/// Run the DHT update workload; returns total execution time.
pub fn run(tb: &Testbed, kind: WindowKind, cfg: &DhtConfig) -> Result<SimTime> {
    let mut sim = PgasSim::new(tb.clone(), cfg.ranks);
    let vol_bytes = cfg.local_volume * ELEM_BYTES;
    let heap_bytes = cfg.local_volume * OVERFLOW * ELEM_BYTES / 8;
    let vol = sim.alloc_window(kind, vol_bytes);
    let heap = sim.alloc_window(kind, heap_bytes);
    for r in 0..cfg.ranks {
        sim.warm(vol, r);
        sim.warm(heap, r);
    }
    let mut rng = SimRng::new(0xD117);

    for op in 0..cfg.ops_per_rank {
        for rank in 0..cfg.ranks {
            // pick a random target volume and bucket (one-sided access)
            let target = rng.gen_index(cfg.ranks);
            let bucket = rng.gen_range(cfg.local_volume);
            let off = bucket * ELEM_BYTES;
            // read bucket, then write back (update); ~25% of updates
            // collide and touch the overflow heap too
            sim.compute(rank, 2.0 * MPI_OP_COST + 120e-9); // issue + hash
            sim.get(vol, rank, target, off, ELEM_BYTES, true)?;
            sim.put(vol, rank, target, off, ELEM_BYTES, true)?;
            if rng.gen_f64() < 0.25 {
                let hoff = rng.gen_range(heap_bytes / ELEM_BYTES) * ELEM_BYTES;
                sim.compute(rank, MPI_OP_COST);
                sim.put(heap, rank, target, hoff, ELEM_BYTES, true)?;
            }
        }
        if (op + 1) % cfg.sync_interval == 0 {
            // sync per window across ranks (collective fence pattern):
            // interleaving windows per rank would convoy the devices
            for rank in 0..cfg.ranks {
                sim.win_sync(vol, rank)?;
            }
            for rank in 0..cfg.ranks {
                sim.win_sync(heap, rank)?;
            }
        }
    }
    for rank in 0..cfg.ranks {
        sim.win_sync(vol, rank)?;
    }
    for rank in 0..cfg.ranks {
        sim.win_sync(heap, rank)?;
    }
    Ok(sim.elapsed())
}

// ---------------------------------------------------------------------
// Real (functional) DHT used to validate semantics at test scale.
// ---------------------------------------------------------------------

/// A real distributed hash table over per-rank element arrays with an
/// overflow chain — the data structure the windows hold.
pub struct RealDht {
    ranks: usize,
    buckets_per_rank: u64,
    /// volume[rank][bucket] = Some((key, value))
    volume: Vec<Vec<Option<(u64, u64)>>>,
    /// overflow heaps
    heap: Vec<Vec<(u64, u64)>>,
}

impl RealDht {
    /// Build with `buckets_per_rank` buckets on each of `ranks` ranks.
    pub fn new(ranks: usize, buckets_per_rank: u64) -> Self {
        RealDht {
            ranks,
            buckets_per_rank,
            volume: (0..ranks)
                .map(|_| vec![None; buckets_per_rank as usize])
                .collect(),
            heap: vec![Vec::new(); ranks],
        }
    }

    fn home(&self, key: u64) -> (usize, usize) {
        let h = key.wrapping_mul(0x9E3779B97F4A7C15);
        let rank = (h >> 32) as usize % self.ranks;
        let bucket = (h as u64 % self.buckets_per_rank) as usize;
        (rank, bucket)
    }

    /// Insert (put): bucket if empty/match, else overflow chain.
    pub fn put(&mut self, key: u64, value: u64) {
        let (r, b) = self.home(key);
        match &mut self.volume[r][b] {
            slot @ None => *slot = Some((key, value)),
            Some((k, v)) if *k == key => *v = value,
            _ => {
                // collision -> overflow heap (replace if present)
                if let Some(e) =
                    self.heap[r].iter_mut().find(|(k, _)| *k == key)
                {
                    e.1 = value;
                } else {
                    self.heap[r].push((key, value));
                }
            }
        }
    }

    /// Lookup (get).
    pub fn get(&self, key: u64) -> Option<u64> {
        let (r, b) = self.home(key);
        match &self.volume[r][b] {
            Some((k, v)) if *k == key => Some(*v),
            _ => self.heap[r]
                .iter()
                .find(|(k, _)| *k == key)
                .map(|(_, v)| *v),
        }
    }

    /// Total stored elements.
    pub fn len(&self) -> usize {
        self.volume
            .iter()
            .map(|v| v.iter().flatten().count())
            .sum::<usize>()
            + self.heap.iter().map(|h| h.len()).sum::<usize>()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Every stored (key, value), volumes first then overflow heaps
    /// (deterministic order for persistence).
    pub fn entries(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::with_capacity(self.len());
        for vol in &self.volume {
            out.extend(vol.iter().flatten().copied());
        }
        for heap in &self.heap {
            out.extend(heap.iter().copied());
        }
        out
    }
}

/// Persist a [`RealDht`]'s contents into a Mero KV index through ONE
/// Clovis session (ISSUE 4): the durability path of the paper's
/// DHT-style analytics workloads rides the same op group as every
/// other Clovis operation — the PUT of all records is one `.idx_put`
/// op and a verifying `.idx_get` is chained `.after` it. Returns the
/// index id (big-endian u64 keys/values).
pub fn persist_to_kvs(
    client: &mut crate::clovis::Client,
    dht: &RealDht,
) -> Result<crate::mero::IndexId> {
    let entries = dht.entries();
    let records: Vec<(Vec<u8>, Vec<u8>)> = entries
        .iter()
        .map(|(k, v)| (k.to_be_bytes().to_vec(), v.to_be_bytes().to_vec()))
        .collect();
    let keys: Vec<Vec<u8>> = records.iter().map(|(k, _)| k.clone()).collect();
    let idx = client.create_index();
    let mut s = client.session();
    let put = s.idx_put(idx, records);
    let get = s.idx_get(idx, keys);
    s.after(get, put)?;
    let report = s.run()?;
    if let crate::clovis::OpOutput::IdxGet(vals) = report.output(get) {
        if vals.iter().any(|v| v.is_none()) {
            return Err(crate::error::SageError::Integrity(
                "persisted DHT record missing on readback".into(),
            ));
        }
    }
    Ok(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pgas::StorageTarget;

    #[test]
    fn real_dht_put_get_with_collisions() {
        let mut d = RealDht::new(4, 8); // tiny: force collisions
        for k in 0..200u64 {
            d.put(k, k * 10);
        }
        for k in 0..200u64 {
            assert_eq!(d.get(k), Some(k * 10), "key {k}");
        }
        assert_eq!(d.len(), 200);
        d.put(7, 42);
        assert_eq!(d.get(7), Some(42), "overwrite");
        assert_eq!(d.len(), 200);
        assert_eq!(d.get(9999), None);
    }

    #[test]
    fn dht_persists_to_kvs_through_one_session() {
        use crate::config::Testbed;
        let mut d = RealDht::new(4, 16);
        for k in 0..300u64 {
            d.put(k, k * 3 + 1);
        }
        let mut c = crate::clovis::Client::new_sim(Testbed::sage_prototype());
        let idx = persist_to_kvs(&mut c, &d).unwrap();
        assert_eq!(c.store.index(idx).unwrap().len(), 300);
        // spot-check through the legacy batched GET (same store state)
        let got = c
            .idx_get(idx, &[7u64.to_be_bytes().to_vec()])
            .unwrap();
        assert_eq!(got[0], Some(d.get(7).unwrap().to_be_bytes().to_vec()));
    }

    #[test]
    fn fig4a_shape_storage_overhead_ordering() {
        // Blackdog: HDD worse than SSD worse than memory, but same
        // order of magnitude (paper: +34% HDD, +20% SSD)
        let tb = Testbed::blackdog();
        let cfg = DhtConfig {
            ranks: 8,
            local_volume: 20_000,
            ops_per_rank: 60_000,
            sync_interval: 30_000,
        };
        let t_mem = run(&tb, WindowKind::Memory, &cfg).unwrap();
        let t_ssd =
            run(&tb, WindowKind::Storage(StorageTarget::Ssd), &cfg).unwrap();
        let t_hdd =
            run(&tb, WindowKind::Storage(StorageTarget::Hdd), &cfg).unwrap();
        assert!(t_mem < t_ssd && t_ssd < t_hdd, "{t_mem} {t_ssd} {t_hdd}");
        assert!(
            t_hdd < 3.0 * t_mem,
            "storage overhead should be a penalty, not a collapse: \
             mem {t_mem} hdd {t_hdd}"
        );
    }

    #[test]
    fn fig4b_shape_tegner_negligible_overhead() {
        // Tegner: cross-node one-sided traffic dominates; storage
        // windows barely matter (paper: ~2%)
        let tb = Testbed::tegner();
        let cfg = DhtConfig {
            ranks: 96,
            local_volume: 20_000,
            ops_per_rank: 10_000,
            sync_interval: u64::MAX, // durability sync at the end only
        };
        let t_mem = run(&tb, WindowKind::Memory, &cfg).unwrap();
        let t_pfs =
            run(&tb, WindowKind::Storage(StorageTarget::Pfs), &cfg).unwrap();
        let overhead = t_pfs / t_mem - 1.0;
        assert!(
            overhead < 0.35,
            "Tegner DHT overhead should be small (got {overhead:.2})"
        );
    }
}
