//! Application workloads from the paper's evaluation (§4) and use-case
//! portfolio (§2): STREAM, the Distributed Hash Table, the HACC I/O
//! kernel, a mini-iPIC3D particle code with streaming visualization,
//! and ALF log analytics over function shipping.

pub mod alf;
pub mod dht;
pub mod hacc;
pub mod ipic3d;
pub mod stream;
