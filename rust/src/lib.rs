//! # SAGE: Percipient Storage for Exascale Data Centric Computing
//!
//! A full-stack reproduction of the SAGE system (Narasimhamurthy et al.,
//! Parallel Computing 2018): a multi-tier object-storage platform with
//! in-storage compute, evaluated with the paper's PGAS-I/O and MPI-stream
//! experiments.
//!
//! ## Architecture (three layers, python never on the request path)
//!
//! * **L3 (this crate)** — the SAGE stack: [`mero`] (object-store core:
//!   objects, KV indices, layouts, SNS distributed RAID, transactions,
//!   HA), [`clovis`] (access + management API, function shipping, ADDB,
//!   FDMI), [`hsm`] (tiering), [`pgas`] (MPI-storage-window analog),
//!   [`streams`] (MPI-stream analog), all running over a simulated
//!   cluster ([`sim`], [`cluster`]) with deterministic virtual time.
//!   Every operation is an op on the sharded per-device scheduler
//!   ([`sim::sched`]): `Client::session()` ([`clovis::session`])
//!   stages object I/O, KV access, transactions, function shipping,
//!   migration and repair on ONE scheduler-backed op group — groups
//!   dispatch unit I/Os to home-device shards and complete at the max
//!   over per-device frontiers. Submissions carry a QoS
//!   [`TrafficClass`](sim::sched::TrafficClass), and shards enforce
//!   the cluster's repair/foreground bandwidth split
//!   ([`QosConfig`](sim::sched::QosConfig), §3.2.1 repair throttling)
//!   so recovery traffic never starves applications — `OPERATIONS.md`
//!   at the repo root is the operator's handbook for tuning it.
//! * **L2/L1 (build time)** — JAX graphs + Pallas kernels under
//!   `python/compile/`, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **Runtime bridge** — [`runtime`] loads the artifacts once via the
//!   PJRT CPU client (`xla` crate) and executes them from the storage
//!   hot path (SNS parity, shipped functions).
//!
//! The full paper → module map (which module reproduces which section
//! of the paper, §3.1–§4.2) lives in `ARCHITECTURE.md` at the repo
//! root; `README.md` has the quickstart, the tier-1 verify command and
//! the bench protocol.
//!
//! ## Quickstart
//!
//! ```no_run
//! use sage::clovis::Client;
//! use sage::config::Testbed;
//!
//! let mut client = Client::new_sim(Testbed::blackdog());
//! let obj = client.create_object(4096).unwrap();
//! client.write_object(&obj, 0, &vec![7u8; 16384]).unwrap();
//! let back = client.read_object(&obj, 0, 16384).unwrap();
//! assert_eq!(back, vec![7u8; 16384]);
//! ```

// New `unsafe` needs a visible, file-local waiver: the only allowed
// block is `util/alloc.rs::CountingAlloc` (the counting global
// allocator), which carries a scoped `#[allow(unsafe_code)]`.
#![deny(unsafe_code)]

pub mod bench;
pub mod cluster;
pub mod clovis;
pub mod config;
pub mod error;
pub mod gateway;
pub mod hsm;
pub mod mero;
pub mod metrics;
pub mod pgas;
pub mod proptest;
pub mod runtime;
pub mod sim;
pub mod streams;
pub mod tools;
pub mod util;

pub mod apps;

pub use error::{Result, SageError};
