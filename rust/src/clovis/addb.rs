//! ADDB: Analysis and Diagnostics Data Base (§3.2.2).
//!
//! "Clovis contains a management interface that accesses telemetry
//! records called ADDB records on system performance that can be fed
//! into external system data analysis tools" (e.g. ARM Forge, §3.2.3).
//!
//! A bounded ring of `(time, subsystem, metric, value)` records plus
//! aggregation for reports.

use std::collections::BTreeMap;

use crate::sim::clock::SimTime;

/// One telemetry record.
#[derive(Debug, Clone, PartialEq)]
pub struct AddbRecord {
    pub at: SimTime,
    pub subsystem: String,
    pub metric: String,
    pub value: f64,
}

/// Bounded telemetry ring buffer with running aggregates.
#[derive(Debug)]
pub struct Addb {
    capacity: usize,
    ring: Vec<AddbRecord>,
    head: usize,
    /// subsystem.metric -> (count, sum) running aggregate (not bounded
    /// by the ring: aggregates survive eviction).
    totals: BTreeMap<String, (u64, f64)>,
}

impl Addb {
    /// Ring of `capacity` records.
    pub fn new(capacity: usize) -> Self {
        Addb {
            capacity: capacity.max(1),
            ring: Vec::new(),
            head: 0,
            totals: BTreeMap::new(),
        }
    }

    /// Record a telemetry sample.
    pub fn record(&mut self, at: SimTime, subsystem: &str, metric: &str, value: f64) {
        let rec = AddbRecord {
            at,
            subsystem: subsystem.to_string(),
            metric: metric.to_string(),
            value,
        };
        let key = format!("{subsystem}.{metric}");
        let e = self.totals.entry(key).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += value;
        if self.ring.len() < self.capacity {
            self.ring.push(rec);
        } else {
            self.ring[self.head] = rec;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Most recent records (up to `n`, newest last).
    pub fn recent(&self, n: usize) -> Vec<&AddbRecord> {
        let len = self.ring.len();
        let take = n.min(len);
        let mut out = Vec::with_capacity(take);
        for i in 0..take {
            let idx = (self.head + len - take + i) % len;
            out.push(&self.ring[idx]);
        }
        out
    }

    /// `(metric, (count, sum))` aggregates for reporting.
    pub fn summary(&self) -> Vec<(String, (u64, f64))> {
        self.totals.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Total of one metric.
    pub fn total(&self, subsystem: &str, metric: &str) -> f64 {
        self.totals
            .get(&format!("{subsystem}.{metric}"))
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    /// Render the performance report fed to "ARM Forge" (§3.2.3) —
    /// here, a plain aggregated table.
    pub fn report(&self) -> String {
        let mut t = crate::metrics::Table::new(
            "ADDB performance report",
            &["metric", "count", "total"],
        );
        for (k, (n, s)) in &self.totals {
            t.row(vec![k.clone(), n.to_string(), format!("{s:.1}")]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_survive_ring_eviction() {
        let mut a = Addb::new(4);
        for i in 0..100 {
            a.record(i as f64, "io", "bytes", 10.0);
        }
        assert_eq!(a.total("io", "bytes"), 1000.0);
        assert_eq!(a.recent(10).len(), 4, "ring bounded");
    }

    #[test]
    fn recent_returns_newest_last() {
        let mut a = Addb::new(3);
        for i in 0..5 {
            a.record(i as f64, "s", "m", i as f64);
        }
        let r = a.recent(2);
        assert_eq!(r[0].value, 3.0);
        assert_eq!(r[1].value, 4.0);
    }

    #[test]
    fn report_renders() {
        let mut a = Addb::new(8);
        a.record(0.0, "clovis", "op", 1.0);
        assert!(a.report().contains("clovis.op"));
    }
}
