//! FDMI: the File Data Manipulation Interface — Clovis's extension
//! interface (§3.2.2).
//!
//! "Additional data management plug-ins can easily be built on top of
//! the core through FDMI. Hierarchical storage management and
//! information lifecycle management, file system integrity checking,
//! data indexing, data compression are some examples of third-party
//! plug-ins utilizing the API."
//!
//! Storage events are published on the [`FdmiBus`]; plugins implement
//! [`FdmiPlugin`] with a filter predicate and a handler. HSM (the
//! in-tree consumer) subscribes to write/read events for heat tracking.

use crate::mero::object::ObjectId;
use crate::sim::clock::SimTime;

/// Storage events visible to plugins.
#[derive(Debug, Clone, PartialEq)]
pub enum FdmiRecord {
    ObjectCreated { obj: ObjectId, at: SimTime },
    ObjectWritten { obj: ObjectId, offset: u64, len: u64, at: SimTime },
    ObjectRead { obj: ObjectId, offset: u64, len: u64, at: SimTime },
    ObjectDeleted { obj: ObjectId, at: SimTime },
    /// Data movement between tiers, published by the recovery plane
    /// (`Client::migrate_with`) once per moved object. Tier stamps are
    /// [`DeviceKind::tier`](crate::sim::device::DeviceKind::tier)
    /// indices; `hsm::storage_kind_for_tier` decodes them.
    ObjectMigrated { obj: ObjectId, from_tier: u8, to_tier: u8, at: SimTime },
}

impl FdmiRecord {
    /// The object the record concerns.
    pub fn object(&self) -> ObjectId {
        match self {
            FdmiRecord::ObjectCreated { obj, .. }
            | FdmiRecord::ObjectWritten { obj, .. }
            | FdmiRecord::ObjectRead { obj, .. }
            | FdmiRecord::ObjectDeleted { obj, .. }
            | FdmiRecord::ObjectMigrated { obj, .. } => *obj,
        }
    }

    /// Event timestamp.
    pub fn at(&self) -> SimTime {
        match self {
            FdmiRecord::ObjectCreated { at, .. }
            | FdmiRecord::ObjectWritten { at, .. }
            | FdmiRecord::ObjectRead { at, .. }
            | FdmiRecord::ObjectDeleted { at, .. }
            | FdmiRecord::ObjectMigrated { at, .. } => *at,
        }
    }
}

/// A third-party plugin: filter + handler.
pub trait FdmiPlugin: Send {
    /// Plugin name (diagnostics).
    fn name(&self) -> &str;
    /// Return true for records this plugin wants delivered.
    fn filter(&self, rec: &FdmiRecord) -> bool;
    /// Handle a delivered record.
    fn deliver(&mut self, rec: &FdmiRecord);
}

/// The event bus: emit on one side, plugins consume on the other.
#[derive(Default)]
pub struct FdmiBus {
    plugins: Vec<Box<dyn FdmiPlugin>>,
    /// Records kept for pull-style consumers (drained by HSM et al.).
    pending: Vec<FdmiRecord>,
    pub emitted: u64,
}

impl FdmiBus {
    /// Empty bus.
    pub fn new() -> Self {
        FdmiBus::default()
    }

    /// Register a plugin.
    pub fn register(&mut self, plugin: Box<dyn FdmiPlugin>) {
        self.plugins.push(plugin);
    }

    /// Publish a record: pushes to matching plugins and to the pending
    /// queue for pull-style consumers.
    pub fn emit(&mut self, rec: FdmiRecord) {
        self.emitted += 1;
        for p in &mut self.plugins {
            if p.filter(&rec) {
                p.deliver(&rec);
            }
        }
        self.pending.push(rec);
    }

    /// Drain pending records (pull-style consumption).
    pub fn drain(&mut self) -> Vec<FdmiRecord> {
        std::mem::take(&mut self.pending)
    }

    /// Registered plugin names.
    pub fn plugin_names(&self) -> Vec<&str> {
        self.plugins.iter().map(|p| p.name()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    // Arc<AtomicU64> is Send by construction — no `unsafe impl`
    // needed under the crate-wide `#![deny(unsafe_code)]`.
    struct CountWrites {
        seen: Arc<AtomicU64>,
    }

    impl FdmiPlugin for CountWrites {
        fn name(&self) -> &str {
            "count-writes"
        }
        fn filter(&self, rec: &FdmiRecord) -> bool {
            matches!(rec, FdmiRecord::ObjectWritten { .. })
        }
        fn deliver(&mut self, _rec: &FdmiRecord) {
            self.seen.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[test]
    fn plugins_get_filtered_records() {
        let seen = Arc::new(AtomicU64::new(0));
        let mut bus = FdmiBus::new();
        bus.register(Box::new(CountWrites { seen: seen.clone() }));
        bus.emit(FdmiRecord::ObjectCreated { obj: ObjectId(1), at: 0.0 });
        bus.emit(FdmiRecord::ObjectWritten {
            obj: ObjectId(1),
            offset: 0,
            len: 10,
            at: 1.0,
        });
        assert_eq!(
            seen.load(Ordering::Relaxed),
            1,
            "only the write matched the filter"
        );
        assert_eq!(bus.emitted, 2);
        assert_eq!(bus.plugin_names(), vec!["count-writes"]);
    }

    #[test]
    fn drain_empties_pending() {
        let mut bus = FdmiBus::new();
        bus.emit(FdmiRecord::ObjectDeleted { obj: ObjectId(2), at: 3.0 });
        let drained = bus.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].object(), ObjectId(2));
        assert_eq!(drained[0].at(), 3.0);
        assert!(bus.drain().is_empty());
    }
}
