//! Clovis: the transactional storage API on top of Mero (§3.2.2).
//!
//! * access interface — ONE asynchronous op interface for every
//!   operation kind: [`Client::session`] yields the [`session`]
//!   op builder (object I/O, KV indices, transactions, function
//!   shipping, migration and repair all stage ops on one
//!   scheduler-backed group); op lifecycle in [`ops`]
//! * function shipping — [`fshipping`] (§3.2.1): run computations on
//!   the storage nodes where the data lives
//! * management interface — [`addb`] telemetry and the [`fdmi`]
//!   extension/plugin interface
//!
//! [`Client`] is what applications and the high-level HPC interfaces
//! (PGAS I/O, MPI streams, HDF5/pNFS gateways) link against. Its
//! vectored legacy entry points ([`Client::writev`], [`Client::readv`],
//! [`Client::migrate_with`], [`Client::repair_with`],
//! [`Client::ship_to_object`]) are thin wrappers over one-op sessions,
//! equal to their session-built equivalents in bytes, placements and
//! bit-identical completion times (`tests/prop_session.rs`). Relative
//! to the pre-session engine, stored bytes and write timings are
//! unchanged; `readv` additionally gained cross-op extent coalescing
//! (this PR's ROADMAP item), which preserves bytes and ordering and
//! can only tighten read timings (shared edge units are read once).

pub mod addb;
pub mod fdmi;
pub mod fshipping;
pub mod ops;
pub mod session;

use crate::cluster::failure::{FailureEvent, FailureKind, FailureSchedule};
use crate::cluster::Cluster;
use crate::config::Testbed;
use crate::error::{Result, SageError};
use crate::mero::dtm::TxId;
use crate::mero::ha::RepairAction;
use crate::mero::{ContainerId, IndexId, Layout, MeroStore, ObjectId};
use crate::runtime::Executor;
use crate::sim::clock::SimTime;
use crate::sim::device::DeviceKind;
use crate::sim::sched::{IoScheduler, TenantId};

pub use fshipping::{FnOutput, FunctionKind, ShipResult};
pub use ops::Extent;
pub use session::{OpHandle, OpOutput, Session, SessionReport};

/// One coalesced write extent: borrowed when it is a single caller
/// extent, owned when adjacent extents were merged into one buffer.
enum Coalesced<'a> {
    Borrowed(&'a [u8]),
    Owned(Vec<u8>),
}

impl Coalesced<'_> {
    fn len(&self) -> usize {
        match self {
            Coalesced::Borrowed(d) => d.len(),
            Coalesced::Owned(v) => v.len(),
        }
    }
}

impl Coalesced<'_> {
    fn extend_into(self, v: &mut Vec<u8>) {
        match self {
            Coalesced::Borrowed(d) => v.extend_from_slice(d),
            Coalesced::Owned(d) => v.extend_from_slice(&d),
        }
    }
}

/// Cross-op extent coalescing (ROADMAP §Perf): merge runs of
/// list-adjacent extents (`prev.offset + prev.len == next.offset`)
/// into single ops before striping. Only exactly-adjacent,
/// list-consecutive, non-empty neighbours merge, so overlapping
/// extents keep their application order and the persisted bytes are
/// identical to the unmerged batch — while merged partial stripes
/// become full stripes, saving RMW parity envelopes (and their
/// survivor-read round trips). The ONE implementation behind both
/// `writev` and `writev_owned`, so the two paths can never coalesce
/// differently.
fn coalesce<'a>(list: Vec<(u64, Coalesced<'a>)>) -> Vec<(u64, Coalesced<'a>)> {
    let mut out: Vec<(u64, Coalesced<'a>)> = Vec::with_capacity(list.len());
    for (off, data) in list {
        let adjacent = out.last().is_some_and(|(poff, prev)| {
            prev.len() > 0 && data.len() > 0 && *poff + prev.len() as u64 == off
        });
        if !adjacent {
            out.push((off, data));
            continue;
        }
        let (_, prev) = out.last_mut().unwrap();
        let mut v = match std::mem::replace(prev, Coalesced::Borrowed(&[])) {
            Coalesced::Borrowed(d) => d.to_vec(),
            Coalesced::Owned(v) => v,
        };
        data.extend_into(&mut v);
        *prev = Coalesced::Owned(v);
    }
    out
}

/// [`coalesce`] over borrowed extents (the `writev` path).
fn coalesce_extents<'a>(extents: &[(u64, &'a [u8])]) -> Vec<(u64, Coalesced<'a>)> {
    coalesce(
        extents
            .iter()
            .map(|&(off, d)| (off, Coalesced::Borrowed(d)))
            .collect(),
    )
}

/// [`coalesce`] over owned buffers (the `writev_owned` path;
/// persist-by-move is preserved — buffers merge by appending, never by
/// re-borrowing).
fn coalesce_owned_extents(extents: Vec<(u64, Vec<u8>)>) -> Vec<(u64, Vec<u8>)> {
    coalesce(
        extents
            .into_iter()
            .map(|(off, d)| (off, Coalesced::Owned(d)))
            .collect(),
    )
    .into_iter()
    .map(|(off, c)| match c {
        Coalesced::Owned(v) => (off, v),
        // unreachable: every input above is Owned
        Coalesced::Borrowed(d) => (off, d.to_vec()),
    })
    .collect()
}

/// Shared error shape for a session op whose output variant does not
/// match what the staging call guarantees — a logic error surfaced
/// loudly by every legacy wrapper instead of coerced to a default.
fn unexpected_output(kind: &str, other: &OpOutput) -> crate::error::SageError {
    crate::error::SageError::Invalid(format!(
        "{kind} op yielded unexpected output {other:?}"
    ))
}

/// First retry backoff for isolated transient I/O errors, in virtual
/// seconds; each further attempt doubles it. Pure bookkeeping on the
/// [`RecoveryVerdict::TransientRetried`] verdict — the client clock
/// never advances for a retry, so the accounting cannot perturb
/// recovery schedules (no-storm runs stay bit-exact).
pub const TRANSIENT_RETRY_BACKOFF: SimTime = 0.001;

/// How a consumed failure event was ultimately resolved — the typed
/// verdict the storm-hardened [`Client::consume_failure_feed`] attaches
/// to every [`RecoveryOutcome`], so drivers (the soak harness,
/// `tools::soak`) account for every event without string-matching
/// errors.
#[derive(Debug, Clone, PartialEq)]
pub enum RecoveryVerdict {
    /// No data movement was needed (below HA thresholds, or an
    /// operator-facing `NodeAlert`).
    NoAction,
    /// The decided recovery session ran to completion.
    Recovered,
    /// An isolated transient I/O error resolved by bounded retry — no
    /// recovery session runs. `attempts` is 1, or 2 when the transient
    /// struck inside an in-flight recovery's window (the bound: retry
    /// never loops); `resolved_at` sums the doubling backoffs from
    /// [`TRANSIENT_RETRY_BACKOFF`] onto the event time.
    TransientRetried { attempts: u32, resolved_at: SimTime },
    /// This outcome's recovery was RETRACTED: its device re-failed at
    /// `refailed_at`, inside the session's in-flight window. The HA
    /// stamp was reopened and aborted
    /// ([`HaSubsystem::repair_aborted`](crate::mero::ha::HaSubsystem::repair_aborted)
    /// — `repairs_aborted` counts it), and the re-failure's own
    /// outcome carries the restarted repair, so the repair log never
    /// double-counts the device.
    AbortedByRefailure { refailed_at: SimTime },
    /// A decided proactive drain found its source already hard-failed
    /// (storm preemption) and ESCALATED to a full SNS repair under the
    /// same HA engagement — one repair-log entry, no double-count.
    EscalatedToRepair,
    /// A hard failure absorbed by an earlier escalated repair of the
    /// same pass: the device was already rebuilt by the escalation, so
    /// no second session runs and the device is not re-failed.
    AbsorbedByEscalation,
    /// The concurrent failure set exceeded pool parity tolerance:
    /// these objects hold stripes that can no longer be reconstructed.
    /// Surfaced as data, never a panic and never silent corruption —
    /// reads of the named objects keep erroring `Unavailable`, all
    /// other objects stay intact.
    DataLoss { objects: Vec<ObjectId> },
    /// Recovery could not complete for another reason (e.g. no spare
    /// capacity); see [`RecoveryOutcome::error`].
    Failed,
}

/// Outcome of one failure-feed event consumed by
/// [`Client::consume_failure_feed`]: the event, the HA subsystem's
/// decision for it, the typed [`RecoveryVerdict`], and — when a
/// recovery session ran — what it moved and when it completed.
#[derive(Debug, Clone)]
pub struct RecoveryOutcome {
    /// The failure event ingested from the feed.
    pub event: FailureEvent,
    /// The HA subsystem's decision (quasi-ordered event-set analysis).
    pub action: RepairAction,
    /// Bytes the executed recovery session rebuilt/moved (0 when no
    /// action ran). An [`RecoveryVerdict::AbortedByRefailure`] outcome
    /// keeps the bytes its session dispatched before the retraction.
    pub bytes: u64,
    /// Completion frontier of the executed recovery session (None when
    /// the decision required no data movement, or when it failed).
    pub completed_at: Option<SimTime>,
    /// Error of a recovery that could NOT complete (e.g. a drain with
    /// no spare capacity). The event is still consumed and the pass
    /// continues with the remaining events; the session's error path
    /// already re-armed the device in the HA subsystem
    /// (`repair_aborted`), so its next failure event decides fresh.
    pub error: Option<String>,
    /// Typed resolution; see [`RecoveryVerdict`].
    pub verdict: RecoveryVerdict,
}

/// Per-pass memory of the last recovery launched per device: the
/// consumer detects OVERLAP (a later event whose `at` falls inside an
/// earlier session's in-flight window) by comparing against this.
struct LastRecovery {
    /// Index of the outcome that launched the session.
    outcome: usize,
    /// The session's completion frontier.
    completed_at: SimTime,
    /// True when the session was a drain escalated to repair (its
    /// device was rebuilt; a stale hard event for it is absorbed).
    escalated: bool,
}

/// §Perf (ISSUE 8): scratch recycled across
/// [`Client::consume_failure_feed`] passes — the device→node map, the
/// due-event batch and the per-device overlap table would otherwise
/// reallocate on every pass (one pass per soak tick). Taken out of
/// the client for the pass (`mem::take`) and put back afterwards, so
/// `consume_event` can borrow its fields disjointly from `&mut self`.
#[derive(Default)]
struct PassScratch {
    nodes: Vec<Option<usize>>,
    due: Vec<FailureEvent>,
    last: std::collections::BTreeMap<usize, LastRecovery>,
}

/// A Clovis client handle: the entry point of the SAGE storage API.
pub struct Client {
    pub store: MeroStore,
    /// PJRT executor for shipped functions and SNS parity; `None` runs
    /// CPU fallbacks (identical results, no kernel offload).
    pub exec: Option<Executor>,
    pub addb: addb::Addb,
    pub fdmi: fdmi::FdmiBus,
    /// Client-local virtual clock (single-client convenience; rank-
    /// parallel workloads keep their own `RankClocks` and use the
    /// `*_at` variants).
    pub now: SimTime,
    /// The ONE cluster-wide per-device scheduler (ISSUE 7): every
    /// session adopts it for the duration of its run (opening a fresh
    /// scheduling epoch) and hands it back, so concurrent sessions
    /// contend on shared device shards instead of each owning a
    /// private scheduler. Its QoS split and tenant table are re-synced
    /// from [`Cluster::qos`]/[`Cluster::tenants`] at every adoption.
    pub sched: IoScheduler,
    /// Recycled consumer-pass scratch (see [`PassScratch`]).
    feed_scratch: PassScratch,
}

impl Client {
    /// Client over a simulated testbed, no kernel offload.
    pub fn new_sim(testbed: Testbed) -> Client {
        Client::from_cluster(testbed.build_cluster())
    }

    /// Client over an explicitly-built [`Cluster`] (what
    /// [`Client::new_sim`] delegates to; benches and tests that craft
    /// bespoke pool geometries use this directly).
    pub fn from_cluster(cluster: Cluster) -> Client {
        Client {
            store: MeroStore::new(cluster),
            exec: None,
            addb: addb::Addb::new(4096),
            fdmi: fdmi::FdmiBus::new(),
            now: 0.0,
            sched: IoScheduler::new(),
            feed_scratch: PassScratch::default(),
        }
    }

    /// Client with the PJRT executor attached (loads `artifacts/`).
    pub fn new_with_runtime(testbed: Testbed) -> Result<Client> {
        let mut c = Client::new_sim(testbed);
        c.exec = Some(Executor::load_default()?);
        Ok(c)
    }

    // ------------------------------------------------------------ objects

    /// Create an object with the default layout.
    pub fn create_object(&mut self, block_size: u64) -> Result<ObjectId> {
        self.create_object_with(block_size, Layout::default())
    }

    /// Create an object with an explicit layout.
    pub fn create_object_with(
        &mut self,
        block_size: u64,
        layout: Layout,
    ) -> Result<ObjectId> {
        let id = self.store.create_object(block_size, layout)?;
        self.addb.record(self.now, "clovis", "obj_create", 1.0);
        self.fdmi.emit(fdmi::FdmiRecord::ObjectCreated { obj: id, at: self.now });
        Ok(id)
    }

    /// Write (real bytes), advancing the client clock.
    pub fn write_object(
        &mut self,
        obj: &ObjectId,
        offset: u64,
        data: &[u8],
    ) -> Result<SimTime> {
        let t = self
            .store
            .write_object(*obj, offset, data, self.now, self.exec.as_ref())?;
        self.addb
            .record(self.now, "clovis", "obj_write_bytes", data.len() as f64);
        self.fdmi.emit(fdmi::FdmiRecord::ObjectWritten {
            obj: *obj,
            offset,
            len: data.len() as u64,
            at: self.now,
        });
        self.now = t;
        Ok(t)
    }

    /// Read, advancing the client clock.
    pub fn read_object(
        &mut self,
        obj: &ObjectId,
        offset: u64,
        len: u64,
    ) -> Result<Vec<u8>> {
        let (data, t) = self.store.read_object(*obj, offset, len, self.now)?;
        self.addb.record(self.now, "clovis", "obj_read_bytes", len as f64);
        self.fdmi.emit(fdmi::FdmiRecord::ObjectRead {
            obj: *obj,
            offset,
            len,
            at: self.now,
        });
        self.now = t;
        Ok(data)
    }

    /// Read `dst.len()` bytes directly into a caller buffer (§Perf:
    /// no per-read allocation; reuse one buffer across reads).
    /// Semantically identical to [`Client::read_object`].
    pub fn read_object_into(
        &mut self,
        obj: &ObjectId,
        offset: u64,
        dst: &mut [u8],
    ) -> Result<SimTime> {
        let t = self.store.read_object_into(*obj, offset, dst, self.now)?;
        self.addb
            .record(self.now, "clovis", "obj_read_bytes", dst.len() as f64);
        self.fdmi.emit(fdmi::FdmiRecord::ObjectRead {
            obj: *obj,
            offset,
            len: dst.len() as u64,
            at: self.now,
        });
        self.now = t;
        Ok(t)
    }

    // ------------------------------------------------------ batched ops
    //
    // The vectored entry points below are thin wrappers over one-op
    // [`Session`]s (the op-builder API, ISSUE 4): signatures, stored
    // bytes, placements and completion times are identical to their
    // pre-session selves (`tests/prop_session.rs` pins this), while
    // the execution engine lives in exactly one place
    // (`session::exec`). Stage several ops on one session instead to
    // overlap mixed kinds on shared device shards.

    /// The Clovis op builder: every operation kind staged as an op on
    /// ONE scheduler-backed group — see [`session::Session`]. Runs as
    /// [`DEFAULT_TENANT`](crate::sim::sched::DEFAULT_TENANT) (always
    /// admitted).
    pub fn session<'c, 'd>(&'c mut self) -> Session<'c, 'd> {
        Session::new(self)
    }

    /// Admit a new tenant with `weight` onto the cluster's tenant
    /// table and return its id (ISSUE 7 multi-tenant plane). With two
    /// or more registered tenants every shard schedules `(tenant,
    /// class)` frontier lanes at `weight/Σweights` of the device rate
    /// — see [`TenantShares`](crate::sim::sched::TenantShares) and
    /// OPERATIONS.md §Tenant shares.
    pub fn register_tenant(&mut self, weight: f64) -> TenantId {
        self.store.cluster.tenants.register(weight)
    }

    /// [`Client::session`] dispatching as `tenant` — the admission
    /// control point of the multi-tenant plane: unregistered ids are
    /// refused here, at the Clovis layer, so the scheduler below never
    /// sees a tenant the cluster didn't admit.
    pub fn session_as<'c, 'd>(
        &'c mut self,
        tenant: TenantId,
    ) -> Result<Session<'c, 'd>> {
        if !self.store.cluster.tenants.is_registered(tenant) {
            return Err(SageError::Invalid(format!(
                "tenant {tenant} is not registered (admission control; \
                 register_tenant first)"
            )));
        }
        Ok(Session::for_tenant(self, tenant))
    }

    /// Vectored write over borrowed extents: one session op, launched
    /// at the current clock (`m0_op_launch`/`m0_op_wait` over the
    /// batch). Unit I/Os dispatch onto the group's sharded per-device
    /// scheduler in one pass, so extents on different devices overlap
    /// in virtual time and the call completes at the max over
    /// per-device completion frontiers (`mero::sns_serial` keeps the
    /// serial-fold semantics as the oracle). List-adjacent extents are
    /// **coalesced into one op before striping** (ROADMAP §Perf
    /// cross-op coalescing): merged partial stripes become full
    /// stripes, saving RMW parity envelopes, while overlapping extents
    /// keep their application order — persisted bytes are identical to
    /// the unmerged batch. ADDB telemetry and the FDMI event are
    /// amortized to ONE record per batch (§Perf). Returns the group
    /// completion time.
    pub fn writev(
        &mut self,
        obj: &ObjectId,
        extents: &[(u64, &[u8])],
    ) -> Result<SimTime> {
        let mut s = self.session();
        s.write(obj, extents);
        Ok(s.run()?.completed_at)
    }

    /// Vectored write of owned buffers (§Perf persist-by-move: each
    /// buffer becomes object block storage without a copy). Batched
    /// and sharded like [`Client::writev`].
    pub fn writev_owned(
        &mut self,
        obj: &ObjectId,
        extents: Vec<(u64, Vec<u8>)>,
    ) -> Result<SimTime> {
        let mut s = self.session();
        s.write_owned(obj, extents);
        Ok(s.run()?.completed_at)
    }

    /// Vectored read over an extent list: one session op dispatched
    /// through the group's sharded per-device scheduler (extents on
    /// different devices overlap in virtual time). List-adjacent
    /// extents are **coalesced into one striped read before dispatch**
    /// (ROADMAP cross-op read coalescing, mirroring the `writev`
    /// merge): the merged buffer is sliced back per caller extent, so
    /// the returned buffers are byte-identical and order-preserving
    /// while shared edge units are read once. Returns one buffer per
    /// extent; ADDB/FDMI amortized to one record per batch.
    pub fn readv(
        &mut self,
        obj: &ObjectId,
        extents: &[ops::Extent],
    ) -> Result<Vec<Vec<u8>>> {
        let mut s = self.session();
        let h = s.read(obj, extents);
        let mut report = s.run()?;
        match report.outputs.swap_remove(h.index()) {
            OpOutput::Read(bufs) => Ok(bufs),
            other => Err(unexpected_output("read", &other)),
        }
    }

    /// Delete an object at end of life.
    pub fn delete_object(&mut self, obj: ObjectId) -> Result<()> {
        self.store.delete_object(obj)?;
        self.fdmi.emit(fdmi::FdmiRecord::ObjectDeleted { obj, at: self.now });
        Ok(())
    }

    // ------------------------------------------------- recovery plane

    /// Execute an HSM migration `plan` as ONE batched op group on the
    /// group's sharded scheduler (scheduler-driven recovery plane):
    /// every source read dispatches up front, rewrites stream behind
    /// their own read frontiers, and the op completes at the max over
    /// per-device frontiers (`Hsm::migrate_with`). Emits one
    /// [`fdmi::FdmiRecord::ObjectMigrated`] per moved object — the
    /// HSM/analytics data-movement feed — plus batch-amortized ADDB
    /// telemetry, and advances the client clock.
    pub fn migrate_with(
        &mut self,
        hsm: &mut crate::hsm::Hsm,
        plan: &[crate::hsm::Migration],
    ) -> Result<SimTime> {
        let mut s = self.session();
        s.migrate(hsm, plan);
        Ok(s.run()?.completed_at)
    }

    /// SNS-repair `failed_dev` over `objects` as ONE batched op group
    /// (scheduler-driven recovery plane): survivor reads dispatch
    /// across per-device shards in one pass, rebuild writes stream
    /// onto the replacement devices, and the HA subsystem's
    /// `repair_done` is stamped with the group's `wait_all` completion
    /// — so repair telemetry carries the real scheduler frontier, not
    /// a serial fold. The repaired device is returned to service empty
    /// (`replace_device`). Returns (bytes rebuilt, completion time)
    /// and advances the client clock.
    pub fn repair_with(
        &mut self,
        objects: &[ObjectId],
        failed_dev: usize,
    ) -> Result<(u64, SimTime)> {
        let mut s = self.session();
        let h = s.repair(objects, failed_dev);
        let report = s.run()?;
        let bytes = match report.output(h) {
            OpOutput::Repair { bytes } => *bytes,
            other => return Err(unexpected_output("repair", other)),
        };
        Ok((bytes, report.completed_at))
    }

    /// Proactively drain a DEGRADING (still-live) device through the
    /// recovery plane, as ONE session op (`.migrate`-shaped two-phase
    /// drain): every unit homed on `dev` across `objects` is read off
    /// the device and rewritten elsewhere at its own read frontier —
    /// no reconstruction, the device still serves reads. Executes
    /// [`RepairAction::ProactiveDrain`] decisions: the HA subsystem's
    /// `repair_done` is stamped with the drain's completion frontier
    /// and the device STAYS in service (it never failed). Returns
    /// (bytes moved, completion time) and advances the client clock.
    ///
    /// [`RepairAction::ProactiveDrain`]: crate::mero::ha::RepairAction::ProactiveDrain
    pub fn drain_with(
        &mut self,
        objects: &[ObjectId],
        dev: usize,
    ) -> Result<(u64, SimTime)> {
        let mut s = self.session();
        let h = s.drain(objects, dev);
        let report = s.run()?;
        let bytes = match report.output(h) {
            OpOutput::Drain { bytes } => *bytes,
            other => return Err(unexpected_output("drain", other)),
        };
        Ok((bytes, report.completed_at))
    }

    /// Consume every due event of the cluster's failure feed and close
    /// the loop from detection to recovery with no manual
    /// intervention: each popped [`FailureEvent`] is routed through
    /// the HA subsystem's decision rules
    /// ([`HaSubsystem::observe`](crate::mero::ha::HaSubsystem::observe)),
    /// and the decided action executes immediately as a recovery-plane
    /// session — [`RepairAction::RebuildDevice`] via
    /// [`Client::repair_with`], [`RepairAction::ProactiveDrain`] via
    /// [`Client::drain_with`] — dispatching as Repair-class traffic
    /// under the cluster's QoS split, so a consumer pass never starves
    /// concurrent foreground sessions (§3.2.1 repair throttling).
    ///
    /// Rebuild and drain TARGETS are picked through the live
    /// placement [`CongestionView`](crate::mero::pool::CongestionView)
    /// (ISSUE 10): the recovery sessions spawned here run through
    /// [`Session::run`](crate::clovis::session::Session::run), which
    /// samples the cluster scheduler's committed backlog at adoption
    /// time and installs it on the pool set — so
    /// `PoolSet::allocate` re-homes units away from the
    /// deepest-backlog devices while the view is live, and falls back
    /// bit-for-bit to least-utilized placement when every shard has
    /// drained past the clock.
    ///
    /// Hard `FailureKind::Device` events take the device out of
    /// service before the HA subsystem sees them (the feed is the
    /// source of truth; no test-side `fail_device` needed). Executed
    /// recoveries advance the client clock, and newly-due events that
    /// the advanced clock exposes are consumed in the same pass, so
    /// one call fully settles the feed up to `self.now`. Returns one
    /// [`RecoveryOutcome`] per event consumed — a recovery that fails
    /// (e.g. no spare capacity) surfaces in its outcome's `error`
    /// field and the pass CONTINUES, so one stuck device never makes
    /// the consumer drop later events the feed already popped.
    ///
    /// ## Storm hardening
    ///
    /// The consumer is hardened for OVERLAPPING failures (correlated
    /// storms, `FailureSchedule::storm`):
    ///
    /// * **batch-concurrent strikes** — every hard failure of a due
    ///   batch takes effect before any recovery of the batch runs, so
    ///   a storm's members are genuinely down together and parity
    ///   arithmetic sees the true concurrent set. A batch with at most
    ///   one hard failure behaves exactly like the pre-storm consumer,
    ///   bit-exactly (`tests/prop_storm.rs`).
    /// * **re-failure mid-repair** — a device re-failing inside its
    ///   Repair-class session's in-flight window retracts that
    ///   session's HA stamp (reopen + [`repair_aborted`], counted in
    ///   `repairs_aborted`), marks the old outcome
    ///   [`RecoveryVerdict::AbortedByRefailure`], and restarts repair
    ///   accounting under the re-failure's own outcome.
    /// * **drain preemption** — a decided proactive drain whose source
    ///   already hard-failed escalates to a full SNS repair under the
    ///   SAME engagement ([`RecoveryVerdict::EscalatedToRepair`]); the
    ///   source's own hard event is then absorbed
    ///   ([`RecoveryVerdict::AbsorbedByEscalation`]) — one repair-log
    ///   entry, never a double-count.
    /// * **beyond-parity storms** — a storm exceeding pool parity
    ///   tolerance surfaces a typed
    ///   [`RecoveryVerdict::DataLoss`] naming the objects whose
    ///   stripes are no longer reconstructible
    ///   (`MeroStore::unrecoverable_objects`) — never a panic, never
    ///   silent corruption.
    /// * **transient retry accounting** — an isolated transient
    ///   resolves as [`RecoveryVerdict::TransientRetried`] with
    ///   bounded attempts and a backoff-summed `resolved_at`; the
    ///   client clock never advances for a retry.
    ///
    /// [`repair_aborted`]: crate::mero::ha::HaSubsystem::repair_aborted
    pub fn consume_failure_feed(
        &mut self,
        feed: &mut FailureSchedule,
        objects: &[ObjectId],
    ) -> Vec<RecoveryOutcome> {
        // §Perf (ISSUE 8): the pass scratch is taken out of the
        // client, reused for the whole pass (and across passes), and
        // put back — a long soak runs one pass per tick and
        // reallocating the node map / batch buffer / overlap table
        // every tick was measurable churn.
        let mut scratch = std::mem::take(&mut self.feed_scratch);
        // topology is fixed across the pass: map devices to nodes once
        let n_devs = self.store.cluster.devices.len();
        scratch.nodes.clear();
        scratch
            .nodes
            .extend((0..n_devs).map(|d| self.store.cluster.node_of(d)));
        scratch.last.clear();
        let mut out: Vec<RecoveryOutcome> = Vec::new();
        loop {
            // events due at the client clock; executed recoveries
            // advance it, so newly-due events surface next iteration
            feed.due_into(self.now, &mut scratch.due);
            if scratch.due.is_empty() {
                break;
            }
            // failures strike at their own timestamps, BEFORE any
            // recovery of this batch runs: a correlated storm is
            // genuinely concurrent, so parity arithmetic sees every
            // member down. The one exception is a re-failure already
            // absorbed by an escalated repair — that device was
            // rebuilt, and the stale event refers to hardware that no
            // longer holds data.
            for event in &scratch.due {
                if let FailureKind::Device(d) = event.kind {
                    let absorbed = scratch.last.get(&d).is_some_and(|l| {
                        l.escalated && event.at <= l.completed_at
                    });
                    if !absorbed && !self.store.cluster.devices[d].failed {
                        self.store.cluster.fail_device(d);
                    }
                }
            }
            for event in scratch.due.drain(..) {
                // recovery-plane bookkeeping errors are typed values,
                // never panics (`no-panic-in-recovery`): an internal
                // error becomes a Failed outcome so the event stays
                // accounted and the pass continues
                if let Err(e) = self.consume_event(
                    event,
                    objects,
                    &scratch.nodes,
                    &mut scratch.last,
                    &mut out,
                ) {
                    out.push(Self::failed_outcome(event, &e));
                }
            }
        }
        self.feed_scratch = scratch;
        out
    }

    /// Wrap an internal recovery-plane error as a typed
    /// [`RecoveryVerdict::Failed`] outcome (the event is consumed and
    /// accounted; the error text names the bookkeeping fault).
    fn failed_outcome(event: FailureEvent, e: &SageError) -> RecoveryOutcome {
        RecoveryOutcome {
            event,
            action: RepairAction::None,
            bytes: 0,
            completed_at: None,
            error: Some(e.to_string()),
            verdict: RecoveryVerdict::Failed,
        }
    }

    /// One event of a consumer pass: overlap handling, HA decision,
    /// recovery execution, verdict. See [`Client::consume_failure_feed`].
    /// Bookkeeping faults surface as [`SageError::Recovery`] — this
    /// path never panics (`no-panic-in-recovery`).
    fn consume_event(
        &mut self,
        event: FailureEvent,
        objects: &[ObjectId],
        nodes: &[Option<usize>],
        last: &mut std::collections::BTreeMap<usize, LastRecovery>,
        out: &mut Vec<RecoveryOutcome>,
    ) -> Result<()> {
        if let FailureKind::Device(d) = event.kind {
            if let Some(l) = last.get(&d) {
                if event.at <= l.completed_at && l.escalated {
                    // the escalated repair already rebuilt this device;
                    // the stale hard event is absorbed — no second
                    // session, no re-fail, no HA churn
                    out.push(RecoveryOutcome {
                        event,
                        action: RepairAction::None,
                        bytes: 0,
                        completed_at: None,
                        error: None,
                        verdict: RecoveryVerdict::AbsorbedByEscalation,
                    });
                    return Ok(());
                }
                if event.at <= l.completed_at {
                    // the device re-failed while its recovery session
                    // was in flight: retract the stamp (reopen the log
                    // entry, then abort the re-engaged repair — the
                    // abort counter records the restart), take the
                    // replacement out of service, and let this event's
                    // own observe decide a fresh rebuild
                    let prev = last.remove(&d).ok_or_else(|| {
                        SageError::Recovery(format!(
                            "overlap table lost device {d} mid-pass"
                        ))
                    })?;
                    self.store.ha.reopen_last(d);
                    self.store.ha.repair_aborted(d);
                    if !self.store.cluster.devices[d].failed {
                        self.store.cluster.fail_device(d);
                    }
                    let retracted =
                        out.get_mut(prev.outcome).ok_or_else(|| {
                            SageError::Recovery(format!(
                                "dangling outcome index {} for device {d}",
                                prev.outcome
                            ))
                        })?;
                    retracted.verdict =
                        RecoveryVerdict::AbortedByRefailure {
                            refailed_at: event.at,
                        };
                }
            }
        }

        let action = self.store.ha.observe(event, |d| nodes[d]);
        let executed = match action {
            RepairAction::RebuildDevice(d) => {
                Some((d, self.repair_with(objects, d), false))
            }
            RepairAction::ProactiveDrain(d) => {
                if self.store.cluster.devices[d].failed {
                    // storm preemption: the drain source hard-failed
                    // before the drain could run — escalate to a full
                    // SNS repair under the SAME engagement (the repair
                    // closes the engagement observe() opened, so the
                    // log carries exactly one entry for this device)
                    Some((d, self.repair_with(objects, d), true))
                } else {
                    Some((d, self.drain_with(objects, d), false))
                }
            }
            _ => None,
        };
        match executed {
            Some((d, Ok((bytes, t)), escalated)) => {
                last.insert(
                    d,
                    LastRecovery { outcome: out.len(), completed_at: t, escalated },
                );
                out.push(RecoveryOutcome {
                    event,
                    action,
                    bytes,
                    completed_at: Some(t),
                    error: None,
                    verdict: if escalated {
                        RecoveryVerdict::EscalatedToRepair
                    } else {
                        RecoveryVerdict::Recovered
                    },
                });
            }
            Some((_, Err(e), _)) => {
                // typed data-loss verdict: when the concurrent failure
                // set exceeded pool parity tolerance, NAME the objects
                // that are no longer reconstructible — never a panic,
                // never silent corruption
                let lost = self.store.unrecoverable_objects(objects);
                let verdict = if lost.is_empty() {
                    RecoveryVerdict::Failed
                } else {
                    RecoveryVerdict::DataLoss { objects: lost }
                };
                out.push(RecoveryOutcome {
                    event,
                    action,
                    bytes: 0,
                    completed_at: None,
                    error: Some(e.to_string()),
                    verdict,
                });
            }
            None => {
                // below thresholds: bounded transient retry accounting
                let verdict = match event.kind {
                    FailureKind::Transient(d)
                        if action == RepairAction::None =>
                    {
                        let attempts = if last
                            .get(&d)
                            .is_some_and(|l| event.at <= l.completed_at)
                        {
                            2
                        } else {
                            1
                        };
                        RecoveryVerdict::TransientRetried {
                            attempts,
                            resolved_at: event.at
                                + TRANSIENT_RETRY_BACKOFF
                                    * ((1u64 << attempts) - 1) as f64,
                        }
                    }
                    _ => RecoveryVerdict::NoAction,
                };
                out.push(RecoveryOutcome {
                    event,
                    action,
                    bytes: 0,
                    completed_at: None,
                    error: None,
                    verdict,
                });
            }
        }
        Ok(())
    }

    /// Grow a pool under load (elastic membership): attach a fresh
    /// device with `profile` to `node`, register it with the tier
    /// pools (`PoolSet::register` — allocations see the capacity
    /// immediately), and rebalance `objects` onto it as ONE
    /// Migration-class session ([`Session::rebalance`], the inverse of
    /// a drain). Returns (new device id, bytes moved, completion time)
    /// and advances the client clock. Objects the rebalance plan does
    /// not touch keep their placements bit-for-bit
    /// (`tests/prop_storm.rs`).
    pub fn expand_pool(
        &mut self,
        node: crate::cluster::NodeId,
        profile: crate::sim::device::DeviceProfile,
        objects: &[ObjectId],
    ) -> Result<(crate::cluster::DeviceId, u64, SimTime)> {
        let dev = self.store.attach_device(node, profile)?;
        let mut s = self.session();
        let h = s.rebalance(objects, dev);
        let report = s.run()?;
        let bytes = match report.output(h) {
            OpOutput::Rebalance { bytes } => *bytes,
            other => return Err(unexpected_output("rebalance", other)),
        };
        Ok((dev, bytes, report.completed_at))
    }

    // ------------------------------------------------------------ indices

    /// Create a KV index.
    pub fn create_index(&mut self) -> IndexId {
        self.store.create_index()
    }

    /// Batched PUT on an index.
    pub fn idx_put(
        &mut self,
        idx: IndexId,
        records: Vec<(Vec<u8>, Vec<u8>)>,
    ) -> Result<()> {
        let n = records.len() as f64;
        self.store.index_mut(idx)?.put_batch(records);
        self.addb.record(self.now, "clovis", "idx_put", n);
        Ok(())
    }

    /// Batched GET on an index.
    pub fn idx_get(
        &mut self,
        idx: IndexId,
        keys: &[Vec<u8>],
    ) -> Result<Vec<Option<Vec<u8>>>> {
        Ok(self.store.index(idx)?.get_batch(keys))
    }

    /// Batched DEL on an index.
    pub fn idx_del(&mut self, idx: IndexId, keys: &[Vec<u8>]) -> Result<Vec<bool>> {
        Ok(self.store.index_mut(idx)?.del_batch(keys))
    }

    /// Batched NEXT on an index.
    pub fn idx_next(
        &mut self,
        idx: IndexId,
        keys: &[Vec<u8>],
    ) -> Result<Vec<Option<(Vec<u8>, Vec<u8>)>>> {
        Ok(self.store.index(idx)?.next_batch(keys))
    }

    // -------------------------------------------------------- containers

    /// Create a container with a tier hint.
    pub fn create_container(
        &mut self,
        label: &str,
        tier: Option<DeviceKind>,
    ) -> ContainerId {
        self.store.create_container(label, tier)
    }

    /// Add an object to a container.
    pub fn container_add(&mut self, c: ContainerId, obj: ObjectId) -> Result<()> {
        self.store.container_mut(c)?.add(obj);
        Ok(())
    }

    // ------------------------------------------------------ transactions

    /// Begin a distributed transaction.
    pub fn tx_begin(&mut self) -> TxId {
        self.store.dtm.begin()
    }

    /// Transactional KV write (buffered until commit).
    pub fn tx_put(&mut self, tx: TxId, key: Vec<u8>, val: Vec<u8>) -> Result<()> {
        self.store.dtm.write(tx, key, val)
    }

    /// Transactional read (snapshot + read-your-writes).
    pub fn tx_get(&mut self, tx: TxId, key: &[u8]) -> Result<Option<Vec<u8>>> {
        self.store.dtm.read(tx, key)
    }

    /// Commit; advances the clock by the (group-amortized) log force.
    pub fn tx_commit(&mut self, tx: TxId) -> Result<SimTime> {
        let t = self.store.dtm.commit(tx, self.now)?;
        self.now = t;
        self.addb.record(self.now, "dtm", "commit", 1.0);
        Ok(t)
    }

    /// Abort a transaction.
    pub fn tx_abort(&mut self, tx: TxId) -> Result<()> {
        self.store.dtm.abort(tx)
    }

    // -------------------------------------------------- function shipping

    /// Ship a function to the storage node holding `obj` (§3.2.1):
    /// the computation runs where the data lives. One session op —
    /// stage [`Session::ship`] next to writes/reads/migrations instead
    /// to overlap in-storage compute with foreground I/O on shared
    /// device shards (the paper's headline mixed workload).
    pub fn ship_to_object(
        &mut self,
        obj: ObjectId,
        func: FunctionKind,
    ) -> Result<ShipResult> {
        let mut s = self.session();
        let h = s.ship(obj, func);
        let mut report = s.run()?;
        match report.outputs.swap_remove(h.index()) {
            OpOutput::Ship(r) => Ok(r),
            other => Err(unexpected_output("ship", &other)),
        }
    }

    /// One-shot operation: ship a function to every object in a
    /// container (§3.2.1 Containers), as ONE `.after`-chained session
    /// (each shipment dispatches at its predecessor's completion
    /// frontier — identical to the former sequential calls, but on one
    /// op group).
    pub fn ship_to_container(
        &mut self,
        container: ContainerId,
        func: FunctionKind,
    ) -> Result<Vec<ShipResult>> {
        let objs = self.store.container_objects(container)?;
        if objs.is_empty() {
            return Ok(Vec::new());
        }
        let mut s = self.session();
        let mut prev: Option<OpHandle> = None;
        for obj in objs {
            let h = s.ship(obj, func.clone());
            if let Some(p) = prev {
                s.after(h, p)?;
            }
            prev = Some(h);
        }
        let report = s.run()?;
        let mut out = Vec::with_capacity(report.outputs.len());
        for o in report.outputs {
            match o {
                OpOutput::Ship(r) => out.push(r),
                other => return Err(unexpected_output("ship", &other)),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn client() -> Client {
        Client::new_sim(Testbed::sage_prototype())
    }

    #[test]
    fn tenant_admission_control_gates_session_as() {
        let mut c = client();
        // the default tenant is always admitted
        assert!(c.session_as(crate::sim::sched::DEFAULT_TENANT).is_ok());
        // an unregistered id is refused at the Clovis layer
        assert!(matches!(c.session_as(7), Err(SageError::Invalid(_))));
        // registration admits it; ids are dense and deterministic
        let t = c.register_tenant(2.0);
        assert_eq!(t, 1);
        assert!(c.session_as(t).is_ok());
        assert!(c.store.cluster.tenants.active());
        // a refused session leaves the client fully usable (the
        // shared scheduler was never taken)
        let obj = c.create_object(4096).unwrap();
        let data = vec![5u8; 4 * 65536];
        c.write_object(&obj, 0, &data).unwrap();
        assert_eq!(c.read_object(&obj, 0, data.len() as u64).unwrap(), data);
    }

    #[test]
    fn object_roundtrip_via_client() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![7u8; 4 * 65536]; // one full default stripe
        let t = c.write_object(&obj, 0, &data).unwrap();
        assert!(t > 0.0);
        let back = c.read_object(&obj, 0, data.len() as u64).unwrap();
        assert_eq!(back, data);
        assert!(c.now >= t);
    }

    #[test]
    fn index_api() {
        let mut c = client();
        let idx = c.create_index();
        c.idx_put(idx, vec![(b"k1".to_vec(), b"v1".to_vec())]).unwrap();
        let got = c.idx_get(idx, &[b"k1".to_vec(), b"nope".to_vec()]).unwrap();
        assert_eq!(got[0], Some(b"v1".to_vec()));
        assert_eq!(got[1], None);
    }

    #[test]
    fn transactions_atomic_via_client() {
        let mut c = client();
        let tx = c.tx_begin();
        c.tx_put(tx, b"a".to_vec(), b"1".to_vec()).unwrap();
        assert_eq!(c.tx_get(tx, b"a").unwrap(), Some(b"1".to_vec()));
        c.tx_commit(tx).unwrap();
        assert_eq!(c.store.dtm.get(b"a"), Some(&b"1".to_vec()));
    }

    #[test]
    fn container_grouping() {
        let mut c = client();
        let cont = c.create_container("hot", Some(DeviceKind::Nvram));
        let o1 = c.create_object(4096).unwrap();
        let o2 = c.create_object(4096).unwrap();
        c.container_add(cont, o1).unwrap();
        c.container_add(cont, o2).unwrap();
        assert_eq!(c.store.container_objects(cont).unwrap().len(), 2);
    }

    #[test]
    fn writev_matches_sequential_single_ops() {
        let mut batched = client();
        let mut sequential = client();
        let ob = batched.create_object(4096).unwrap();
        let os = sequential.create_object(4096).unwrap();
        let stripe = 4 * 65536u64; // default layout stripe width
        let chunks: Vec<Vec<u8>> = (0..3)
            .map(|i| vec![(i + 1) as u8; stripe as usize])
            .collect();
        let extents: Vec<(u64, &[u8])> = chunks
            .iter()
            .enumerate()
            .map(|(i, c)| (i as u64 * stripe, c.as_slice()))
            .collect();
        batched.writev(&ob, &extents).unwrap();
        for (off, data) in &extents {
            sequential.write_object(&os, *off, data).unwrap();
        }
        let nb = batched.read_object(&ob, 0, 3 * stripe).unwrap();
        let ns = sequential.read_object(&os, 0, 3 * stripe).unwrap();
        assert_eq!(nb, ns, "vectored and single-op writes store same bytes");
    }

    #[test]
    fn writev_amortizes_fdmi_and_addb_to_one_record_per_batch() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let emitted_before = c.fdmi.emitted;
        let stripe = 4 * 65536u64;
        let a = vec![1u8; stripe as usize];
        let b = vec![2u8; stripe as usize];
        let d = vec![3u8; stripe as usize];
        c.writev(&obj, &[(0, &a), (stripe, &b), (2 * stripe, &d)]).unwrap();
        assert_eq!(
            c.fdmi.emitted - emitted_before,
            1,
            "one FDMI event per batch, not per extent"
        );
        let summary = c.addb.summary();
        let (n_batches, bytes) = summary
            .iter()
            .find(|(k, _)| k == "clovis.obj_writev_bytes")
            .map(|(_, v)| *v)
            .unwrap();
        assert_eq!(n_batches, 1, "one ADDB sample per batch");
        assert_eq!(bytes, 3.0 * stripe as f64);
    }

    #[test]
    fn writev_records_sharded_dispatch_stats() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let stripe = 4 * 65536u64;
        // ONE op spanning 3 full stripes: its 15 unit writes share one
        // submit timestamp, so each touched device's submissions
        // coalesce into a single accounting run (8 SSDs in the pool)
        let a = vec![1u8; 3 * stripe as usize];
        c.writev(&obj, &[(0, &a)]).unwrap();
        let summary = c.addb.summary();
        let (_, runs) = summary
            .iter()
            .find(|(k, _)| k == "clovis.obj_writev_io_runs")
            .map(|(_, v)| *v)
            .expect("sharded dispatch stat recorded");
        assert!(
            runs >= 1.0 && runs < 15.0,
            "15 unit writes must coalesce below one run per unit: {runs}"
        );
    }

    #[test]
    fn readv_and_read_into_match_read_object() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let stripe = 4 * 65536u64;
        let data: Vec<u8> = (0..2 * stripe).map(|i| (i % 247) as u8).collect();
        c.write_object(&obj, 0, &data).unwrap();
        let exts =
            [Extent::new(0, stripe), Extent::new(stripe, stripe)];
        let parts = c.readv(&obj, &exts).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], &data[..stripe as usize]);
        assert_eq!(parts[1], &data[stripe as usize..]);
        let mut buf = vec![0xFFu8; data.len()];
        c.read_object_into(&obj, 0, &mut buf).unwrap();
        assert_eq!(buf, data);
    }

    #[test]
    fn writev_owned_roundtrip_and_clock_advance() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let stripe = 4 * 65536u64;
        let t0 = c.now;
        let t = c
            .writev_owned(
                &obj,
                vec![
                    (0, vec![9u8; stripe as usize]),
                    (stripe, vec![8u8; stripe as usize]),
                ],
            )
            .unwrap();
        assert!(t > t0, "group completion advances the clock");
        assert_eq!(c.now, t);
        let back = c.read_object(&obj, 0, 2 * stripe).unwrap();
        assert_eq!(&back[..stripe as usize], &vec![9u8; stripe as usize][..]);
        assert_eq!(&back[stripe as usize..], &vec![8u8; stripe as usize][..]);
    }

    #[test]
    fn writev_coalesces_adjacent_extents_before_striping() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let stripe = 4 * 65536u64;
        // two half-stripe extents, adjacent in list order: they merge
        // into ONE full-stripe op (no RMW envelope at all)
        let half = (stripe / 2) as usize;
        let a = vec![5u8; half];
        let b = vec![6u8; half];
        c.writev(&obj, &[(0, &a), (stripe / 2, &b)]).unwrap();
        let summary = c.addb.summary();
        let (_, merged) = summary
            .iter()
            .find(|(k, _)| k == "clovis.obj_writev_merged_ops")
            .map(|(_, v)| *v)
            .expect("merged-op stat recorded");
        assert_eq!(merged, 1.0, "adjacent extents merge into one op");
        let back = c.read_object(&obj, 0, stripe).unwrap();
        assert_eq!(&back[..half], &a[..]);
        assert_eq!(&back[half..], &b[..]);
    }

    #[test]
    fn writev_overlapping_extents_apply_in_list_order() {
        // coalescing must not reorder: a duplicate-offset extent later
        // in the list wins, exactly like sequential single ops
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let stripe = 4 * 65536u64;
        let a = vec![1u8; stripe as usize];
        let b = vec![2u8; 8192];
        c.writev(&obj, &[(0, &a), (0, &b)]).unwrap();
        let back = c.read_object(&obj, 0, stripe).unwrap();
        assert_eq!(&back[..8192], &b[..]);
        assert_eq!(&back[8192..], &a[8192..]);
    }

    #[test]
    fn writev_owned_coalesces_adjacent_extents() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let stripe = 4 * 65536u64;
        let half = (stripe / 2) as usize;
        c.writev_owned(
            &obj,
            vec![(0, vec![7u8; half]), (stripe / 2, vec![8u8; half])],
        )
        .unwrap();
        let summary = c.addb.summary();
        let (_, merged) = summary
            .iter()
            .find(|(k, _)| k == "clovis.obj_writev_merged_ops")
            .map(|(_, v)| *v)
            .expect("merged-op stat recorded");
        assert_eq!(merged, 1.0);
        let back = c.read_object(&obj, 0, stripe).unwrap();
        assert_eq!(&back[..half], &vec![7u8; half][..]);
        assert_eq!(&back[half..], &vec![8u8; half][..]);
    }

    #[test]
    fn migrate_with_emits_object_migrated_fdmi() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![3u8; 4 * 65536];
        c.write_object(&obj, 0, &data).unwrap();
        let mut hsm =
            crate::hsm::Hsm::new(crate::hsm::TieringPolicy::HeatWeighted);
        let plan = vec![crate::hsm::Migration {
            obj,
            from: DeviceKind::Ssd,
            to: DeviceKind::Nvram,
        }];
        let _ = c.fdmi.drain();
        let t = c.migrate_with(&mut hsm, &plan).unwrap();
        assert!(t > 0.0);
        let recs = c.fdmi.drain();
        assert!(
            recs.iter().any(|r| matches!(
                r,
                fdmi::FdmiRecord::ObjectMigrated {
                    obj: o,
                    from_tier: 2,
                    to_tier: 1,
                    ..
                } if *o == obj
            )),
            "migration path must publish ObjectMigrated: {recs:?}"
        );
        let back = c.read_object(&obj, 0, data.len() as u64).unwrap();
        assert_eq!(back, data);
        assert_eq!(
            c.store.object(obj).unwrap().layout.tier(),
            DeviceKind::Nvram
        );
    }

    #[test]
    fn repair_with_restores_redundancy_and_stamps_ha() {
        use crate::cluster::failure::{FailureEvent, FailureKind};
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![9u8; 2 * 4 * 65536];
        c.write_object(&obj, 0, &data).unwrap();
        let dev =
            c.store.object(obj).unwrap().placement(0, 1).unwrap().device;
        c.store.cluster.fail_device(dev);
        let at = c.now;
        c.store.ha.observe(
            FailureEvent { at, kind: FailureKind::Device(dev) },
            |_| Some(0),
        );
        let (bytes, t) = c.repair_with(&[obj], dev).unwrap();
        assert!(bytes > 0);
        assert!(t >= at);
        assert!(
            c.store.ha.repairing().is_empty(),
            "repair_done stamped through the recovery plane"
        );
        assert_eq!(c.store.ha.repair_log.len(), 1);
        let (d, from, to) = c.store.ha.repair_log[0];
        assert_eq!(d, dev);
        assert_eq!(from, at);
        assert_eq!(to, t, "repair_done carries the group wait_all completion");
        assert!(!c.store.cluster.devices[dev].failed, "device replaced");
        let back = c.read_object(&obj, 0, data.len() as u64).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn drain_with_moves_units_off_live_device_and_stamps_ha() {
        use crate::cluster::failure::{FailureEvent, FailureKind};
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![4u8; 2 * 4 * 65536];
        c.write_object(&obj, 0, &data).unwrap();
        let dev = c.store.object(obj).unwrap().placement(0, 0).unwrap().device;
        // three transients on one device inside the window: the HA
        // subsystem decides a proactive drain…
        let mut action = crate::mero::ha::RepairAction::None;
        for i in 0..3u32 {
            action = c.store.ha.observe(
                FailureEvent {
                    at: i as f64,
                    kind: FailureKind::Transient(dev),
                },
                |_| Some(0),
            );
        }
        assert_eq!(action, crate::mero::ha::RepairAction::ProactiveDrain(dev));
        // …and the recovery plane executes it as a session
        c.now = 3.0;
        let (bytes, t) = c.drain_with(&[obj], dev).unwrap();
        assert!(bytes > 0, "the device held units to move");
        assert!(t > 3.0, "the drain takes real virtual time");
        assert!(
            c.store
                .object(obj)
                .unwrap()
                .placed_units()
                .all(|u| u.device != dev),
            "no unit remains on the drained device"
        );
        assert!(!c.store.cluster.devices[dev].failed, "device stays in service");
        assert!(c.store.ha.repairing().is_empty(), "drain stamped as done");
        assert_eq!(c.store.ha.repair_log.len(), 1);
        let (d, from, to) = c.store.ha.repair_log[0];
        assert_eq!(d, dev);
        assert_eq!(from, 2.0, "engaged at the deciding transient");
        assert_eq!(to, t, "completed at the drain's frontier");
        // redundancy is intact: the drained device can now hard-fail
        // with nothing to rebuild from it, and bytes survive
        c.store.cluster.fail_device(dev);
        let back = c.read_object(&obj, 0, data.len() as u64).unwrap();
        assert_eq!(back, data, "bytes survive the drained device's failure");
    }

    #[test]
    fn feed_consumer_aborts_and_restarts_on_refailure_mid_repair() {
        use crate::cluster::failure::{FailureEvent, FailureKind};
        let mut c = client();
        let mut objs = Vec::new();
        let mut datas = Vec::new();
        for i in 0..4u64 {
            let o = c.create_object(4096).unwrap();
            let mut d = vec![0u8; 2 * 4 * 65536];
            crate::sim::rng::SimRng::new(700 + i).fill_bytes(&mut d);
            c.write_object(&o, 0, &d).unwrap();
            objs.push(o);
            datas.push(d);
        }
        let dev = c.store.object(objs[0]).unwrap().placement(0, 0).unwrap().device;
        // the device fails, then RE-fails inside the repair's in-flight
        // window (the repair dispatched at c.now completes well after
        // 1.5), then a transient lands in the restarted repair's window
        let mut feed = FailureSchedule::scripted(vec![
            FailureEvent { at: 1.0, kind: FailureKind::Device(dev) },
            FailureEvent { at: 1.5, kind: FailureKind::Device(dev) },
            FailureEvent { at: 1.8, kind: FailureKind::Transient(dev) },
        ]);
        c.now = 2.0;
        let outcomes = c.consume_failure_feed(&mut feed, &objs);
        assert_eq!(outcomes.len(), 3);
        assert_eq!(
            outcomes[0].verdict,
            RecoveryVerdict::AbortedByRefailure { refailed_at: 1.5 },
            "the first repair's stamp was retracted"
        );
        assert_eq!(outcomes[1].verdict, RecoveryVerdict::Recovered);
        assert_eq!(
            outcomes[2].verdict,
            RecoveryVerdict::TransientRetried {
                attempts: 2,
                resolved_at: 1.8 + 3.0 * TRANSIENT_RETRY_BACKOFF,
            },
            "a transient inside the in-flight window retries twice"
        );
        assert_eq!(c.store.ha.repairs_aborted, 1, "the restart was counted");
        assert_eq!(c.store.ha.repairs_started, 2);
        assert_eq!(
            c.store.ha.repair_log.len(),
            1,
            "exactly one completed repair survives — no double-count"
        );
        assert!(c.store.ha.repairing().is_empty());
        assert!(!c.store.cluster.devices[dev].failed, "device back in service");
        for (o, d) in objs.iter().zip(datas.iter()) {
            assert_eq!(&c.read_object(o, 0, d.len() as u64).unwrap(), d);
        }
    }

    #[test]
    fn feed_consumer_escalates_preempted_drain_and_absorbs_the_hard_event() {
        use crate::cluster::failure::{FailureEvent, FailureKind};
        let mut c = client();
        let mut objs = Vec::new();
        let mut datas = Vec::new();
        for i in 0..4u64 {
            let o = c.create_object(4096).unwrap();
            let mut d = vec![0u8; 2 * 4 * 65536];
            crate::sim::rng::SimRng::new(800 + i).fill_bytes(&mut d);
            c.write_object(&o, 0, &d).unwrap();
            objs.push(o);
            datas.push(d);
        }
        let dev = c.store.object(objs[0]).unwrap().placement(0, 0).unwrap().device;
        // three transients decide a drain at 1.2 — but the device
        // hard-fails at 1.3, in the SAME due batch, so the strike is
        // applied before the drain runs: the drain must escalate to a
        // repair, and the hard event must be absorbed by it
        let mut feed = FailureSchedule::scripted(vec![
            FailureEvent { at: 1.0, kind: FailureKind::Transient(dev) },
            FailureEvent { at: 1.1, kind: FailureKind::Transient(dev) },
            FailureEvent { at: 1.2, kind: FailureKind::Transient(dev) },
            FailureEvent { at: 1.3, kind: FailureKind::Device(dev) },
        ]);
        c.now = 2.0;
        let outcomes = c.consume_failure_feed(&mut feed, &objs);
        assert_eq!(outcomes.len(), 4);
        assert!(matches!(
            outcomes[0].verdict,
            RecoveryVerdict::TransientRetried { attempts: 1, .. }
        ));
        assert_eq!(outcomes[2].verdict, RecoveryVerdict::EscalatedToRepair);
        assert!(
            outcomes[2].bytes > 0,
            "the escalated repair rebuilt the failed drain source"
        );
        assert_eq!(
            outcomes[3].verdict,
            RecoveryVerdict::AbsorbedByEscalation,
            "the source's own hard event runs no second session"
        );
        assert_eq!(outcomes[3].action, RepairAction::None);
        assert_eq!(c.store.ha.repair_log.len(), 1, "ONE engagement, no double-count");
        assert_eq!(c.store.ha.repairs_started, 1);
        assert_eq!(c.store.ha.repairs_aborted, 0);
        assert!(!c.store.cluster.devices[dev].failed, "device back in service");
        for (o, d) in objs.iter().zip(datas.iter()) {
            assert_eq!(&c.read_object(o, 0, d.len() as u64).unwrap(), d);
        }
    }

    #[test]
    fn storm_beyond_parity_surfaces_typed_data_loss_not_a_panic() {
        use crate::cluster::failure::FailureSchedule;
        use crate::error::SageError;
        let mut c = client();
        let ssd_obj = c.create_object(4096).unwrap();
        let ssd_data = vec![6u8; 2 * 4 * 65536];
        c.write_object(&ssd_obj, 0, &ssd_data).unwrap();
        let hdd_obj = c
            .create_object_with(
                4096,
                crate::mero::Layout::Raid {
                    data: 4,
                    parity: 1,
                    unit: 65536,
                    tier: DeviceKind::Hdd,
                },
            )
            .unwrap();
        let hdd_data = vec![7u8; 2 * 4 * 65536];
        c.write_object(&hdd_obj, 0, &hdd_data).unwrap();
        let objs = vec![ssd_obj, hdd_obj];
        // a whole-tier storm: every SSD hard-fails within half a second
        // — far beyond the 4+1 layout's single-loss parity tolerance
        let ssds = c
            .store
            .cluster
            .devices_where(|d| d.profile.kind == DeviceKind::Ssd);
        let mut rng = crate::sim::rng::SimRng::new(77);
        let mut feed = FailureSchedule::storm(&ssds, 1.0, 0.5, &mut rng);
        c.now = 2.0;
        let outcomes = c.consume_failure_feed(&mut feed, &objs);
        assert_eq!(outcomes.len(), ssds.len());
        let losses: Vec<_> = outcomes
            .iter()
            .filter_map(|o| match &o.verdict {
                RecoveryVerdict::DataLoss { objects } => Some(objects),
                _ => None,
            })
            .collect();
        assert!(!losses.is_empty(), "the verdict is typed data loss");
        for lost in &losses {
            assert!(lost.contains(&ssd_obj), "the striped victim is named");
            assert!(!lost.contains(&hdd_obj), "the other tier is not");
        }
        assert!(
            outcomes.iter().all(|o| o.verdict != RecoveryVerdict::Recovered),
            "nothing pretended to recover past parity tolerance"
        );
        // reads of the victim keep erroring — no silent corruption…
        assert!(matches!(
            c.read_object(&ssd_obj, 0, ssd_data.len() as u64),
            Err(SageError::Unavailable(_))
        ));
        // …and the unaffected tier is untouched
        assert_eq!(
            c.read_object(&hdd_obj, 0, hdd_data.len() as u64).unwrap(),
            hdd_data
        );
    }

    #[test]
    fn expand_pool_attaches_rebalances_and_preserves_bytes() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![5u8; 4 * 4 * 65536];
        c.write_object(&obj, 0, &data).unwrap();
        let src = c.store.object(obj).unwrap().placement(0, 0).unwrap().device;
        let prof = c.store.cluster.devices[src].profile.clone();
        let (dev, bytes, t) = c.expand_pool(1, prof, &[obj]).unwrap();
        assert!(bytes > 0, "the newcomer attracted units");
        assert!(t > 0.0);
        assert!(
            c.store.pools.devices(DeviceKind::Ssd).contains(&dev),
            "the device joined its tier pool"
        );
        assert!(
            c.store
                .object(obj)
                .unwrap()
                .placed_units()
                .any(|u| u.device == dev),
            "placements moved onto the new capacity"
        );
        assert_eq!(c.read_object(&obj, 0, data.len() as u64).unwrap(), data);
        // attaching to a nonsense node is a typed error
        let prof2 = c.store.cluster.devices[src].profile.clone();
        assert!(c.expand_pool(usize::MAX, prof2, &[obj]).is_err());
    }

    #[test]
    fn addb_collects_telemetry() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        c.write_object(&obj, 0, &vec![1u8; 4 * 65536]).unwrap();
        let report = c.addb.summary();
        assert!(report.iter().any(|(k, _)| k.contains("obj_write_bytes")));
    }

    // ---- recovery plane: converted panic sites (ISSUE 9) ----
    // `consume_event` used to unwrap the overlap-table entry and index
    // `out` directly; both now surface `SageError::Recovery`. These
    // pin the typed error paths — the plane must NEVER panic.

    #[test]
    fn dangling_outcome_index_is_a_typed_recovery_error() {
        let mut c = client();
        let n_devs = c.store.cluster.devices.len();
        let nodes: Vec<Option<usize>> =
            (0..n_devs).map(|d| c.store.cluster.node_of(d)).collect();
        // poison the overlap table: device 0's last recovery claims an
        // outcome slot that does not exist in `out`
        let mut last = std::collections::BTreeMap::new();
        last.insert(
            0usize,
            LastRecovery {
                outcome: 99,
                completed_at: 1e9,
                escalated: false,
            },
        );
        let mut out = Vec::new();
        let event = FailureEvent {
            at: 1.0,
            kind: FailureKind::Device(0),
        };
        let err = c
            .consume_event(event, &[], &nodes, &mut last, &mut out)
            .unwrap_err();
        assert!(matches!(err, SageError::Recovery(_)));
        assert!(
            err.to_string().contains("dangling outcome index 99"),
            "error names the bad slot: {err}"
        );
        assert!(out.is_empty(), "no outcome was fabricated mid-error");
    }

    #[test]
    fn internal_recovery_error_becomes_failed_outcome() {
        // the feed consumer converts a bookkeeping error into a
        // consumed, accounted outcome with a Failed verdict
        let event = FailureEvent {
            at: 2.0,
            kind: FailureKind::Device(3),
        };
        let e = SageError::Recovery(
            "overlap table lost device 3 mid-pass".to_string(),
        );
        let o = Client::failed_outcome(event, &e);
        assert_eq!(o.verdict, RecoveryVerdict::Failed);
        assert_eq!(o.action, RepairAction::None);
        assert_eq!(o.bytes, 0);
        assert!(o.completed_at.is_none());
        let msg = o.error.expect("error text is preserved");
        assert!(
            msg.contains("recovery-plane bookkeeping error"),
            "typed Display prefix survives: {msg}"
        );
        assert!(msg.contains("overlap table lost device 3"));
    }
}
