//! Clovis: the transactional storage API on top of Mero (§3.2.2).
//!
//! * access interface — objects, indices, containers, layouts,
//!   transactions ([`Client`] methods; op lifecycle in [`ops`])
//! * function shipping — [`fshipping`] (§3.2.1): run computations on
//!   the storage nodes where the data lives
//! * management interface — [`addb`] telemetry and the [`fdmi`]
//!   extension/plugin interface
//!
//! [`Client`] is what applications and the high-level HPC interfaces
//! (PGAS I/O, MPI streams, HDF5/pNFS gateways) link against.

pub mod addb;
pub mod fdmi;
pub mod fshipping;
pub mod ops;

use crate::config::Testbed;
use crate::error::Result;
use crate::mero::dtm::TxId;
use crate::mero::{ContainerId, IndexId, Layout, MeroStore, ObjectId};
use crate::runtime::Executor;
use crate::sim::clock::SimTime;
use crate::sim::device::DeviceKind;

pub use fshipping::{FnOutput, FunctionKind, ShipResult};

/// A Clovis client handle: the entry point of the SAGE storage API.
pub struct Client {
    pub store: MeroStore,
    /// PJRT executor for shipped functions and SNS parity; `None` runs
    /// CPU fallbacks (identical results, no kernel offload).
    pub exec: Option<Executor>,
    pub addb: addb::Addb,
    pub fdmi: fdmi::FdmiBus,
    /// Client-local virtual clock (single-client convenience; rank-
    /// parallel workloads keep their own `RankClocks` and use the
    /// `*_at` variants).
    pub now: SimTime,
}

impl Client {
    /// Client over a simulated testbed, no kernel offload.
    pub fn new_sim(testbed: Testbed) -> Client {
        Client {
            store: MeroStore::new(testbed.build_cluster()),
            exec: None,
            addb: addb::Addb::new(4096),
            fdmi: fdmi::FdmiBus::new(),
            now: 0.0,
        }
    }

    /// Client with the PJRT executor attached (loads `artifacts/`).
    pub fn new_with_runtime(testbed: Testbed) -> Result<Client> {
        let mut c = Client::new_sim(testbed);
        c.exec = Some(Executor::load_default()?);
        Ok(c)
    }

    // ------------------------------------------------------------ objects

    /// Create an object with the default layout.
    pub fn create_object(&mut self, block_size: u64) -> Result<ObjectId> {
        self.create_object_with(block_size, Layout::default())
    }

    /// Create an object with an explicit layout.
    pub fn create_object_with(
        &mut self,
        block_size: u64,
        layout: Layout,
    ) -> Result<ObjectId> {
        let id = self.store.create_object(block_size, layout)?;
        self.addb.record(self.now, "clovis", "obj_create", 1.0);
        self.fdmi.emit(fdmi::FdmiRecord::ObjectCreated { obj: id, at: self.now });
        Ok(id)
    }

    /// Write (real bytes), advancing the client clock.
    pub fn write_object(
        &mut self,
        obj: &ObjectId,
        offset: u64,
        data: &[u8],
    ) -> Result<SimTime> {
        let t = self
            .store
            .write_object(*obj, offset, data, self.now, self.exec.as_ref())?;
        self.addb
            .record(self.now, "clovis", "obj_write_bytes", data.len() as f64);
        self.fdmi.emit(fdmi::FdmiRecord::ObjectWritten {
            obj: *obj,
            offset,
            len: data.len() as u64,
            at: self.now,
        });
        self.now = t;
        Ok(t)
    }

    /// Read, advancing the client clock.
    pub fn read_object(
        &mut self,
        obj: &ObjectId,
        offset: u64,
        len: u64,
    ) -> Result<Vec<u8>> {
        let (data, t) = self.store.read_object(*obj, offset, len, self.now)?;
        self.addb.record(self.now, "clovis", "obj_read_bytes", len as f64);
        self.fdmi.emit(fdmi::FdmiRecord::ObjectRead {
            obj: *obj,
            offset,
            len,
            at: self.now,
        });
        self.now = t;
        Ok(data)
    }

    /// Delete an object at end of life.
    pub fn delete_object(&mut self, obj: ObjectId) -> Result<()> {
        self.store.delete_object(obj)?;
        self.fdmi.emit(fdmi::FdmiRecord::ObjectDeleted { obj, at: self.now });
        Ok(())
    }

    // ------------------------------------------------------------ indices

    /// Create a KV index.
    pub fn create_index(&mut self) -> IndexId {
        self.store.create_index()
    }

    /// Batched PUT on an index.
    pub fn idx_put(
        &mut self,
        idx: IndexId,
        records: Vec<(Vec<u8>, Vec<u8>)>,
    ) -> Result<()> {
        let n = records.len() as f64;
        self.store.index_mut(idx)?.put_batch(records);
        self.addb.record(self.now, "clovis", "idx_put", n);
        Ok(())
    }

    /// Batched GET on an index.
    pub fn idx_get(
        &mut self,
        idx: IndexId,
        keys: &[Vec<u8>],
    ) -> Result<Vec<Option<Vec<u8>>>> {
        Ok(self.store.index(idx)?.get_batch(keys))
    }

    /// Batched DEL on an index.
    pub fn idx_del(&mut self, idx: IndexId, keys: &[Vec<u8>]) -> Result<Vec<bool>> {
        Ok(self.store.index_mut(idx)?.del_batch(keys))
    }

    /// Batched NEXT on an index.
    pub fn idx_next(
        &mut self,
        idx: IndexId,
        keys: &[Vec<u8>],
    ) -> Result<Vec<Option<(Vec<u8>, Vec<u8>)>>> {
        Ok(self.store.index(idx)?.next_batch(keys))
    }

    // -------------------------------------------------------- containers

    /// Create a container with a tier hint.
    pub fn create_container(
        &mut self,
        label: &str,
        tier: Option<DeviceKind>,
    ) -> ContainerId {
        self.store.create_container(label, tier)
    }

    /// Add an object to a container.
    pub fn container_add(&mut self, c: ContainerId, obj: ObjectId) -> Result<()> {
        self.store.container_mut(c)?.add(obj);
        Ok(())
    }

    // ------------------------------------------------------ transactions

    /// Begin a distributed transaction.
    pub fn tx_begin(&mut self) -> TxId {
        self.store.dtm.begin()
    }

    /// Transactional KV write (buffered until commit).
    pub fn tx_put(&mut self, tx: TxId, key: Vec<u8>, val: Vec<u8>) -> Result<()> {
        self.store.dtm.write(tx, key, val)
    }

    /// Transactional read (snapshot + read-your-writes).
    pub fn tx_get(&mut self, tx: TxId, key: &[u8]) -> Result<Option<Vec<u8>>> {
        self.store.dtm.read(tx, key)
    }

    /// Commit; advances the clock by the (group-amortized) log force.
    pub fn tx_commit(&mut self, tx: TxId) -> Result<SimTime> {
        let t = self.store.dtm.commit(tx, self.now)?;
        self.now = t;
        self.addb.record(self.now, "dtm", "commit", 1.0);
        Ok(t)
    }

    /// Abort a transaction.
    pub fn tx_abort(&mut self, tx: TxId) -> Result<()> {
        self.store.dtm.abort(tx)
    }

    // -------------------------------------------------- function shipping

    /// Ship a function to the storage node holding `obj` (§3.2.1):
    /// the computation runs where the data lives.
    pub fn ship_to_object(
        &mut self,
        obj: ObjectId,
        func: FunctionKind,
    ) -> Result<ShipResult> {
        let r = fshipping::ship_to_object(self, obj, func)?;
        self.now = r.t_done;
        Ok(r)
    }

    /// One-shot operation: ship a function to every object in a
    /// container (§3.2.1 Containers).
    pub fn ship_to_container(
        &mut self,
        container: ContainerId,
        func: FunctionKind,
    ) -> Result<Vec<ShipResult>> {
        let objs = self.store.container_objects(container)?;
        let mut out = Vec::with_capacity(objs.len());
        for obj in objs {
            out.push(self.ship_to_object(obj, func.clone())?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn client() -> Client {
        Client::new_sim(Testbed::sage_prototype())
    }

    #[test]
    fn object_roundtrip_via_client() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![7u8; 4 * 65536]; // one full default stripe
        let t = c.write_object(&obj, 0, &data).unwrap();
        assert!(t > 0.0);
        let back = c.read_object(&obj, 0, data.len() as u64).unwrap();
        assert_eq!(back, data);
        assert!(c.now >= t);
    }

    #[test]
    fn index_api() {
        let mut c = client();
        let idx = c.create_index();
        c.idx_put(idx, vec![(b"k1".to_vec(), b"v1".to_vec())]).unwrap();
        let got = c.idx_get(idx, &[b"k1".to_vec(), b"nope".to_vec()]).unwrap();
        assert_eq!(got[0], Some(b"v1".to_vec()));
        assert_eq!(got[1], None);
    }

    #[test]
    fn transactions_atomic_via_client() {
        let mut c = client();
        let tx = c.tx_begin();
        c.tx_put(tx, b"a".to_vec(), b"1".to_vec()).unwrap();
        assert_eq!(c.tx_get(tx, b"a").unwrap(), Some(b"1".to_vec()));
        c.tx_commit(tx).unwrap();
        assert_eq!(c.store.dtm.get(b"a"), Some(&b"1".to_vec()));
    }

    #[test]
    fn container_grouping() {
        let mut c = client();
        let cont = c.create_container("hot", Some(DeviceKind::Nvram));
        let o1 = c.create_object(4096).unwrap();
        let o2 = c.create_object(4096).unwrap();
        c.container_add(cont, o1).unwrap();
        c.container_add(cont, o2).unwrap();
        assert_eq!(c.store.container_objects(cont).unwrap().len(), 2);
    }

    #[test]
    fn addb_collects_telemetry() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        c.write_object(&obj, 0, &vec![1u8; 4 * 65536]).unwrap();
        let report = c.addb.summary();
        assert!(report.iter().any(|(k, _)| k.contains("obj_write_bytes")));
    }
}
