//! Clovis sessions: the op-builder face of the access interface
//! (§3.1/§3.2.2 — *one* asynchronous operation interface for every
//! operation kind).
//!
//! The SAGE papers define Clovis as a single asynchronous op state
//! machine: applications create op objects, chain dependencies, launch
//! the batch, and wait — object I/O, key-value access, transactions
//! and function shipping all flow through the same interface, and the
//! POSIX/HDF5/S3 gateways plus the HSM/recovery planes are built on
//! it. [`Session`] is that interface:
//!
//! * [`Client::session`](crate::clovis::Client::session) yields a
//!   builder over ONE scheduler-backed
//!   [`OpGroup`](crate::clovis::ops::OpGroup);
//! * every staging method ([`Session::write`], [`Session::read`],
//!   [`Session::idx_put`], [`Session::tx`], [`Session::ship`],
//!   [`Session::migrate`], [`Session::repair`], [`Session::drain`], …)
//!   returns an [`OpHandle`];
//! * [`Session::after`]`(op, pred)` declares a dependency edge: `op`
//!   dispatches at `pred`'s completion frontier — NOT at a global
//!   barrier, so unrelated ops still overlap;
//! * [`Session::run`] executes the batch on the group's sharded
//!   per-device scheduler and returns a [`SessionReport`] with per-op
//!   outputs, per-op completion times, and the group `wait_all` time.
//!
//! Because all ops of a session share one set of per-device shards, a
//! mixed batch — in-storage compute ([`Session::ship`]) next to a
//! checkpoint write next to a background migration — genuinely
//! overlaps on the device queues (the paper's headline scenario;
//! measured by `benches/ablate_session.rs`). A session with a single
//! op is byte- and time-identical to the matching legacy `Client`
//! entry point, and a fully `.after`-chained session is identical to
//! the same calls made sequentially (`tests/prop_session.rs`).
//!
//! The sharing is QoS-governed (§3.2.1 repair throttling): every op
//! dispatches under its kind's
//! [`TrafficClass`](crate::sim::sched::TrafficClass)
//! ([`Session::repair`]/[`Session::drain`] as `Repair`,
//! [`Session::migrate`] as `Migration`, everything else `Foreground`),
//! and the group scheduler enforces the cluster's
//! [`QosConfig`](crate::sim::sched::QosConfig) bandwidth split per
//! shard — so a rebuild racing a checkpoint is capped at its
//! configured share instead of starving the application
//! (`benches/ablate_qos.rs` measures the foreground win;
//! [`SessionReport::qos`] carries the per-class frontier table).
//!
//! KVS and DTM ops carry no device I/O in this model (metadata and the
//! NVRAM log force are not pool devices), but their completion stamps
//! ride the same group: a transaction op completes one `LOG_FORCE`
//! after its dispatch frontier, so two independent tx ops in one
//! session group-commit concurrently instead of serializing through
//! the client clock.

use crate::clovis::fdmi::FdmiRecord;
use crate::clovis::fshipping::{self, FunctionKind, ShipResult};
use crate::clovis::ops::{Extent, OpGroup, OpKind};
use crate::clovis::Client;
use crate::error::{Result, SageError};
use crate::hsm::{Hsm, Migration};
use crate::mero::dtm::TxId;
use crate::mero::pool::CongestionView;
use crate::mero::{IndexId, ObjectId};
use crate::sim::clock::SimTime;
use crate::sim::sched::{QosShardReport, TenantId, TenantShardReport, DEFAULT_TENANT};

/// Handle to one staged session op. Redeem against
/// [`SessionReport::outputs`] / [`SessionReport::completed`] after
/// [`Session::run`], or feed to [`Session::after`] to chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpHandle(usize);

impl OpHandle {
    /// Index of this op in the session's staging order (also its index
    /// into the report's `outputs`/`completed` vectors).
    pub fn index(&self) -> usize {
        self.0
    }
}

/// Per-op result returned by [`Session::run`], in staging order.
#[derive(Debug)]
pub enum OpOutput {
    /// Object write completed (completion time in `completed`).
    Write,
    /// Vectored read: one buffer per requested extent.
    Read(Vec<Vec<u8>>),
    /// In-place read completed (the staged `dst` buffer is filled).
    ReadInto,
    /// Index PUT applied.
    IdxPut,
    /// Index GET results (None per missing key).
    IdxGet(Vec<Option<Vec<u8>>>),
    /// Index DEL results (per-key existence).
    IdxDel(Vec<bool>),
    /// Index NEXT results.
    IdxNext(Vec<Option<(Vec<u8>, Vec<u8>)>>),
    /// Transaction committed under this id.
    Tx(TxId),
    /// Function-shipping outcome.
    Ship(ShipResult),
    /// Migration batch completed.
    Migrate,
    /// SNS repair completed; bytes rebuilt onto replacement homes.
    Repair { bytes: u64 },
    /// Proactive drain completed; bytes moved off the degrading device.
    Drain { bytes: u64 },
    /// Rebalance completed; bytes moved onto the freshly-attached
    /// device (elastic pool membership).
    Rebalance { bytes: u64 },
}

/// Outcome of [`Session::run`]: per-op results plus the group
/// completion, and the scheduler's dispatch statistics.
#[derive(Debug)]
pub struct SessionReport {
    /// One output per staged op, in staging order (`OpHandle::index`).
    pub outputs: Vec<OpOutput>,
    /// Per-op completion times, in staging order.
    pub completed: Vec<SimTime>,
    /// Group completion: `OpGroup::wait_all_from(session start)` — the
    /// max over per-device completion frontiers and op finish times,
    /// floored at the clock the session was launched at.
    pub completed_at: SimTime,
    /// Device accounting calls the batch issued (coalesced runs).
    pub io_calls: u64,
    /// Logical unit I/Os the batch dispatched.
    pub ios: u64,
    /// `(device, completion frontier)` per shard the batch touched.
    pub frontiers: Vec<(usize, SimTime)>,
    /// The QoS plane's per-class frontier table: one row per shard the
    /// batch drained work on — per-class busy time, frontiers, and the
    /// shard's inherited base (OPERATIONS.md §Reading the per-class
    /// frontier tables). Repair/drain ops dispatch as
    /// `TrafficClass::Repair`, migrations as
    /// `TrafficClass::Migration`; the cluster's
    /// [`QosConfig`](crate::sim::sched::QosConfig) caps their
    /// per-device share against the session's foreground ops.
    pub qos: Vec<QosShardReport>,
    /// The multi-tenant plane's per-tenant frontier table: one row per
    /// shard with `(tenant, class)` lanes drained during this session
    /// (OPERATIONS.md §Reading the per-tenant frontier tables). Empty
    /// unless two or more tenants are registered on the cluster
    /// ([`Client::register_tenant`](crate::clovis::Client::register_tenant)).
    pub tenants: Vec<TenantShardReport>,
}

impl SessionReport {
    /// Borrow the output of one op.
    pub fn output(&self, h: OpHandle) -> &OpOutput {
        &self.outputs[h.0]
    }

    /// Completion time of one op.
    pub fn completed_at_op(&self, h: OpHandle) -> SimTime {
        self.completed[h.0]
    }
}

/// One staged (not yet executed) operation.
enum StagedOp<'d> {
    Write { obj: ObjectId, extents: Vec<(u64, &'d [u8])> },
    WriteOwned { obj: ObjectId, extents: Vec<(u64, Vec<u8>)> },
    Read { obj: ObjectId, extents: Vec<Extent> },
    ReadInto { obj: ObjectId, offset: u64, dst: &'d mut [u8] },
    IdxPut { idx: IndexId, records: Vec<(Vec<u8>, Vec<u8>)> },
    IdxGet { idx: IndexId, keys: Vec<Vec<u8>> },
    IdxDel { idx: IndexId, keys: Vec<Vec<u8>> },
    IdxNext { idx: IndexId, keys: Vec<Vec<u8>> },
    Tx { updates: Vec<(Vec<u8>, Vec<u8>)> },
    Ship { obj: ObjectId, func: FunctionKind },
    Migrate { hsm: &'d mut Hsm, plan: &'d [Migration] },
    Repair { objects: Vec<ObjectId>, dev: usize },
    Drain { objects: Vec<ObjectId>, dev: usize },
    Rebalance { objects: Vec<ObjectId>, dev: usize },
}

impl StagedOp<'_> {
    fn kind(&self) -> OpKind {
        match self {
            StagedOp::Write { .. } | StagedOp::WriteOwned { .. } => OpKind::ObjWrite,
            StagedOp::Read { .. } | StagedOp::ReadInto { .. } => OpKind::ObjRead,
            StagedOp::IdxPut { .. } => OpKind::IdxPut,
            StagedOp::IdxGet { .. } => OpKind::IdxGet,
            StagedOp::IdxDel { .. } => OpKind::IdxDel,
            StagedOp::IdxNext { .. } => OpKind::IdxNext,
            StagedOp::Tx { .. } => OpKind::Tx,
            StagedOp::Ship { .. } => OpKind::FnShip,
            StagedOp::Migrate { .. } => OpKind::Migrate,
            StagedOp::Repair { .. } => OpKind::Repair,
            StagedOp::Drain { .. } => OpKind::Drain,
            StagedOp::Rebalance { .. } => OpKind::Rebalance,
        }
    }
}

/// The Clovis op builder: stage ops, chain dependencies, run the batch
/// on one scheduler-backed op group. See the module docs.
pub struct Session<'c, 'd> {
    client: &'c mut Client,
    staged: Vec<StagedOp<'d>>,
    /// Predecessor indices per op (forward edges only).
    deps: Vec<Vec<usize>>,
    /// Tenant every submission of this session is stamped with
    /// (ISSUE 7 multi-tenant plane; admission-checked by
    /// [`Client::session_as`](crate::clovis::Client::session_as)).
    tenant: TenantId,
}

impl<'c, 'd> Session<'c, 'd> {
    pub(crate) fn new(client: &'c mut Client) -> Self {
        Session::for_tenant(client, DEFAULT_TENANT)
    }

    pub(crate) fn for_tenant(client: &'c mut Client, tenant: TenantId) -> Self {
        Session { client, staged: Vec::new(), deps: Vec::new(), tenant }
    }

    /// Tenant this session dispatches as.
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }

    fn stage(&mut self, op: StagedOp<'d>) -> OpHandle {
        self.staged.push(op);
        self.deps.push(Vec::new());
        OpHandle(self.staged.len() - 1)
    }

    /// Number of staged ops.
    pub fn len(&self) -> usize {
        self.staged.len()
    }

    /// True if no ops are staged ([`Session::run`] then completes at
    /// the client clock).
    pub fn is_empty(&self) -> bool {
        self.staged.is_empty()
    }

    /// Stage a vectored object write over borrowed extents.
    /// List-adjacent extents coalesce into one striped op before
    /// dispatch (bytes identical; merged partial stripes become full
    /// stripes and skip their RMW envelopes).
    pub fn write(
        &mut self,
        obj: &ObjectId,
        extents: &'d [(u64, &'d [u8])],
    ) -> OpHandle {
        self.stage(StagedOp::Write { obj: *obj, extents: extents.to_vec() })
    }

    /// Stage a vectored write of owned buffers (§Perf persist-by-move:
    /// each buffer becomes object block storage without a copy).
    pub fn write_owned(
        &mut self,
        obj: &ObjectId,
        extents: Vec<(u64, Vec<u8>)>,
    ) -> OpHandle {
        self.stage(StagedOp::WriteOwned { obj: *obj, extents })
    }

    /// Stage a vectored read; the output is one buffer per extent.
    /// List-adjacent extents coalesce into one striped read before
    /// dispatch (ROADMAP cross-op read coalescing): the merged buffer
    /// is sliced back per caller extent, so outputs are byte-identical
    /// and order-preserving while shared edge units are read once.
    pub fn read(&mut self, obj: &ObjectId, extents: &[Extent]) -> OpHandle {
        self.stage(StagedOp::Read { obj: *obj, extents: extents.to_vec() })
    }

    /// Stage a read of `dst.len()` bytes at `offset` straight into a
    /// caller buffer (§Perf: no per-read allocation).
    pub fn read_into(
        &mut self,
        obj: &ObjectId,
        offset: u64,
        dst: &'d mut [u8],
    ) -> OpHandle {
        self.stage(StagedOp::ReadInto { obj: *obj, offset, dst })
    }

    /// Stage a batched PUT on a KV index.
    pub fn idx_put(
        &mut self,
        idx: IndexId,
        records: Vec<(Vec<u8>, Vec<u8>)>,
    ) -> OpHandle {
        self.stage(StagedOp::IdxPut { idx, records })
    }

    /// Stage a batched GET on a KV index.
    pub fn idx_get(&mut self, idx: IndexId, keys: Vec<Vec<u8>>) -> OpHandle {
        self.stage(StagedOp::IdxGet { idx, keys })
    }

    /// Stage a batched DEL on a KV index.
    pub fn idx_del(&mut self, idx: IndexId, keys: Vec<Vec<u8>>) -> OpHandle {
        self.stage(StagedOp::IdxDel { idx, keys })
    }

    /// Stage a batched NEXT on a KV index.
    pub fn idx_next(&mut self, idx: IndexId, keys: Vec<Vec<u8>>) -> OpHandle {
        self.stage(StagedOp::IdxNext { idx, keys })
    }

    /// Stage a whole transaction (begin + buffered writes + epoch group
    /// commit) as one op; it completes one log force after its dispatch
    /// frontier, exactly like the legacy `tx_begin`/`tx_put`/`tx_commit`
    /// sequence — but independent tx ops of one session group-commit
    /// concurrently.
    pub fn tx(&mut self, updates: Vec<(Vec<u8>, Vec<u8>)>) -> OpHandle {
        self.stage(StagedOp::Tx { updates })
    }

    /// Stage a function shipment to the storage node holding `obj`
    /// (§3.2.1 in-storage compute): the node-local object read rides
    /// the session's shards, so shipped compute shares device queues
    /// with foreground I/O and recovery traffic.
    pub fn ship(&mut self, obj: ObjectId, func: FunctionKind) -> OpHandle {
        self.stage(StagedOp::Ship { obj, func })
    }

    /// Stage an HSM migration batch (two-phase: reads up front,
    /// rewrites at each object's read frontier) on the session's
    /// shards. FDMI `ObjectMigrated` records are published for exactly
    /// the objects that really moved.
    pub fn migrate(&mut self, hsm: &'d mut Hsm, plan: &'d [Migration]) -> OpHandle {
        self.stage(StagedOp::Migrate { hsm, plan })
    }

    /// Stage an SNS repair of failed device `dev` over `objects`
    /// (two-phase: survivor reads across all objects, rebuild writes
    /// at each unit's reconstruction frontier). On completion the
    /// device returns to service empty and the HA subsystem's
    /// `repair_done` is stamped with the repair's completion frontier.
    pub fn repair(&mut self, objects: &[ObjectId], dev: usize) -> OpHandle {
        self.stage(StagedOp::Repair { objects: objects.to_vec(), dev })
    }

    /// Stage a proactive drain of DEGRADING (still-live) device `dev`:
    /// every unit homed on it across `objects` is read off the device
    /// and rewritten elsewhere at its own read frontier — the
    /// `RepairAction::ProactiveDrain` executor (no reconstruction
    /// needed; the device still serves reads). The drain interval is
    /// stamped into the HA repair log; the device stays in service.
    pub fn drain(&mut self, objects: &[ObjectId], dev: usize) -> OpHandle {
        self.stage(StagedOp::Drain { objects: objects.to_vec(), dev })
    }

    /// Stage a rebalance onto freshly-attached device `dev` (elastic
    /// pool membership — the inverse of [`Session::drain`]): units of
    /// `objects` move onto the newcomer while each move improves the
    /// pool's balance, as Migration-class traffic capped against the
    /// session's foreground ops. Placements of untouched objects are
    /// unchanged.
    pub fn rebalance(&mut self, objects: &[ObjectId], dev: usize) -> OpHandle {
        self.stage(StagedOp::Rebalance { objects: objects.to_vec(), dev })
    }

    /// Declare a dependency edge: `op` dispatches at `pred`'s
    /// completion frontier instead of the session start (deps gate
    /// dispatch, not the whole group — unrelated ops still overlap).
    /// `pred` must have been staged before `op`.
    pub fn after(&mut self, op: OpHandle, pred: OpHandle) -> Result<()> {
        if op.0 >= self.staged.len() || pred.0 >= self.staged.len() {
            return Err(SageError::Invalid(format!(
                "after({}, {}): unknown op handle",
                op.0, pred.0
            )));
        }
        if pred.0 >= op.0 {
            return Err(SageError::Invalid(format!(
                "after({}, {}): an op can only depend on earlier-staged ops",
                op.0, pred.0
            )));
        }
        if !self.deps[op.0].contains(&pred.0) {
            self.deps[op.0].push(pred.0);
        }
        Ok(())
    }

    /// Launch the batch: every op executes on the group's sharded
    /// per-device scheduler, dispatching at the max of the session
    /// start clock and its predecessors' completion frontiers. Returns
    /// per-op outputs and completion times plus the group `wait_all`
    /// completion (which also advances the client clock). A zero-op
    /// session completes at the current clock. On the first op error
    /// the op is marked FAILED and the error propagates (ops already
    /// executed keep their effects, exactly like sequential calls).
    pub fn run(self) -> Result<SessionReport> {
        let Session { client, staged, deps, tenant } = self;
        let now = client.now;
        // ISSUE 7: adopt the ONE cluster-wide scheduler. Take it out
        // of the client (no aliasing against `client.store` during
        // exec), sync the cluster's QoS split and tenant table (config
        // edits between sessions take effect exactly as they did with
        // private per-group schedulers), stamp this session's tenant,
        // and open a fresh scheduling epoch at the session clock —
        // shards idle at `now` behave like a fresh private scheduler
        // (bit-exact), busy shards contend. The scheduler is handed
        // back to the client on EVERY path below, error included.
        let mut sched = std::mem::take(&mut client.sched);
        sched.set_qos(client.store.cluster.qos);
        sched.set_tenants(client.store.cluster.tenants.clone());
        sched.set_tenant(tenant);
        // ISSUE 10: close the QoS→placement feedback loop. Sample the
        // cluster-wide scheduler's committed backlog (cross-epoch —
        // earlier sessions' frontiers included) at the session clock
        // and install it as this session's placement congestion view:
        // every `PoolSet::allocate` this session performs — new
        // writes, repair targets, drain re-homes — steers away from
        // deep-backlog shards. Back-to-back sessions find every
        // frontier at or behind `now` (empty view), so placement is
        // bit-identical to the no-feedback baseline. Cleared on
        // release below, on BOTH paths.
        let view = CongestionView::from_reports(&sched.qos_report_all(), now);
        client.store.pools.set_congestion(view);
        let mut group = OpGroup::adopt(sched, now);
        let ids: Vec<u64> = staged.iter().map(|op| group.add(op.kind())).collect();
        let mut completed = vec![now; staged.len()];
        let mut outputs = Vec::with_capacity(staged.len());
        let mut failure = group.launch_batch(now).err();
        if failure.is_none() {
            for (i, op) in staged.into_iter().enumerate() {
                let at = deps[i].iter().fold(now, |t, &p| t.max(completed[p]));
                // every submission of this op carries the op kind's class
                let class = op.kind().traffic_class();
                let prev = group.sched().set_class(class);
                let result = exec(client, &mut group, op, at);
                group.sched().set_class(prev);
                let step = match result {
                    Ok((out, t)) => group
                        .op_mut(ids[i])
                        .and_then(|o| o.complete(t))
                        .map(|()| (out, t)),
                    Err(e) => {
                        // best-effort FAILED stamp; the op error wins
                        let _ = group
                            .op_mut(ids[i])
                            .and_then(|o| o.fail(at, &e.to_string()));
                        Err(e)
                    }
                };
                match step {
                    Ok((out, t)) => {
                        completed[i] = t;
                        outputs.push(out);
                    }
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                }
            }
        }
        let outcome = match failure {
            None => group.wait_all_from(now),
            Some(e) => Err(e),
        };
        match outcome {
            Ok(completed_at) => {
                client.now = client.now.max(completed_at);
                let sched = group.sched_ref();
                let frontiers = sched.frontiers();
                let qos = sched.qos_report();
                let tenants = sched.tenant_report();
                // epoch-scoped counters: per-session stats on the
                // shared instance, same values the private scheduler
                // reported before
                let io_calls = sched.epoch_io_calls();
                let ios = sched.epoch_ios();
                client.sched = group.release();
                client.store.pools.clear_congestion();
                Ok(SessionReport {
                    outputs,
                    completed,
                    completed_at,
                    io_calls,
                    ios,
                    frontiers,
                    qos,
                    tenants,
                })
            }
            Err(e) => {
                // ops already executed keep their effects, exactly
                // like sequential calls — and the cluster scheduler
                // (with whatever frontiers this session committed)
                // survives for the next session
                client.sched = group.release();
                client.store.pools.clear_congestion();
                Err(e)
            }
        }
    }
}

/// Execute one staged op at dispatch time `at` on the group's shards.
/// Returns the op's output and completion time. Telemetry (ADDB/FDMI)
/// is batch-amortized per op, with the same records the legacy entry
/// points emit.
fn exec(
    client: &mut Client,
    group: &mut OpGroup,
    op: StagedOp<'_>,
    at: SimTime,
) -> Result<(OpOutput, SimTime)> {
    match op {
        StagedOp::Write { obj, extents } => {
            if extents.is_empty() {
                return Ok((OpOutput::Write, at));
            }
            let first_off = extents[0].0;
            let n_ops = extents.len();
            let io_before = group.sched_ref().io_calls();
            // cross-op coalescing: list-adjacent extents merge into one
            // op before striping (fewer RMW envelopes; bytes unchanged)
            let merged = super::coalesce_extents(&extents);
            let n_merged = merged.len();
            let mut total = 0u64;
            let mut t_op = at;
            for (off, data) in merged {
                let len = data.len() as u64;
                let t = match data {
                    super::Coalesced::Borrowed(d) => client.store.write_object_with(
                        obj,
                        off,
                        d,
                        at,
                        client.exec.as_ref(),
                        group.sched(),
                    )?,
                    super::Coalesced::Owned(v) => client.store.write_object_owned_with(
                        obj,
                        off,
                        v,
                        at,
                        client.exec.as_ref(),
                        group.sched(),
                    )?,
                };
                total += len;
                t_op = t_op.max(t);
            }
            write_telemetry(client, group, obj, first_off, n_ops, n_merged, total, io_before, at);
            Ok((OpOutput::Write, t_op))
        }

        StagedOp::WriteOwned { obj, extents } => {
            if extents.is_empty() {
                return Ok((OpOutput::Write, at));
            }
            let first_off = extents[0].0;
            let n_ops = extents.len();
            let io_before = group.sched_ref().io_calls();
            let merged = super::coalesce_owned_extents(extents);
            let n_merged = merged.len();
            let mut total = 0u64;
            let mut t_op = at;
            for (off, data) in merged {
                let len = data.len() as u64;
                let t = client.store.write_object_owned_with(
                    obj,
                    off,
                    data,
                    at,
                    client.exec.as_ref(),
                    group.sched(),
                )?;
                total += len;
                t_op = t_op.max(t);
            }
            write_telemetry(client, group, obj, first_off, n_ops, n_merged, total, io_before, at);
            Ok((OpOutput::Write, t_op))
        }

        StagedOp::Read { obj, extents } => {
            if extents.is_empty() {
                return Ok((OpOutput::Read(Vec::new()), at));
            }
            let io_before = group.sched_ref().io_calls();
            // cross-op read coalescing (ROADMAP): merge list-adjacent
            // extents into one striped read, then slice the merged
            // buffer back into one output per caller extent — shared
            // edge units are read once, bytes and order are unchanged
            let mut merged: Vec<(u64, Vec<u64>)> = Vec::new();
            for e in &extents {
                let adjacent = merged.last().is_some_and(|(off, lens)| {
                    let span: u64 = lens.iter().sum();
                    span > 0 && e.len > 0 && off + span == e.offset
                });
                match merged.last_mut() {
                    Some((_, lens)) if adjacent => lens.push(e.len),
                    _ => merged.push((e.offset, vec![e.len])),
                }
            }
            let n_merged = merged.len();
            let mut out: Vec<Vec<u8>> = Vec::with_capacity(extents.len());
            let mut total = 0u64;
            let mut t_op = at;
            for (off, lens) in merged {
                let span: u64 = lens.iter().sum();
                let (buf, t) =
                    client.store.read_object_with(obj, off, span, at, group.sched())?;
                t_op = t_op.max(t);
                total += span;
                if lens.len() == 1 {
                    out.push(buf);
                } else {
                    let mut cursor = 0usize;
                    for l in lens {
                        out.push(buf[cursor..cursor + l as usize].to_vec());
                        cursor += l as usize;
                    }
                }
            }
            client.addb.record(at, "clovis", "obj_readv_bytes", total as f64);
            client
                .addb
                .record(at, "clovis", "obj_readv_ops", extents.len() as f64);
            client
                .addb
                .record(at, "clovis", "obj_readv_merged_ops", n_merged as f64);
            client.addb.record(
                at,
                "clovis",
                "obj_readv_io_runs",
                (group.sched_ref().io_calls() - io_before) as f64,
            );
            client.fdmi.emit(FdmiRecord::ObjectRead {
                obj,
                offset: extents[0].offset,
                len: total,
                at,
            });
            Ok((OpOutput::Read(out), t_op))
        }

        StagedOp::ReadInto { obj, offset, dst } => {
            let len = dst.len() as u64;
            let t = client
                .store
                .read_object_into_with(obj, offset, dst, at, group.sched())?;
            client.addb.record(at, "clovis", "obj_read_bytes", len as f64);
            client
                .fdmi
                .emit(FdmiRecord::ObjectRead { obj, offset, len, at });
            Ok((OpOutput::ReadInto, t))
        }

        StagedOp::IdxPut { idx, records } => {
            let n = records.len() as f64;
            client.store.index_mut(idx)?.put_batch(records);
            client.addb.record(at, "clovis", "idx_put", n);
            Ok((OpOutput::IdxPut, at))
        }
        StagedOp::IdxGet { idx, keys } => {
            Ok((OpOutput::IdxGet(client.store.index(idx)?.get_batch(&keys)), at))
        }
        StagedOp::IdxDel { idx, keys } => Ok((
            OpOutput::IdxDel(client.store.index_mut(idx)?.del_batch(&keys)),
            at,
        )),
        StagedOp::IdxNext { idx, keys } => Ok((
            OpOutput::IdxNext(client.store.index(idx)?.next_batch(&keys)),
            at,
        )),

        StagedOp::Tx { updates } => {
            let tx = client.store.dtm.begin();
            for (k, v) in updates {
                client.store.dtm.write(tx, k, v)?;
            }
            let t = client.store.dtm.commit(tx, at)?;
            client.addb.record(t, "dtm", "commit", 1.0);
            Ok((OpOutput::Tx(tx), t))
        }

        StagedOp::Ship { obj, func } => {
            let r = fshipping::ship_to_object_with(client, obj, func, at, group.sched())?;
            let t = r.t_done;
            Ok((OpOutput::Ship(r), t))
        }

        StagedOp::Migrate { hsm, plan } => {
            if plan.is_empty() {
                return Ok((OpOutput::Migrate, at));
            }
            let io_before = group.sched_ref().io_calls();
            let bytes_before = hsm.bytes_moved;
            let r = hsm.migrate_with(&mut client.store, plan, at, group.sched());
            // objects migrated before a mid-plan failure really moved:
            // publish their records + telemetry either way, so FDMI
            // consumers never diverge from the store. `last_migrated`
            // is the HSM's own record of what completed.
            if !hsm.last_migrated().is_empty() {
                client.addb.record(
                    at,
                    "hsm",
                    "migrate_objects",
                    hsm.last_migrated().len() as f64,
                );
                client.addb.record(
                    at,
                    "hsm",
                    "migrate_bytes",
                    (hsm.bytes_moved - bytes_before) as f64,
                );
                client.addb.record(
                    at,
                    "hsm",
                    "migrate_io_runs",
                    (group.sched_ref().io_calls() - io_before) as f64,
                );
            }
            for m in hsm.last_migrated() {
                client.fdmi.emit(FdmiRecord::ObjectMigrated {
                    obj: m.obj,
                    from_tier: m.from.tier(),
                    to_tier: m.to.tier(),
                    at,
                });
            }
            let t = r?;
            Ok((OpOutput::Migrate, t))
        }

        StagedOp::Repair { objects, dev } => {
            let io_before = group.sched_ref().io_calls();
            let r = crate::mero::sns::repair_with(
                &mut client.store,
                &objects,
                dev,
                at,
                group.sched(),
            );
            let (bytes, t) = match r {
                Ok(v) => v,
                Err(e) => {
                    // a rebuild that errors out must not leave the
                    // device marked in-repair, or the HA subsystem
                    // suppresses every later failure event on it
                    client.store.ha.repair_aborted(dev);
                    return Err(e);
                }
            };
            // `repair_with`'s completion already covers every frontier
            // of the repair's OWN I/O (phase-B rebuild writes end after
            // the phase-A reads they wait on), so this is exactly the
            // legacy one-op group's `wait_all` — and in a mixed session
            // the repair_log stamp stays the repair's own completion,
            // not the whole session's frontier.
            client.store.cluster.replace_device(dev);
            client.store.ha.repair_done(dev, t);
            client.addb.record(at, "sns", "repair_bytes", bytes as f64);
            client.addb.record(
                at,
                "sns",
                "repair_io_runs",
                (group.sched_ref().io_calls() - io_before) as f64,
            );
            Ok((OpOutput::Repair { bytes }, t))
        }

        StagedOp::Drain { objects, dev } => {
            let io_before = group.sched_ref().io_calls();
            let r = crate::mero::sns::drain_with(
                &mut client.store,
                &objects,
                dev,
                at,
                group.sched(),
            );
            let (bytes, t) = match r {
                Ok(v) => v,
                Err(e) => {
                    // a drain that cannot complete (e.g. no spare
                    // capacity) must re-arm the device in the HA
                    // subsystem so its next failure event still acts
                    client.store.ha.repair_aborted(dev);
                    return Err(e);
                }
            };
            // as with repair, the drain's completion covers its own
            // frontiers (re-home writes end after their source reads);
            // the device stays in service (it never failed); the drain
            // interval lands in the HA repair log like any recovery
            client.store.ha.repair_done(dev, t);
            client.addb.record(at, "sns", "drain_bytes", bytes as f64);
            client.addb.record(
                at,
                "sns",
                "drain_io_runs",
                (group.sched_ref().io_calls() - io_before) as f64,
            );
            Ok((OpOutput::Drain { bytes }, t))
        }

        StagedOp::Rebalance { objects, dev } => {
            let io_before = group.sched_ref().io_calls();
            // no HA engagement to unwind on error: a rebalance either
            // fails up front (failed target, unknown object) before
            // state changes, or completes — so errors just propagate
            let (bytes, t) = crate::mero::sns::rebalance_onto_with(
                &mut client.store,
                &objects,
                dev,
                at,
                group.sched(),
            )?;
            client.addb.record(at, "sns", "rebalance_bytes", bytes as f64);
            client.addb.record(
                at,
                "sns",
                "rebalance_io_runs",
                (group.sched_ref().io_calls() - io_before) as f64,
            );
            Ok((OpOutput::Rebalance { bytes }, t))
        }
    }
}

/// The shared ADDB/FDMI tail of both write variants: one record set
/// per op (batch-amortized, same keys as the legacy `writev`).
#[allow(clippy::too_many_arguments)]
fn write_telemetry(
    client: &mut Client,
    group: &OpGroup,
    obj: ObjectId,
    first_off: u64,
    n_ops: usize,
    n_merged: usize,
    total: u64,
    io_before: u64,
    at: SimTime,
) {
    client.addb.record(at, "clovis", "obj_writev_bytes", total as f64);
    client.addb.record(at, "clovis", "obj_writev_ops", n_ops as f64);
    client
        .addb
        .record(at, "clovis", "obj_writev_merged_ops", n_merged as f64);
    client.addb.record(
        at,
        "clovis",
        "obj_writev_io_runs",
        (group.sched_ref().io_calls() - io_before) as f64,
    );
    client.fdmi.emit(FdmiRecord::ObjectWritten {
        obj,
        offset: first_off,
        len: total,
        at,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;
    use crate::sim::device::DeviceKind;

    fn client() -> Client {
        Client::new_sim(Testbed::sage_prototype())
    }

    const STRIPE: u64 = 4 * 65536; // default layout stripe width

    #[test]
    fn zero_op_session_completes_at_now() {
        let mut c = client();
        c.now = 11.5;
        let r = c.session().run().unwrap();
        assert_eq!(r.completed_at, 11.5);
        assert_eq!(c.now, 11.5);
        assert!(r.outputs.is_empty());
        assert_eq!(r.ios, 0);
    }

    #[test]
    fn single_write_session_equals_legacy_writev() {
        let data = vec![7u8; STRIPE as usize];
        let extents: Vec<(u64, &[u8])> = vec![(0, &data)];
        let mut a = client();
        let oa = a.create_object(4096).unwrap();
        let ta = a.writev(&oa, &extents).unwrap();
        let mut b = client();
        let ob = b.create_object(4096).unwrap();
        let tb = {
            let mut s = b.session();
            s.write(&ob, &extents);
            s.run().unwrap().completed_at
        };
        assert_eq!(ta.to_bits(), tb.to_bits(), "bit-identical completion");
        assert_eq!(a.now.to_bits(), b.now.to_bits());
        assert_eq!(
            a.read_object(&oa, 0, STRIPE).unwrap(),
            b.read_object(&ob, 0, STRIPE).unwrap()
        );
    }

    #[test]
    fn unchained_ops_overlap_chained_ops_serialize() {
        let a = vec![1u8; STRIPE as usize];
        let b = vec![2u8; STRIPE as usize];
        let run = |chain: bool| {
            let mut c = client();
            let o1 = c.create_object(4096).unwrap();
            let o2 = c.create_object(4096).unwrap();
            let mut s = c.session();
            let w1 = s.write_owned(&o1, vec![(0, a.clone())]);
            let w2 = s.write_owned(&o2, vec![(0, b.clone())]);
            if chain {
                s.after(w2, w1).unwrap();
            }
            s.run().unwrap().completed_at
        };
        let t_par = run(false);
        let t_chain = run(true);
        assert!(
            t_par < t_chain,
            "independent ops overlap on their shards: {t_par} vs {t_chain}"
        );
    }

    #[test]
    fn after_chain_matches_sequential_legacy_calls() {
        let data = vec![3u8; 2 * STRIPE as usize];
        // sequential legacy: write then read, clock advancing between
        let mut a = client();
        let oa = a.create_object(4096).unwrap();
        a.writev(&oa, &[(0, &data)]).unwrap();
        let back_a = a
            .readv(&oa, &[Extent::new(0, STRIPE), Extent::new(STRIPE, STRIPE)])
            .unwrap();
        // one session, read chained after the write
        let mut b = client();
        let ob = b.create_object(4096).unwrap();
        let (back_b, t_b) = {
            let mut s = b.session();
            let w = s.write(&ob, &[(0, &data)]);
            let r = s.read(&ob, &[Extent::new(0, STRIPE), Extent::new(STRIPE, STRIPE)]);
            s.after(r, w).unwrap();
            let mut rep = s.run().unwrap();
            let OpOutput::Read(bufs) = rep.outputs.swap_remove(r.index()) else {
                panic!("read output expected");
            };
            (bufs, rep.completed_at)
        };
        assert_eq!(back_a, back_b, "chained session == sequential bytes");
        assert_eq!(a.now.to_bits(), t_b.to_bits(), "and bit-identical time");
        assert_eq!(b.now.to_bits(), t_b.to_bits());
    }

    #[test]
    fn after_rejects_forward_and_unknown_edges() {
        let mut c = client();
        let idx = c.create_index();
        let mut s = c.session();
        let g1 = s.idx_get(idx, vec![b"a".to_vec()]);
        let g2 = s.idx_get(idx, vec![b"b".to_vec()]);
        assert!(s.after(g1, g2).is_err(), "dep on later op rejected");
        assert!(s.after(g1, g1).is_err(), "self-dep rejected");
        assert!(s.after(OpHandle(99), g1).is_err(), "unknown handle rejected");
        assert!(s.after(g2, g1).is_ok());
        s.run().unwrap();
    }

    #[test]
    fn mixed_kinds_share_one_group() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let idx = c.create_index();
        let payload = vec![9u8; STRIPE as usize];
        let mut s = c.session();
        let w = s.write_owned(&obj, vec![(0, payload.clone())]);
        let p = s.idx_put(idx, vec![(b"k".to_vec(), b"v".to_vec())]);
        let t = s.tx(vec![(b"tk".to_vec(), b"tv".to_vec())]);
        let g = s.idx_get(idx, vec![b"k".to_vec()]);
        s.after(g, p).unwrap();
        let rep = s.run().unwrap();
        assert!(rep.completed[w.index()] > 0.0);
        assert!(matches!(rep.output(p), OpOutput::IdxPut));
        match rep.output(g) {
            OpOutput::IdxGet(vals) => assert_eq!(vals[0], Some(b"v".to_vec())),
            other => panic!("unexpected {other:?}"),
        }
        match rep.output(t) {
            OpOutput::Tx(tx) => {
                assert_eq!(c.store.dtm.get(b"tk"), Some(&b"tv".to_vec()));
                assert!(tx.0 > 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        // the group completed at the max over all ops' completions
        assert!(rep.completed_at >= rep.completed[w.index()]);
        assert_eq!(c.read_object(&obj, 0, STRIPE).unwrap(), payload);
    }

    #[test]
    fn independent_tx_ops_group_commit_concurrently() {
        // two tx ops in one session each complete one LOG_FORCE after
        // dispatch — not one after the other
        let mut c = client();
        let mut s = c.session();
        let t1 = s.tx(vec![(b"a".to_vec(), b"1".to_vec())]);
        let t2 = s.tx(vec![(b"b".to_vec(), b"2".to_vec())]);
        let rep = s.run().unwrap();
        assert_eq!(
            rep.completed[t1.index()].to_bits(),
            rep.completed[t2.index()].to_bits(),
            "independent tx ops overlap"
        );
        // versus the chained/legacy shape, which serializes the forces
        let mut d = client();
        let mut s = d.session();
        let u1 = s.tx(vec![(b"a".to_vec(), b"1".to_vec())]);
        let u2 = s.tx(vec![(b"b".to_vec(), b"2".to_vec())]);
        s.after(u2, u1).unwrap();
        let rep2 = s.run().unwrap();
        assert!(rep2.completed[u2.index()] > rep2.completed[u1.index()]);
        assert!(rep2.completed_at > rep.completed_at);
    }

    #[test]
    fn read_coalescing_is_byte_identical_and_reads_shared_units_once() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data: Vec<u8> = (0..2 * STRIPE).map(|i| (i % 241) as u8).collect();
        c.write_object(&obj, 0, &data).unwrap();
        // two adjacent extents that split one 64 KiB unit mid-way:
        // unmerged they would each read that unit; merged it is one op
        let exts = [
            Extent::new(0, STRIPE / 2 + 4096),
            Extent::new(STRIPE / 2 + 4096, STRIPE / 2 - 4096),
        ];
        let mut s = c.session();
        let h = s.read(&obj, &exts);
        let mut rep = s.run().unwrap();
        let OpOutput::Read(bufs) = rep.outputs.swap_remove(h.index()) else {
            panic!("read output expected");
        };
        assert_eq!(bufs.len(), 2, "one buffer per caller extent");
        assert_eq!(bufs[0], &data[..(STRIPE / 2 + 4096) as usize]);
        assert_eq!(bufs[1], &data[(STRIPE / 2 + 4096) as usize..STRIPE as usize]);
        let summary = c.addb.summary();
        let (_, merged) = summary
            .iter()
            .find(|(k, _)| k == "clovis.obj_readv_merged_ops")
            .map(|(_, v)| *v)
            .expect("merged-op stat recorded");
        assert_eq!(merged, 1.0, "adjacent read extents merge into one op");
    }

    #[test]
    fn session_error_marks_op_failed_and_propagates() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let mut s = c.session();
        // unaligned write: the engine rejects it
        let bad = vec![1u8; 100];
        let extents: Vec<(u64, &[u8])> = vec![(13, &bad)];
        s.write(&obj, &extents);
        assert!(s.run().is_err());
    }

    #[test]
    fn ship_session_shares_shards_with_foreground_io() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let chk = c.create_object(4096).unwrap();
        let data = vec![4u8; STRIPE as usize];
        c.write_object(&obj, 0, &data).unwrap();
        let payload = vec![5u8; STRIPE as usize];
        let mut s = c.session();
        let sh = s.ship(obj, FunctionKind::IntegrityCheck);
        s.write_owned(&chk, vec![(0, payload)]);
        let rep = s.run().unwrap();
        match rep.output(sh) {
            OpOutput::Ship(r) => assert!(r.t_done > 0.0),
            other => panic!("unexpected {other:?}"),
        }
        assert!(rep.ios > 0, "both kinds dispatched unit I/O on one group");
        assert!(!rep.frontiers.is_empty());
        assert_eq!(c.store.object(chk).unwrap().size, STRIPE);
    }

    #[test]
    fn mixed_session_tags_classes_in_the_qos_frontier_table() {
        use crate::sim::device::DeviceKind as DK;
        use crate::sim::sched::TrafficClass;
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![2u8; 2 * STRIPE as usize];
        c.write_object(&obj, 0, &data).unwrap();
        let dev = c.store.object(obj).unwrap().placement(0, 0).unwrap().device;
        c.store.cluster.fail_device(dev);
        let fresh = c.create_object(4096).unwrap();
        let mut s = c.session();
        s.repair(&[obj], dev);
        s.write_owned(&fresh, vec![(0, vec![3u8; STRIPE as usize])]);
        let rep = s.run().unwrap();
        assert!(!rep.qos.is_empty(), "drained shards report class state");
        let repair_busy: f64 = rep
            .qos
            .iter()
            .map(|r| r.class_busy[TrafficClass::Repair.index()])
            .sum();
        let fg_busy: f64 = rep
            .qos
            .iter()
            .map(|r| r.class_busy[TrafficClass::Foreground.index()])
            .sum();
        assert!(repair_busy > 0.0, "repair traffic tagged Repair");
        assert!(fg_busy > 0.0, "the write stays Foreground");
        // the cap held on every shard repair touched
        let cap = c.store.cluster.qos.share(TrafficClass::Repair);
        for r in &rep.qos {
            assert!(r.observed_share(TrafficClass::Repair) <= cap + 1e-9);
        }
        // and the repaired data survives on the original tier
        assert_eq!(c.store.object(obj).unwrap().layout.tier(), DK::Ssd);
        assert_eq!(c.read_object(&obj, 0, data.len() as u64).unwrap(), data);
    }

    #[test]
    fn rebalance_session_is_migration_class_and_preserves_bytes() {
        use crate::sim::sched::TrafficClass;
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![8u8; 4 * STRIPE as usize];
        c.write_object(&obj, 0, &data).unwrap();
        let src = c.store.object(obj).unwrap().placement(0, 0).unwrap().device;
        let prof = c.store.cluster.devices[src].profile.clone();
        let dev = c.store.attach_device(1, prof).unwrap();
        let mut s = c.session();
        let h = s.rebalance(&[obj], dev);
        let rep = s.run().unwrap();
        let OpOutput::Rebalance { bytes } = rep.output(h) else {
            panic!("rebalance output expected");
        };
        assert!(*bytes > 0, "fresh capacity attracted units");
        let mig_busy: f64 = rep
            .qos
            .iter()
            .map(|r| r.class_busy[TrafficClass::Migration.index()])
            .sum();
        assert!(mig_busy > 0.0, "rebalance traffic tagged Migration");
        assert_eq!(c.read_object(&obj, 0, data.len() as u64).unwrap(), data);
        // staging on a failed target surfaces the engine's error
        c.store.cluster.fail_device(dev);
        let mut s = c.session();
        s.rebalance(&[obj], dev);
        assert!(s.run().is_err());
    }

    #[test]
    fn tenant_sessions_report_per_tenant_lanes() {
        let mut c = client();
        let t2 = c.register_tenant(1.0); // activates the tenant plane
        let o1 = c.create_object(4096).unwrap();
        let o2 = c.create_object(4096).unwrap();
        let mut s = c.session(); // default tenant
        s.write_owned(&o1, vec![(0, vec![1u8; STRIPE as usize])]);
        let r1 = s.run().unwrap();
        assert!(!r1.tenants.is_empty(), "active plane reports tenant lanes");
        assert!(r1
            .tenants
            .iter()
            .flat_map(|r| r.lanes.iter())
            .all(|l| l.tenant == crate::sim::sched::DEFAULT_TENANT));
        // the second tenant's session reports ITS lanes only (the
        // earlier session's shards re-seeded: back-to-back, not
        // contending)
        let mut s = c.session_as(t2).unwrap();
        assert_eq!(s.tenant(), t2);
        s.write_owned(&o2, vec![(0, vec![2u8; STRIPE as usize])]);
        let r2 = s.run().unwrap();
        assert!(!r2.tenants.is_empty());
        assert!(r2
            .tenants
            .iter()
            .flat_map(|r| r.lanes.iter())
            .all(|l| l.tenant == t2));
        // bytes land regardless of lane accounting
        assert_eq!(
            c.read_object(&o1, 0, STRIPE).unwrap(),
            vec![1u8; STRIPE as usize]
        );
        assert_eq!(
            c.read_object(&o2, 0, STRIPE).unwrap(),
            vec![2u8; STRIPE as usize]
        );
    }

    #[test]
    fn migrate_session_moves_and_publishes() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![6u8; STRIPE as usize];
        c.write_object(&obj, 0, &data).unwrap();
        let mut hsm = crate::hsm::Hsm::new(crate::hsm::TieringPolicy::HeatWeighted);
        let plan = vec![Migration {
            obj,
            from: DeviceKind::Ssd,
            to: DeviceKind::Nvram,
        }];
        let _ = c.fdmi.drain();
        let mut s = c.session();
        let m = s.migrate(&mut hsm, &plan);
        let rep = s.run().unwrap();
        assert!(matches!(rep.output(m), OpOutput::Migrate));
        assert!(c
            .fdmi
            .drain()
            .iter()
            .any(|r| matches!(r, FdmiRecord::ObjectMigrated { .. })));
        assert_eq!(c.store.object(obj).unwrap().layout.tier(), DeviceKind::Nvram);
        assert_eq!(c.read_object(&obj, 0, STRIPE).unwrap(), data);
    }
}
