//! Function shipping: in-storage compute (§3.2.1).
//!
//! "Instead of moving the data to the computation, the computation
//! moves to the data. The function-shipping component will provide the
//! ability to run data-centric, distributed computations directly on
//! the storage nodes where the data resides. … Well defined functions
//! are offloaded from the use cases to storage through the API and
//! invoked through simple Remote Procedure Call (RPC) mechanisms."
//!
//! A [`FunctionKind`] descriptor is RPC'd to the node holding the
//! object; the node reads the object *locally* (device I/O, no network
//! transfer of the payload), runs the AOT-compiled kernel through the
//! PJRT [`Executor`] (or the CPU fallback), and returns only the small
//! result. [`ShipResult`] reports both the shipped cost and the
//! counterfactual move-data-to-client cost so benches can show the
//! paper's data-movement saving.

use crate::clovis::Client;
use crate::error::Result;
use crate::mero::object::ObjectId;
use crate::sim::clock::SimTime;
use crate::sim::device::{Access, IoOp};
use crate::sim::sched::IoScheduler;

/// The well-defined functions the SAGE use cases offload.
#[derive(Debug, Clone, PartialEq)]
pub enum FunctionKind {
    /// iPIC3D particle post-processing: energy filter at `threshold`
    /// (Fig 6/7 payload; `postprocess_*` artifacts).
    ParticleFilter { threshold: f32 },
    /// ALF log analytics: histogram over [lo, hi) (`alf_histogram_64k`).
    Histogram { lo: f32, hi: f32 },
    /// Integrity scrub of object blocks (`integrity_16x4k`).
    IntegrityCheck,
}

/// Small result returned over RPC (the point: results are tiny, data
/// stays in storage).
#[derive(Debug, Clone)]
pub enum FnOutput {
    /// (selected count, [count, sel energy sum, max, mean]).
    Particles { selected: usize, stats: [f32; 4] },
    /// 64-bin counts.
    Histogram(Vec<f32>),
    /// Per-extent digests.
    Digests(Vec<[i32; 2]>),
    /// Phantom object: cost accounted, no payload to compute on.
    Phantom,
}

/// Outcome of one shipped invocation.
#[derive(Debug, Clone)]
pub struct ShipResult {
    pub output: FnOutput,
    /// Completion time with function shipping.
    pub t_done: SimTime,
    /// Counterfactual completion time moving the data to the client.
    pub t_move_data: SimTime,
    /// Bytes that crossed the network (shipped path).
    pub net_bytes: u64,
    /// Bytes that would have crossed the network (move path).
    pub net_bytes_moved: u64,
}

/// Result payload size over RPC (stats / histogram / digests), bytes.
const RESULT_BYTES: u64 = 1024;
/// RPC descriptor size, bytes.
const RPC_BYTES: u64 = 256;

/// Ship `func` to the storage node holding `obj` as a self-contained
/// op at the client clock (private scheduler).
pub fn ship_to_object(
    client: &mut Client,
    obj: ObjectId,
    func: FunctionKind,
) -> Result<ShipResult> {
    let now = client.now;
    let mut sched = IoScheduler::new();
    ship_to_object_with(client, obj, func, now, &mut sched)
}

/// [`ship_to_object`] dispatching the node-local object read onto the
/// caller's group scheduler at `now` (sharded op execution): in a
/// Clovis session the shipped computation's on-node read shares
/// device shards with foreground I/O and recovery traffic, so
/// in-storage compute genuinely overlaps a checkpoint write or a
/// migration on the same device queues instead of serializing through
/// a private `cluster.io()` fold. A lone call on a fresh scheduler is
/// time-identical to the pre-session behaviour.
pub fn ship_to_object_with(
    client: &mut Client,
    obj: ObjectId,
    func: FunctionKind,
    now: SimTime,
    sched: &mut IoScheduler,
) -> Result<ShipResult> {
    let size = client.store.object(obj)?.size;
    let is_real = client.store.object(obj)?.real_blocks() > 0;

    // locate the primary device/node of the object
    let dev = client
        .store
        .object(obj)?
        .placed_units()
        .next()
        .map(|u| u.device);

    // --- time model: shipped path ------------------------------------
    // RPC there + local read of the object (on the group's shard for
    // the home device) + in-enclosure compute + result back.
    let net = client.store.cluster.net.clone();
    let mut t = now + net.pt2pt(RPC_BYTES);
    let (node, local_read) = match dev {
        Some(d) => {
            let node = client.store.cluster.node_of(d).unwrap_or(0);
            let ticket = sched.submit(d, t, size.max(1), IoOp::Read, Access::Seq);
            sched.drain(&mut client.store.cluster.devices);
            (node, sched.completion(ticket))
        }
        None => (0, t),
    };
    t = local_read;
    // compute cost at ~1 flop/byte for filters/histograms
    t += client.store.cluster.compute_time(node, size as f64);
    t += net.pt2pt(RESULT_BYTES);

    // --- counterfactual: move data to client --------------------------
    // (reported for the data-movement comparison, not part of the op
    // group's completion — it queues on the device like any probe)
    let mut t_move = now;
    if let Some(d) = dev {
        t_move = client
            .store
            .cluster
            // sage-lint: allow(scheduler-discipline, "counterfactual data-movement probe: queues on the device FIFO like any probe, never part of the op group's completion")
            .io(d, now, size.max(1), IoOp::Read, Access::Seq);
    }
    t_move += net.pt2pt(size.max(1)); // bulk transfer
    t_move += size as f64 / 10e9; // client-side compute at 10 GB/s

    // --- actually run the function on real data -----------------------
    let output = if is_real {
        run_function(client, obj, &func, now)?
    } else {
        FnOutput::Phantom
    };

    client.addb.record(now, "fship", "invocations", 1.0);
    client
        .addb
        .record(now, "fship", "bytes_saved", size as f64);

    Ok(ShipResult {
        output,
        t_done: t,
        t_move_data: t_move,
        net_bytes: RPC_BYTES + RESULT_BYTES,
        net_bytes_moved: size,
    })
}

/// Execute the function payload over the object's real bytes, via PJRT
/// when the artifact is loaded, else the CPU fallback.
fn run_function(
    client: &mut Client,
    obj: ObjectId,
    func: &FunctionKind,
    now: SimTime,
) -> Result<FnOutput> {
    let size = client.store.object(obj)?.size;
    let (data, _) = crate::mero::sns::read(&mut client.store, obj, 0, size, now)?;
    match func {
        FunctionKind::ParticleFilter { threshold } => {
            // interpret bytes as (n, 8) f32 particles
            let n_floats = data.len() / 4;
            let n = n_floats / 8;
            let mut floats = vec![0f32; n * 8];
            for (i, f) in floats.iter_mut().enumerate() {
                *f = f32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
            }
            if let Some(e) = &client.exec {
                if let Some(out) = e.postprocess(&floats, *threshold)? {
                    return Ok(FnOutput::Particles {
                        selected: out.selected,
                        stats: out.stats,
                    });
                }
            }
            // CPU fallback — identical math
            let mut selected = 0usize;
            let mut sum = 0f32;
            let mut maxe = 0f32;
            let mut tote = 0f32;
            for p in floats.chunks(8) {
                let e = 0.5 * p[6].abs() * (p[3] * p[3] + p[4] * p[4] + p[5] * p[5]);
                tote += e;
                maxe = maxe.max(e);
                if e > *threshold {
                    selected += 1;
                    sum += e;
                }
            }
            Ok(FnOutput::Particles {
                selected,
                stats: [selected as f32, sum, maxe, tote / n.max(1) as f32],
            })
        }
        FunctionKind::Histogram { lo, hi } => {
            let n = data.len() / 4;
            let mut vals = vec![0f32; n];
            for (i, v) in vals.iter_mut().enumerate() {
                *v = f32::from_le_bytes(data[i * 4..i * 4 + 4].try_into().unwrap());
            }
            if let Some(e) = &client.exec {
                if let Some(counts) = e.histogram(&vals, *lo, *hi)? {
                    return Ok(FnOutput::Histogram(counts));
                }
            }
            let mut counts = vec![0f32; 64];
            let width = (hi - lo) / 64.0;
            for v in vals {
                let idx = (((v - lo) / width).floor() as i64).clamp(0, 63) as usize;
                counts[idx] += 1.0;
            }
            Ok(FnOutput::Histogram(counts))
        }
        FunctionKind::IntegrityCheck => {
            let lanes: Vec<i32> = data
                .chunks(4)
                .map(|c| {
                    let mut b = [0u8; 4];
                    b[..c.len()].copy_from_slice(c);
                    i32::from_le_bytes(b)
                })
                .collect();
            if let Some(e) = &client.exec {
                // pad/truncate to the artifact extent shape
                if let Some(info) = e.info("integrity_16x4k") {
                    let want = info.input_shapes[0][0] * info.input_shapes[0][1];
                    let mut padded = lanes.clone();
                    padded.resize(want, 0);
                    if let Some(d) = e.integrity(&padded)? {
                        return Ok(FnOutput::Digests(d));
                    }
                }
            }
            // CPU fallback: same Fletcher-style pair per 4096-lane block
            let mut out = Vec::new();
            for block in lanes.chunks(4096) {
                let mut s1 = 0i32;
                let mut s2 = 0i32;
                for (i, &v) in block.iter().enumerate() {
                    s1 = s1.wrapping_add(v);
                    s2 = s2.wrapping_add(v.wrapping_mul(i as i32 + 1));
                }
                out.push([s1, s2]);
            }
            Ok(FnOutput::Digests(out))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    fn client() -> Client {
        Client::new_sim(Testbed::sage_prototype())
    }

    /// Particles with known energies, encoded as object bytes.
    fn particle_bytes(n: usize, hot: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n * 32);
        for i in 0..n {
            let speed = if i < hot { 10.0f32 } else { 0.1 };
            let row = [0.0f32, 0.0, 0.0, speed, 0.0, 0.0, 1.0, i as f32];
            for v in row {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn shipped_filter_counts_hot_particles() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        // 1024 particles = 32 KiB; pad to one block multiple
        let mut data = particle_bytes(1024, 37);
        data.resize(64 * 1024 * 4, 0); // whole default stripe
        c.write_object(&obj, 0, &data).unwrap();
        let r = c
            .ship_to_object(obj, FunctionKind::ParticleFilter { threshold: 1.0 })
            .unwrap();
        match r.output {
            FnOutput::Particles { selected, .. } => assert_eq!(selected, 37),
            other => panic!("unexpected output {other:?}"),
        }
    }

    #[test]
    fn shipping_beats_moving_for_large_objects() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        c.write_object(&obj, 0, &particle_bytes(8192, 5).repeat(1)[..8192 * 32].to_vec())
            .unwrap();
        let r = c
            .ship_to_object(obj, FunctionKind::ParticleFilter { threshold: 1.0 })
            .unwrap();
        assert!(
            r.net_bytes < r.net_bytes_moved / 10,
            "shipping moves orders of magnitude fewer bytes"
        );
    }

    #[test]
    fn histogram_in_storage() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let mut bytes = Vec::new();
        for i in 0..16384 {
            bytes.extend_from_slice(&(((i % 64) as f32) + 0.5).to_le_bytes());
        }
        c.write_object(&obj, 0, &bytes).unwrap();
        let r = c
            .ship_to_object(obj, FunctionKind::Histogram { lo: 0.0, hi: 64.0 })
            .unwrap();
        match r.output {
            FnOutput::Histogram(counts) => {
                assert_eq!(counts.len(), 64);
                assert_eq!(counts.iter().sum::<f32>(), 16384.0);
                assert!(counts.iter().all(|&c| c == 256.0));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn integrity_check_detects_no_false_positive() {
        let mut c = client();
        let obj = c.create_object(4096).unwrap();
        let data = vec![3u8; 64 * 1024];
        c.write_object(&obj, 0, &data).unwrap();
        let r1 = c.ship_to_object(obj, FunctionKind::IntegrityCheck).unwrap();
        let r2 = c.ship_to_object(obj, FunctionKind::IntegrityCheck).unwrap();
        match (&r1.output, &r2.output) {
            (FnOutput::Digests(a), FnOutput::Digests(b)) => assert_eq!(a, b),
            _ => panic!("expected digests"),
        }
    }
}
