//! Clovis operation lifecycle (§3.2.2 access interface).
//!
//! The real Clovis API is asynchronous: every object/index call creates
//! an *op* that moves through INIT → LAUNCHED → EXECUTED (or FAILED),
//! and callers wait on ops or op groups. The simulation executes
//! synchronously in virtual time, but the op state machine is preserved
//! as the public API surface: launch times, completion times and
//! failure states are observable exactly as an application would see
//! them.

use crate::error::{Result, SageError};
use crate::sim::clock::SimTime;

/// Op lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpState {
    Init,
    Launched,
    Executed,
    Failed,
}

/// What kind of operation an op represents (diagnostics + ADDB).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    ObjCreate,
    ObjWrite,
    ObjRead,
    ObjDelete,
    IdxPut,
    IdxGet,
    IdxDel,
    IdxNext,
    FnShip,
    Tx,
}

/// One asynchronous operation.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: u64,
    pub kind: OpKind,
    pub state: OpState,
    pub launched_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
    pub error: Option<String>,
}

impl Op {
    /// New op in INIT.
    pub fn new(id: u64, kind: OpKind) -> Self {
        Op {
            id,
            kind,
            state: OpState::Init,
            launched_at: None,
            finished_at: None,
            error: None,
        }
    }

    /// INIT → LAUNCHED.
    pub fn launch(&mut self, at: SimTime) -> Result<()> {
        if self.state != OpState::Init {
            return Err(SageError::Invalid(format!(
                "op {} launch from {:?}",
                self.id, self.state
            )));
        }
        self.state = OpState::Launched;
        self.launched_at = Some(at);
        Ok(())
    }

    /// LAUNCHED → EXECUTED.
    pub fn complete(&mut self, at: SimTime) -> Result<()> {
        if self.state != OpState::Launched {
            return Err(SageError::Invalid(format!(
                "op {} complete from {:?}",
                self.id, self.state
            )));
        }
        self.state = OpState::Executed;
        self.finished_at = Some(at);
        Ok(())
    }

    /// LAUNCHED → FAILED.
    pub fn fail(&mut self, at: SimTime, err: &str) -> Result<()> {
        if self.state != OpState::Launched {
            return Err(SageError::Invalid(format!(
                "op {} fail from {:?}",
                self.id, self.state
            )));
        }
        self.state = OpState::Failed;
        self.finished_at = Some(at);
        self.error = Some(err.to_string());
        Ok(())
    }

    /// Wall time the op took (None until finished).
    pub fn latency(&self) -> Option<SimTime> {
        Some(self.finished_at? - self.launched_at?)
    }
}

/// A group of ops awaited together (`m0_op_wait` analog).
#[derive(Debug, Default)]
pub struct OpGroup {
    ops: Vec<Op>,
    next_id: u64,
}

impl OpGroup {
    /// Empty group.
    pub fn new() -> Self {
        OpGroup::default()
    }

    /// Add an op; returns its id.
    pub fn add(&mut self, kind: OpKind) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.ops.push(Op::new(id, kind));
        id
    }

    /// Borrow an op by id.
    pub fn op_mut(&mut self, id: u64) -> Result<&mut Op> {
        self.ops
            .iter_mut()
            .find(|o| o.id == id)
            .ok_or_else(|| SageError::NotFound(format!("op {id}")))
    }

    /// Wait for all ops: the completion time is the max finish time.
    /// Errors if any op FAILED or is still pending.
    pub fn wait_all(&self) -> Result<SimTime> {
        let mut t = 0.0f64;
        for op in &self.ops {
            match op.state {
                OpState::Executed => {
                    t = t.max(op.finished_at.unwrap_or(0.0));
                }
                OpState::Failed => {
                    return Err(SageError::Invalid(format!(
                        "op {} failed: {}",
                        op.id,
                        op.error.clone().unwrap_or_default()
                    )));
                }
                _ => {
                    return Err(SageError::Invalid(format!(
                        "op {} not finished ({:?})",
                        op.id, op.state
                    )));
                }
            }
        }
        Ok(t)
    }

    /// Count by state.
    pub fn count(&self, state: OpState) -> usize {
        self.ops.iter().filter(|o| o.state == state).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_happy_path() {
        let mut op = Op::new(1, OpKind::ObjWrite);
        op.launch(1.0).unwrap();
        op.complete(3.5).unwrap();
        assert_eq!(op.state, OpState::Executed);
        assert_eq!(op.latency(), Some(2.5));
    }

    #[test]
    fn invalid_transitions_rejected() {
        let mut op = Op::new(1, OpKind::ObjRead);
        assert!(op.complete(1.0).is_err(), "cannot complete before launch");
        op.launch(0.0).unwrap();
        assert!(op.launch(0.0).is_err(), "cannot double-launch");
        op.fail(1.0, "io error").unwrap();
        assert!(op.complete(2.0).is_err(), "cannot complete after fail");
    }

    #[test]
    fn group_wait_semantics() {
        let mut g = OpGroup::new();
        let a = g.add(OpKind::ObjWrite);
        let b = g.add(OpKind::ObjWrite);
        g.op_mut(a).unwrap().launch(0.0).unwrap();
        g.op_mut(b).unwrap().launch(0.0).unwrap();
        g.op_mut(a).unwrap().complete(1.0).unwrap();
        assert!(g.wait_all().is_err(), "b still pending");
        g.op_mut(b).unwrap().complete(4.0).unwrap();
        assert_eq!(g.wait_all().unwrap(), 4.0, "group completes at max");
    }

    #[test]
    fn group_wait_propagates_failure() {
        let mut g = OpGroup::new();
        let a = g.add(OpKind::FnShip);
        g.op_mut(a).unwrap().launch(0.0).unwrap();
        g.op_mut(a).unwrap().fail(1.0, "node died").unwrap();
        assert!(g.wait_all().is_err());
        assert_eq!(g.count(OpState::Failed), 1);
    }
}
