//! Clovis operation lifecycle (§3.2.2 access interface).
//!
//! The real Clovis API is asynchronous: every object/index call creates
//! an *op* that moves through INIT → LAUNCHED → EXECUTED (or FAILED),
//! and callers wait on ops or op groups. The simulation executes
//! synchronously in virtual time, but the op state machine is preserved
//! as the public API surface: launch times, completion times and
//! failure states are observable exactly as an application would see
//! them.
//!
//! ## Batched (vectored) operations, sharded across the cluster
//!
//! The `m0_op_launch`/`m0_op_wait` idiom launches *groups* of ops and
//! waits on the group, not on individual ops. That is the data-path
//! batching the paper's access interface is designed around, and the
//! §Perf engine exposes it end to end:
//!
//! * [`Extent`] describes one `(offset, len)` piece of a vectored I/O;
//! * [`OpGroup::add`] stages one op per extent, [`OpGroup::launch_batch`]
//!   moves every staged op INIT → LAUNCHED at one timestamp (all ops of
//!   a batch are in flight concurrently);
//! * every op of the group dispatches its unit I/Os onto the group's
//!   [`IoScheduler`] ([`OpGroup::sched`]) — per-device submission
//!   queues with completion frontiers, so the batch's units land on
//!   their home devices in one pass and overlap in virtual time;
//! * [`OpGroup::wait_all`] completes at the max over the scheduler's
//!   **per-device completion frontiers** (folded with the op state
//!   machine's finish times), exactly like `m0_op_wait` on a group —
//!   a slow device only delays the ops whose units queue on it;
//! * [`crate::clovis::Client::writev`] / [`Client::readv`] /
//!   [`Client::writev_owned`](crate::clovis::Client::writev_owned) drive
//!   this machinery over extent lists and amortize the per-op ADDB
//!   telemetry and FDMI event emission to **one record per batch**
//!   instead of one per op.
//!
//! The de-sharded semantics (completion as a serial fold over the
//! batch) are preserved in `mero::sns_serial` as the differential
//! oracle; `benches/ablate_sched.rs` measures the gap.
//!
//! ## The op-builder model (`Session`)
//!
//! Since ISSUE 4 the public face of this machinery is the
//! [`Session`](crate::clovis::session::Session) op builder:
//! `Client::session()` yields a builder over ONE scheduler-backed
//! `OpGroup`; every operation kind — object writes/reads, KV index
//! access, transactions, function shipping, HSM migration, SNS repair
//! and proactive drains — stages an op returning an
//! [`OpHandle`](crate::clovis::session::OpHandle);
//! `Session::after(op, pred)` declares dependency edges (dependents
//! dispatch at the predecessor's completion frontier, not at a global
//! barrier); `Session::run` executes the batch and completes at
//! [`OpGroup::wait_all_from`] the session's start clock. The legacy
//! vectored entry points (`writev`, `readv`, `migrate_with`,
//! `repair_with`, `ship_to_object`) are thin wrappers over one-op
//! sessions, bit-identical to their session-built equivalents
//! (`tests/prop_session.rs`; `readv` also gained byte-preserving
//! cross-op read coalescing, which can only tighten timings).
//!
//! [`Client::readv`]: crate::clovis::Client::readv

use crate::error::{Result, SageError};
use crate::sim::clock::SimTime;
use crate::sim::sched::{IoScheduler, QosConfig, TrafficClass};

/// One `(offset, len)` piece of a vectored I/O request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Extent {
    /// Byte offset into the object.
    pub offset: u64,
    /// Length in bytes.
    pub len: u64,
}

impl Extent {
    /// New extent.
    pub fn new(offset: u64, len: u64) -> Self {
        Extent { offset, len }
    }

    /// One-past-the-end byte offset.
    pub fn end(&self) -> u64 {
        self.offset + self.len
    }
}

/// Op lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpState {
    Init,
    Launched,
    Executed,
    Failed,
}

/// What kind of operation an op represents (diagnostics + ADDB).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    ObjCreate,
    ObjWrite,
    ObjRead,
    ObjDelete,
    IdxPut,
    IdxGet,
    IdxDel,
    IdxNext,
    FnShip,
    Tx,
    /// HSM migration batch (scheduler-driven recovery plane).
    Migrate,
    /// SNS repair of a failed device (scheduler-driven recovery plane).
    Repair,
    /// Proactive drain of a degrading (still-live) device
    /// (`RepairAction::ProactiveDrain` executed by the recovery plane).
    Drain,
    /// Rebalance onto freshly-attached pool capacity (elastic pool
    /// membership — the inverse of a drain).
    Rebalance,
}

impl OpKind {
    /// QoS [`TrafficClass`] ops of this kind dispatch under (§3.2.1
    /// repair throttling): recovery work (`Repair`/`Drain`) submits as
    /// [`TrafficClass::Repair`], background data movement
    /// (`Migrate`/`Rebalance`) as
    /// [`TrafficClass::Migration`], everything else — object I/O, KV,
    /// transactions, function shipping — as
    /// [`TrafficClass::Foreground`]. `Session::run` stamps the group
    /// scheduler with this class around each op's dispatch.
    pub fn traffic_class(self) -> TrafficClass {
        match self {
            OpKind::Repair | OpKind::Drain => TrafficClass::Repair,
            OpKind::Migrate | OpKind::Rebalance => TrafficClass::Migration,
            _ => TrafficClass::Foreground,
        }
    }
}

/// One asynchronous operation.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: u64,
    pub kind: OpKind,
    pub state: OpState,
    pub launched_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
    pub error: Option<String>,
}

impl Op {
    /// New op in INIT.
    pub fn new(id: u64, kind: OpKind) -> Self {
        Op {
            id,
            kind,
            state: OpState::Init,
            launched_at: None,
            finished_at: None,
            error: None,
        }
    }

    /// INIT → LAUNCHED.
    pub fn launch(&mut self, at: SimTime) -> Result<()> {
        if self.state != OpState::Init {
            return Err(SageError::Invalid(format!(
                "op {} launch from {:?}",
                self.id, self.state
            )));
        }
        self.state = OpState::Launched;
        self.launched_at = Some(at);
        Ok(())
    }

    /// LAUNCHED → EXECUTED.
    pub fn complete(&mut self, at: SimTime) -> Result<()> {
        if self.state != OpState::Launched {
            return Err(SageError::Invalid(format!(
                "op {} complete from {:?}",
                self.id, self.state
            )));
        }
        self.state = OpState::Executed;
        self.finished_at = Some(at);
        Ok(())
    }

    /// LAUNCHED → FAILED.
    pub fn fail(&mut self, at: SimTime, err: &str) -> Result<()> {
        if self.state != OpState::Launched {
            return Err(SageError::Invalid(format!(
                "op {} fail from {:?}",
                self.id, self.state
            )));
        }
        self.state = OpState::Failed;
        self.finished_at = Some(at);
        self.error = Some(err.to_string());
        Ok(())
    }

    /// Wall time the op took (None until finished).
    pub fn latency(&self) -> Option<SimTime> {
        Some(self.finished_at? - self.launched_at?)
    }
}

/// A group of ops awaited together (`m0_op_wait` analog), owning the
/// sharded per-device [`IoScheduler`] its ops execute on.
#[derive(Debug, Default)]
pub struct OpGroup {
    ops: Vec<Op>,
    next_id: u64,
    sched: IoScheduler,
}

impl OpGroup {
    /// Empty group with NO QoS split (pre-QoS FIFO scheduling) — the
    /// self-contained default.
    pub fn new() -> Self {
        OpGroup::default()
    }

    /// Empty group whose scheduler enforces `qos` on every shard.
    /// `Session::run` builds its group with the cluster's configured
    /// split ([`Cluster::qos`](crate::cluster::Cluster)), so repair,
    /// drain and migration ops are bandwidth-capped against the
    /// session's foreground traffic (§3.2.1 repair throttling).
    pub fn with_qos(qos: QosConfig) -> Self {
        OpGroup {
            ops: Vec::new(),
            next_id: 0,
            sched: IoScheduler::with_qos(qos),
        }
    }

    /// Group over an **adopted** scheduler — the ISSUE 7 multi-tenant
    /// path. `Session::run` takes the cluster-wide scheduler out of
    /// the client, and the group opens a fresh scheduling epoch on it
    /// at the session clock `now`: shards idle at `now` behave exactly
    /// like a fresh private scheduler (bit-exact), busy shards
    /// contend, and [`OpGroup::wait_all`] / `frontiers()` /
    /// `qos_report()` scope to this group's own submissions (other
    /// groups' completions are invisible — see
    /// `sim::sched::IoScheduler::begin_epoch`). Hand the scheduler
    /// back with [`OpGroup::release`] when the group is done.
    pub fn adopt(sched: IoScheduler, now: SimTime) -> Self {
        let mut g = OpGroup { ops: Vec::new(), next_id: 0, sched };
        g.sched.begin_epoch(now);
        g
    }

    /// Give the adopted scheduler back (to be stored on the client for
    /// the next session). Consumes the group: its ops are done, the
    /// scheduler's shard state lives on cluster-wide.
    pub fn release(self) -> IoScheduler {
        self.sched
    }

    /// The group's sharded I/O scheduler: ops executed under this
    /// group dispatch their unit I/Os here (one submission pass to
    /// home-device shards; see `sim::sched`).
    pub fn sched(&mut self) -> &mut IoScheduler {
        &mut self.sched
    }

    /// Read-only view of the scheduler (frontiers, dispatch stats).
    pub fn sched_ref(&self) -> &IoScheduler {
        &self.sched
    }

    /// Add an op; returns its id.
    pub fn add(&mut self, kind: OpKind) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.ops.push(Op::new(id, kind));
        id
    }

    /// Launch every op still in INIT at one timestamp (`m0_op_launch`
    /// over the whole group — the batched data path). Returns the
    /// number of ops launched.
    pub fn launch_batch(&mut self, at: SimTime) -> Result<usize> {
        let mut n = 0;
        for op in &mut self.ops {
            if op.state == OpState::Init {
                op.launch(at)?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Borrow an op by id.
    pub fn op_mut(&mut self, id: u64) -> Result<&mut Op> {
        self.ops
            .iter_mut()
            .find(|o| o.id == id)
            .ok_or_else(|| SageError::NotFound(format!("op {id}")))
    }

    /// Wait for all ops: the completion time is the max over the
    /// scheduler's per-device completion frontiers, folded with each
    /// op's recorded finish time (sharded execution — NOT a serial
    /// fold over units). Errors if any op FAILED or is still pending.
    pub fn wait_all(&self) -> Result<SimTime> {
        let mut t = self.sched.wait_all();
        for op in &self.ops {
            match op.state {
                OpState::Executed => {
                    t = t.max(op.finished_at.unwrap_or(0.0));
                }
                OpState::Failed => {
                    return Err(SageError::Invalid(format!(
                        "op {} failed: {}",
                        op.id,
                        op.error.clone().unwrap_or_default()
                    )));
                }
                _ => {
                    return Err(SageError::Invalid(format!(
                        "op {} not finished ({:?})",
                        op.id, op.state
                    )));
                }
            }
        }
        Ok(t)
    }

    /// [`OpGroup::wait_all`] with a completion floor: the group of an
    /// operation issued at `now` can never complete before `now`, and
    /// an EMPTY group completes exactly at `now`. This is what no-op
    /// paths (empty gateway batches, zero-op [`Session::run`]) rely on
    /// instead of special-casing emptiness.
    ///
    /// [`Session::run`]: crate::clovis::session::Session::run
    pub fn wait_all_from(&self, now: SimTime) -> Result<SimTime> {
        Ok(self.wait_all()?.max(now))
    }

    /// Count by state.
    pub fn count(&self, state: OpState) -> usize {
        self.ops.iter().filter(|o| o.state == state).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_happy_path() {
        let mut op = Op::new(1, OpKind::ObjWrite);
        op.launch(1.0).unwrap();
        op.complete(3.5).unwrap();
        assert_eq!(op.state, OpState::Executed);
        assert_eq!(op.latency(), Some(2.5));
    }

    #[test]
    fn invalid_transitions_rejected() {
        let mut op = Op::new(1, OpKind::ObjRead);
        assert!(op.complete(1.0).is_err(), "cannot complete before launch");
        op.launch(0.0).unwrap();
        assert!(op.launch(0.0).is_err(), "cannot double-launch");
        op.fail(1.0, "io error").unwrap();
        assert!(op.complete(2.0).is_err(), "cannot complete after fail");
    }

    #[test]
    fn group_wait_semantics() {
        let mut g = OpGroup::new();
        let a = g.add(OpKind::ObjWrite);
        let b = g.add(OpKind::ObjWrite);
        g.op_mut(a).unwrap().launch(0.0).unwrap();
        g.op_mut(b).unwrap().launch(0.0).unwrap();
        g.op_mut(a).unwrap().complete(1.0).unwrap();
        assert!(g.wait_all().is_err(), "b still pending");
        g.op_mut(b).unwrap().complete(4.0).unwrap();
        assert_eq!(g.wait_all().unwrap(), 4.0, "group completes at max");
    }

    #[test]
    fn launch_batch_launches_all_init_ops() {
        let mut g = OpGroup::new();
        let a = g.add(OpKind::ObjWrite);
        let b = g.add(OpKind::ObjWrite);
        let c = g.add(OpKind::ObjRead);
        g.op_mut(a).unwrap().launch(0.5).unwrap(); // already in flight
        assert_eq!(g.launch_batch(1.0).unwrap(), 2);
        assert_eq!(g.count(OpState::Launched), 3);
        assert_eq!(g.op_mut(b).unwrap().launched_at, Some(1.0));
        assert_eq!(g.op_mut(c).unwrap().launched_at, Some(1.0));
        // idempotent on an already-launched group
        assert_eq!(g.launch_batch(2.0).unwrap(), 0);
    }

    #[test]
    fn wait_all_folds_in_device_frontiers() {
        use crate::sim::device::{Access, Device, DeviceProfile, IoOp};
        let mut g = OpGroup::new();
        let a = g.add(OpKind::ObjWrite);
        g.op_mut(a).unwrap().launch(0.0).unwrap();
        // the op's unit I/O dispatches to its home-device shard
        let mut devs = vec![Device::new(DeviceProfile::ssd(1 << 30))];
        g.sched().submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        let t = g.sched().drain(&mut devs);
        assert!(t > 0.0);
        g.op_mut(a).unwrap().complete(t).unwrap();
        assert_eq!(g.wait_all().unwrap(), t);
        assert_eq!(g.sched_ref().wait_all(), t, "frontier == group completion");
        assert_eq!(g.sched_ref().shard_count(), 1);
    }

    #[test]
    fn empty_group_wait_all_from_returns_now() {
        // the pinned no-op semantics: an empty group completes at the
        // caller's clock, not at 0.0 and not with an error, so gateway
        // no-op paths and zero-op sessions need no special case
        let g = OpGroup::new();
        assert_eq!(g.wait_all().unwrap(), 0.0);
        assert_eq!(g.wait_all_from(7.25).unwrap(), 7.25);
        // a non-empty group is unaffected by a floor below its completion
        let mut g = OpGroup::new();
        let a = g.add(OpKind::ObjWrite);
        g.op_mut(a).unwrap().launch(0.0).unwrap();
        g.op_mut(a).unwrap().complete(4.0).unwrap();
        assert_eq!(g.wait_all_from(1.0).unwrap(), 4.0);
        assert_eq!(g.wait_all_from(9.0).unwrap(), 9.0);
    }

    #[test]
    fn op_kinds_map_to_traffic_classes_and_groups_carry_qos() {
        assert_eq!(OpKind::Repair.traffic_class(), TrafficClass::Repair);
        assert_eq!(OpKind::Drain.traffic_class(), TrafficClass::Repair);
        assert_eq!(OpKind::Migrate.traffic_class(), TrafficClass::Migration);
        assert_eq!(OpKind::Rebalance.traffic_class(), TrafficClass::Migration);
        assert_eq!(OpKind::ObjWrite.traffic_class(), TrafficClass::Foreground);
        assert_eq!(OpKind::Tx.traffic_class(), TrafficClass::Foreground);
        let g = OpGroup::with_qos(QosConfig::default());
        assert!(g.sched_ref().qos().active());
        assert!(!OpGroup::new().sched_ref().qos().active(), "pre-QoS default");
    }

    #[test]
    fn extent_accessors() {
        let e = Extent::new(4096, 8192);
        assert_eq!(e.end(), 12288);
    }

    #[test]
    fn groups_sharing_one_scheduler_do_not_see_each_others_completions() {
        // the ISSUE 7 satellite fix: before epochs, a second group
        // draining the SAME scheduler inherited the first group's
        // frontiers — wait_all_from(now) returned the OTHER group's
        // completion and its frontier table listed foreign shards.
        use crate::sim::device::{Access, Device, DeviceProfile, IoOp};
        let mut devs = vec![
            Device::new(DeviceProfile::smr(1 << 30)),
            Device::new(DeviceProfile::ssd(1 << 30)),
        ];
        // group 1 adopts the shared scheduler and parks a LONG write
        // on the smr shard
        let mut g1 = OpGroup::adopt(IoScheduler::new(), 0.0);
        let a = g1.add(OpKind::ObjWrite);
        g1.op_mut(a).unwrap().launch(0.0).unwrap();
        g1.sched().submit(0, 0.0, 1 << 22, IoOp::Write, Access::Seq);
        let t_long = g1.sched().drain(&mut devs);
        g1.op_mut(a).unwrap().complete(t_long).unwrap();
        assert_eq!(g1.wait_all_from(0.0).unwrap(), t_long);
        // group 2 adopts the SAME scheduler concurrently (epoch opens
        // at time 0, while the smr shard is still busy) and touches
        // only the ssd shard
        let mut g2 = OpGroup::adopt(g1.release(), 0.0);
        let b = g2.add(OpKind::ObjWrite);
        g2.op_mut(b).unwrap().launch(0.0).unwrap();
        g2.sched().submit(1, 0.0, 4096, IoOp::Write, Access::Seq);
        let t_short = g2.sched().drain(&mut devs);
        g2.op_mut(b).unwrap().complete(t_short).unwrap();
        assert!(t_short < t_long);
        // the pinned fix: group 2 waits on ITS submissions only, and
        // its frontier table does not list group 1's smr shard
        assert_eq!(g2.wait_all_from(0.0).unwrap(), t_short);
        assert_eq!(g2.sched_ref().frontiers(), vec![(1, t_short)]);
        assert!(g2
            .sched_ref()
            .qos_report()
            .iter()
            .all(|r| r.device == 1));
    }

    #[test]
    fn group_wait_propagates_failure() {
        let mut g = OpGroup::new();
        let a = g.add(OpKind::FnShip);
        g.op_mut(a).unwrap().launch(0.0).unwrap();
        g.op_mut(a).unwrap().fail(1.0, "node died").unwrap();
        assert!(g.wait_all().is_err());
        assert_eq!(g.count(OpState::Failed), 1);
    }
}
