//! Config system: testbed presets matching the paper's evaluation
//! platforms (§4.1), loadable/overridable from TOML files.
//!
//! * **blackdog** — eight-core Xeon workstation, 72 GB DRAM, 2×4 TB
//!   HDD + 250 GB SSD (Fig 3a, 4a, 5-left).
//! * **tegner** — KTH cluster: 24-core Haswell nodes, 512 GB DRAM,
//!   Lustre PFS with the measured 12.3 GB/s read / 1.37 GB/s write
//!   asymmetry (Fig 3b, 3c, 4b, 5-right).
//! * **beskow** — Cray XC40, Aries dragonfly, 32-core nodes (Fig 7).
//! * **sage_prototype** — the Jülich SAGE rack (§3.1): NVRAM + SSD +
//!   SAS + SMR tiers in enclosures with in-storage compute.

use std::path::Path;

use crate::cluster::{Cluster, EnclosureCompute};
use crate::error::Result;
use crate::sim::device::{DeviceKind, DeviceProfile};
use crate::sim::network::NetworkModel;
use crate::sim::sched::{QosConfig, TenantShares, DEFAULT_TENANT};
use crate::util::toml::TomlDoc;

/// A named testbed: DRAM + device inventory + network.
#[derive(Debug, Clone)]
pub struct Testbed {
    pub name: String,
    /// DRAM per node (page-cache capacity).
    pub dram_per_node: u64,
    /// DRAM bandwidth per node (STREAM-class), bytes/s.
    pub dram_bw: f64,
    /// Compute nodes available to applications.
    pub compute_nodes: usize,
    /// Cores per compute node.
    pub cores_per_node: usize,
    /// Storage device profiles (the storage side of the platform).
    pub storage: Vec<DeviceProfile>,
    /// Network between nodes / to storage.
    pub net: NetworkModel,
    /// In-storage compute per enclosure (SAGE prototype).
    pub enclosure_flops: f64,
    /// Repair/foreground bandwidth split (§3.2.1 repair throttling),
    /// carried onto the built cluster and enforced by every Clovis op
    /// group. Overridable from TOML (`[qos] repair_share = 0.5`).
    pub qos: QosConfig,
    /// Tenant weights pre-registered on the built cluster (ISSUE 7
    /// multi-tenant plane). Empty (every preset) = single-tenant: the
    /// plane stays inactive until `Client::register_tenant`. From
    /// TOML: `[tenants] weights = [3.0, 1.0]` — the first entry is
    /// the default tenant's weight, each further entry registers a
    /// new tenant.
    pub tenant_weights: Vec<f64>,
}

impl Testbed {
    /// Blackdog workstation (§4.1).
    pub fn blackdog() -> Testbed {
        Testbed {
            name: "blackdog".into(),
            dram_per_node: 72 << 30,
            dram_bw: 11.0e9, // measured-class STREAM triad for E5-2609v2
            compute_nodes: 1,
            cores_per_node: 8,
            storage: vec![
                DeviceProfile::hdd(4 << 40),
                DeviceProfile::hdd(4 << 40),
                DeviceProfile::ssd(250 << 30),
            ],
            net: NetworkModel::loopback(),
            enclosure_flops: 2e10,
            qos: QosConfig::default(),
            tenant_weights: Vec::new(),
        }
    }

    /// Tegner + Lustre (§4.1). 24 OSTs model the shared PFS.
    pub fn tegner() -> Testbed {
        let n_ost = 24;
        Testbed {
            name: "tegner".into(),
            dram_per_node: 512 << 30,
            dram_bw: 55.0e9, // dual-socket Haswell
            compute_nodes: 6,
            cores_per_node: 24,
            storage: (0..n_ost)
                .map(|_| DeviceProfile::lustre_ost(32 << 40, n_ost))
                .collect(),
            net: NetworkModel::tengig(),
            enclosure_flops: 5e10,
            qos: QosConfig::default(),
            tenant_weights: Vec::new(),
        }
    }

    /// Beskow Cray XC40 (§4.2): 1,676 nodes of 32 cores; Lustre-class
    /// PFS sized for a Cray (more OSTs, higher aggregate).
    pub fn beskow() -> Testbed {
        let n_ost = 48;
        Testbed {
            name: "beskow".into(),
            dram_per_node: 64 << 30,
            dram_bw: 60.0e9,
            compute_nodes: 1676,
            cores_per_node: 32,
            storage: (0..n_ost)
                .map(|_| {
                    // Beskow-class scratch: ~40 GB/s read, ~30 GB/s write
                    DeviceProfile {
                        kind: DeviceKind::LustreOst,
                        read_bw: 40e9 / n_ost as f64,
                        write_bw: 30e9 / n_ost as f64,
                        latency: 0.4e-3,
                        seek: 0.0,
                        capacity: 64 << 40,
                    }
                })
                .collect(),
            net: NetworkModel::aries(),
            enclosure_flops: 1e11,
            qos: QosConfig::default(),
            tenant_weights: Vec::new(),
        }
    }

    /// The SAGE prototype rack at Jülich (§3.1): four storage tiers in
    /// compute-capable enclosures on FDR InfiniBand.
    pub fn sage_prototype() -> Testbed {
        let mut storage = Vec::new();
        // Tier-1: NVRAM pools (2 enclosures x 2 devices)
        for _ in 0..4 {
            storage.push(DeviceProfile::nvram(768 << 30));
        }
        // Tier-2: flash (8 SSDs)
        for _ in 0..8 {
            storage.push(DeviceProfile::ssd(2 << 40));
        }
        // Tier-3: SAS (8 HDDs)
        for _ in 0..8 {
            storage.push(DeviceProfile::hdd(8 << 40));
        }
        // Tier-4: SMR archive (4 drives)
        for _ in 0..4 {
            storage.push(DeviceProfile::smr(14 << 40));
        }
        Testbed {
            name: "sage_prototype".into(),
            dram_per_node: 128 << 30,
            dram_bw: 40.0e9,
            compute_nodes: 16,
            cores_per_node: 16,
            storage,
            net: NetworkModel::fdr_infiniband(),
            enclosure_flops: 5e10,
            qos: QosConfig::default(),
            tenant_weights: Vec::new(),
        }
    }

    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<Testbed> {
        match name {
            "blackdog" => Some(Self::blackdog()),
            "tegner" => Some(Self::tegner()),
            "beskow" => Some(Self::beskow()),
            "sage_prototype" | "sage" => Some(Self::sage_prototype()),
            _ => None,
        }
    }

    /// Load a testbed from a TOML file: `base = "<preset>"` plus
    /// overrides (`dram_per_node`, `compute_nodes`, tier sections).
    pub fn from_toml(path: &Path) -> Result<Testbed> {
        let text = std::fs::read_to_string(path)?;
        let doc = TomlDoc::parse(&text)?;
        let base = doc.get_str("", "base", "sage_prototype");
        let mut tb = Self::by_name(&base).ok_or_else(|| {
            crate::error::SageError::Config(format!("unknown base testbed {base}"))
        })?;
        tb.name = doc.get_str("", "name", &tb.name);
        tb.dram_per_node =
            doc.get_bytes("", "dram_per_node", tb.dram_per_node);
        tb.compute_nodes =
            doc.get_i64("", "compute_nodes", tb.compute_nodes as i64) as usize;
        tb.cores_per_node =
            doc.get_i64("", "cores_per_node", tb.cores_per_node as i64) as usize;
        // optional QoS split overrides: [qos] repair_share/migration_share
        tb.qos.repair_share =
            doc.get_f64("qos", "repair_share", tb.qos.repair_share);
        tb.qos.migration_share =
            doc.get_f64("qos", "migration_share", tb.qos.migration_share);
        // [qos] work_conserving = true opts the cluster into headroom
        // borrowing (ISSUE 10); absent ⇒ the static split, bit-exact
        if let Some(v) = doc.get("qos", "work_conserving") {
            if let Some(b) = v.as_bool() {
                tb.qos.work_conserving = b;
            }
        }
        // optional tenant plane: [tenants] weights = [3.0, 1.0]
        if let Some(crate::util::toml::TomlValue::Arr(items)) =
            doc.get("tenants", "weights")
        {
            tb.tenant_weights =
                items.iter().filter_map(|v| v.as_f64()).collect();
        }
        // optional extra tier sections: [tier.<kind>] count=, capacity=
        for kind in ["nvram", "ssd", "hdd", "smr"] {
            let sec = format!("tier.{kind}");
            let count = doc.get_i64(&sec, "count", 0);
            if count > 0 {
                let cap = doc.get_bytes(&sec, "capacity", 1 << 40);
                for _ in 0..count {
                    tb.storage.push(match kind {
                        "nvram" => DeviceProfile::nvram(cap),
                        "ssd" => DeviceProfile::ssd(cap),
                        "hdd" => DeviceProfile::hdd(cap),
                        _ => DeviceProfile::smr(cap),
                    });
                }
            }
        }
        Ok(tb)
    }

    /// Materialize the cluster: one storage node per 4 devices
    /// (enclosure granularity), each with in-storage compute, carrying
    /// this testbed's QoS split.
    pub fn build_cluster(&self) -> Cluster {
        let mut c = Cluster::new(self.net.clone());
        c.qos = self.qos;
        if let Some((first, rest)) = self.tenant_weights.split_first() {
            let mut shares = TenantShares::single();
            shares.set_weight(DEFAULT_TENANT, *first);
            for &w in rest {
                shares.register(w);
            }
            c.tenants = shares;
        }
        for chunk in self.storage.chunks(4) {
            c.add_node(
                chunk.to_vec(),
                EnclosureCompute {
                    cores: self.cores_per_node as u32,
                    flops: self.enclosure_flops,
                },
            );
        }
        c
    }

    /// DRAM device profile (page-cache backing for PGAS windows).
    pub fn dram(&self) -> DeviceProfile {
        DeviceProfile::dram(self.dram_per_node, self.dram_bw)
    }

    /// Total ranks this testbed can host.
    pub fn max_ranks(&self) -> usize {
        self.compute_nodes * self.cores_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build() {
        for name in ["blackdog", "tegner", "beskow", "sage_prototype"] {
            let tb = Testbed::by_name(name).unwrap();
            let c = tb.build_cluster();
            assert!(!c.devices.is_empty(), "{name}");
            assert!(!c.nodes.is_empty(), "{name}");
        }
        assert!(Testbed::by_name("nope").is_none());
    }

    #[test]
    fn prototype_has_all_tiers() {
        let tb = Testbed::sage_prototype();
        let c = tb.build_cluster();
        for kind in [
            DeviceKind::Nvram,
            DeviceKind::Ssd,
            DeviceKind::Hdd,
            DeviceKind::Smr,
        ] {
            assert!(
                c.devices.iter().any(|d| d.profile.kind == kind),
                "{kind:?} missing"
            );
        }
    }

    #[test]
    fn beskow_scale() {
        let tb = Testbed::beskow();
        assert!(tb.max_ranks() >= 8192, "Fig 7 needs 8192 ranks");
    }

    #[test]
    fn toml_overrides() {
        let tmp = std::env::temp_dir().join("sage_tb_test.toml");
        std::fs::write(
            &tmp,
            "base = \"blackdog\"\nname = \"custom\"\ncompute_nodes = 2\n\n[tier.nvram]\ncount = 2\ncapacity = \"1GiB\"\n",
        )
        .unwrap();
        let tb = Testbed::from_toml(&tmp).unwrap();
        assert_eq!(tb.name, "custom");
        assert_eq!(tb.compute_nodes, 2);
        assert_eq!(
            tb.storage
                .iter()
                .filter(|p| p.kind == DeviceKind::Nvram)
                .count(),
            2
        );
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn tenant_weights_from_toml_reach_the_cluster() {
        // presets stay single-tenant (plane inactive, schedules
        // bit-identical to the per-class QoS plane)
        let c = Testbed::sage_prototype().build_cluster();
        assert!(!c.tenants.active());
        // [tenants] weights pre-register a shared cluster's tenants
        let tmp = std::env::temp_dir().join("sage_tb_tenants_test.toml");
        std::fs::write(
            &tmp,
            "base = \"sage_prototype\"\n\n[tenants]\nweights = [3.0, 1.0]\n",
        )
        .unwrap();
        let tb = Testbed::from_toml(&tmp).unwrap();
        assert_eq!(tb.tenant_weights, vec![3.0, 1.0]);
        let c = tb.build_cluster();
        assert!(c.tenants.active());
        assert_eq!(c.tenants.len(), 2);
        assert!((c.tenants.share(0) - 0.75).abs() < 1e-12);
        assert!((c.tenants.share(1) - 0.25).abs() < 1e-12);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn qos_split_defaults_and_toml_override_reach_the_cluster() {
        // presets carry the sane split onto the built cluster
        let c = Testbed::sage_prototype().build_cluster();
        assert_eq!(c.qos, QosConfig::default());
        assert!(c.qos.active());
        // TOML can retune (or disable) the split
        let tmp = std::env::temp_dir().join("sage_tb_qos_test.toml");
        std::fs::write(
            &tmp,
            "base = \"sage_prototype\"\n\n[qos]\nrepair_share = 1.0\nmigration_share = 0.5\n",
        )
        .unwrap();
        let tb = Testbed::from_toml(&tmp).unwrap();
        assert_eq!(tb.qos.repair_share, 1.0);
        assert_eq!(tb.qos.migration_share, 0.5);
        assert!(!tb.qos.work_conserving, "absent key keeps the static split");
        assert!(tb.build_cluster().qos.active(), "migration still capped");
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn qos_work_conserving_toml_opt_in_reaches_the_cluster() {
        // presets stay static — the borrow plane is strictly opt-in
        assert!(!Testbed::sage_prototype().qos.work_conserving);
        let tmp = std::env::temp_dir().join("sage_tb_qos_wc_test.toml");
        std::fs::write(
            &tmp,
            "base = \"sage_prototype\"\n\n[qos]\nwork_conserving = true\n",
        )
        .unwrap();
        let tb = Testbed::from_toml(&tmp).unwrap();
        assert!(tb.qos.work_conserving);
        // shares untouched by the flag
        assert_eq!(tb.qos.repair_share, QosConfig::default().repair_share);
        let c = tb.build_cluster();
        assert!(c.qos.work_conserving, "flag reaches the built cluster");
        assert!(c.qos.active());
        std::fs::remove_file(&tmp).ok();
    }
}
