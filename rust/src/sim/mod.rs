//! Simulation substrate: deterministic virtual time, storage-device
//! models, the sharded op scheduler, an OS page-cache model and a
//! network model (the §4.1 testbeds' hardware side; see
//! ARCHITECTURE.md).
//!
//! The SAGE reproduction separates **real data operations** (the object
//! store really stores bytes, parity is really computed, the DHT really
//! hashes) from **time accounting**, which is carried in virtual time by
//! these models. Benchmarks report virtual time, so results have the
//! *shape* of the paper's testbeds (Blackdog, Tegner/Lustre, Beskow)
//! without the hardware. See DESIGN.md §6 Substitutions.

pub mod cache;
pub mod clock;
pub mod device;
pub mod network;
pub mod rng;
pub mod qos_static_oracle;
pub mod sched;
pub mod sched_oracle;

pub use cache::PageCache;
pub use clock::{RankClocks, SimTime};
pub use device::{Device, DeviceKind, DeviceProfile};
pub use network::NetworkModel;
pub use rng::SimRng;
pub use sched::{IoScheduler, Ticket};
