//! Virtual time: per-rank logical clocks.
//!
//! SAGE benchmarks simulate up to 8192 MPI ranks in one process. Each
//! rank owns a logical clock (seconds, f64); local work advances it,
//! synchronization points (barriers, collectives, stream handshakes)
//! merge clocks. This is conservative parallel-discrete-event
//! simulation specialized to the bulk-synchronous structure of the
//! paper's workloads.

/// Seconds of virtual time.
pub type SimTime = f64;

/// Clocks for a set of simulated ranks.
#[derive(Debug, Clone)]
pub struct RankClocks {
    t: Vec<SimTime>,
}

impl RankClocks {
    /// `n` ranks, all starting at t=0.
    pub fn new(n: usize) -> Self {
        RankClocks { t: vec![0.0; n] }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.t.len()
    }

    /// True if there are no ranks (degenerate).
    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// Current time of `rank`.
    pub fn now(&self, rank: usize) -> SimTime {
        self.t[rank]
    }

    /// Advance `rank` by `dt` seconds of local work; returns new time.
    pub fn advance(&mut self, rank: usize, dt: SimTime) -> SimTime {
        debug_assert!(dt >= 0.0, "negative dt {dt}");
        self.t[rank] += dt;
        self.t[rank]
    }

    /// Set `rank`'s clock to at least `t` (e.g. after waiting on a
    /// device or a message that completes at absolute time `t`).
    pub fn wait_until(&mut self, rank: usize, t: SimTime) -> SimTime {
        if t > self.t[rank] {
            self.t[rank] = t;
        }
        self.t[rank]
    }

    /// Barrier across all ranks: everyone advances to the max clock
    /// (plus `overhead` for the barrier itself). Returns the new time.
    pub fn barrier(&mut self, overhead: SimTime) -> SimTime {
        let max = self.max() + overhead;
        for t in &mut self.t {
            *t = max;
        }
        max
    }

    /// Barrier over a subset of ranks.
    pub fn barrier_subset(&mut self, ranks: &[usize], overhead: SimTime) -> SimTime {
        let max = ranks
            .iter()
            .map(|&r| self.t[r])
            .fold(0.0f64, f64::max)
            + overhead;
        for &r in ranks {
            self.t[r] = max;
        }
        max
    }

    /// Maximum (makespan) across ranks — the reported execution time.
    pub fn max(&self) -> SimTime {
        self.t.iter().copied().fold(0.0, f64::max)
    }

    /// Minimum across ranks.
    pub fn min(&self) -> SimTime {
        self.t.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Mean across ranks.
    pub fn mean(&self) -> SimTime {
        if self.t.is_empty() {
            0.0
        } else {
            self.t.iter().sum::<f64>() / self.t.len() as f64
        }
    }

    /// Reset all clocks to zero (new measurement phase).
    pub fn reset(&mut self) {
        for t in &mut self.t {
            *t = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_and_barrier() {
        let mut c = RankClocks::new(4);
        c.advance(0, 1.0);
        c.advance(1, 3.0);
        assert_eq!(c.max(), 3.0);
        let t = c.barrier(0.5);
        assert_eq!(t, 3.5);
        for r in 0..4 {
            assert_eq!(c.now(r), 3.5);
        }
    }

    #[test]
    fn wait_until_monotone() {
        let mut c = RankClocks::new(1);
        c.advance(0, 2.0);
        c.wait_until(0, 1.0); // no-op: already past
        assert_eq!(c.now(0), 2.0);
        c.wait_until(0, 5.0);
        assert_eq!(c.now(0), 5.0);
    }

    #[test]
    fn subset_barrier_leaves_others() {
        let mut c = RankClocks::new(3);
        c.advance(2, 9.0);
        c.barrier_subset(&[0, 1], 0.0);
        assert_eq!(c.now(0), 0.0);
        assert_eq!(c.now(2), 9.0);
    }
}
