//! OS page-cache model.
//!
//! The paper's key observation for MPI storage windows (§4.1) is that
//! "the OS page cache and buffering of the parallel file system act as
//! automatic caches": memory-mapped storage performs close to DRAM as
//! long as the working set fits and writeback keeps up. This model
//! captures exactly that: an LRU of super-pages with dirty tracking,
//! a dirty-ratio writeback threshold, and explicit `sync` flushes.
//!
//! Time accounting is done by the caller: `read`/`write` return how many
//! bytes hit DRAM vs how many must touch the backing device.

use std::collections::BTreeMap;

/// Result of a cache access: how many bytes were served where.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheOutcome {
    /// Bytes served from / absorbed by DRAM.
    pub hit: u64,
    /// Bytes that must be read from the backing device.
    pub miss: u64,
    /// Dirty bytes that eviction / throttling forces to the device now.
    pub writeback: u64,
}

/// Page-granular LRU cache with dirty tracking.
#[derive(Debug)]
pub struct PageCache {
    /// Bytes per cached page (super-pages keep the map small).
    page_size: u64,
    /// Capacity in bytes.
    capacity: u64,
    /// Start writeback beyond this fraction of dirty bytes
    /// (vm.dirty_ratio analog).
    dirty_ratio: f64,
    /// Absolute dirty cap in bytes (llite osc.max_dirty_mb analog);
    /// effective limit is min(ratio * capacity, cap).
    dirty_cap: u64,
    /// page id -> (lru tick, dirty)
    pages: BTreeMap<u64, (u64, bool)>,
    tick: u64,
    dirty_bytes: u64,
}

impl PageCache {
    /// A cache of `capacity` bytes with `page_size`-byte pages.
    pub fn new(capacity: u64, page_size: u64) -> Self {
        PageCache {
            page_size: page_size.max(1),
            capacity,
            dirty_ratio: 0.4,
            dirty_cap: u64::MAX,
            pages: BTreeMap::new(),
            tick: 0,
            dirty_bytes: 0,
        }
    }

    /// Configure the dirty-writeback threshold (0..1).
    pub fn with_dirty_ratio(mut self, r: f64) -> Self {
        self.dirty_ratio = r.clamp(0.0, 1.0);
        self
    }

    /// Configure an absolute dirty cap (PFS client caches throttle at a
    /// fixed per-client budget regardless of DRAM size).
    pub fn with_dirty_cap(mut self, cap: u64) -> Self {
        self.dirty_cap = cap.max(self.page_size);
        self
    }

    fn page_range(&self, offset: u64, len: u64) -> (u64, u64) {
        let first = offset / self.page_size;
        let last = (offset + len.max(1) - 1) / self.page_size;
        (first, last)
    }

    fn max_pages(&self) -> usize {
        (self.capacity / self.page_size).max(1) as usize
    }

    /// Evict LRU pages until under capacity; returns dirty bytes that
    /// must be written back.
    fn evict(&mut self) -> u64 {
        let mut writeback = 0;
        while self.pages.len() > self.max_pages() {
            // find LRU page (linear scan is fine: eviction is rare and
            // the map is bounded by capacity / page_size)
            let (&victim, &(_, dirty)) = self
                .pages
                .iter()
                .min_by_key(|(_, (tick, _))| *tick)
                .unwrap();
            self.pages.remove(&victim);
            if dirty {
                writeback += self.page_size;
                self.dirty_bytes = self.dirty_bytes.saturating_sub(self.page_size);
            }
        }
        writeback
    }

    /// Read `len` bytes at `offset`: returns hit/miss/writeback split.
    pub fn read(&mut self, offset: u64, len: u64) -> CacheOutcome {
        let (first, last) = self.page_range(offset, len);
        let mut out = CacheOutcome::default();
        for p in first..=last {
            self.tick += 1;
            let span = self.page_span_bytes(p, offset, len);
            if let Some(e) = self.pages.get_mut(&p) {
                e.0 = self.tick;
                out.hit += span;
            } else {
                self.pages.insert(p, (self.tick, false));
                out.miss += span;
            }
        }
        out.writeback = self.evict();
        out
    }

    /// Write `len` bytes at `offset` (write-back: absorbed by DRAM,
    /// marked dirty). Throttles via `writeback` when the dirty ratio is
    /// exceeded — the caller charges device time for those bytes.
    pub fn write(&mut self, offset: u64, len: u64) -> CacheOutcome {
        let (first, last) = self.page_range(offset, len);
        let mut out = CacheOutcome::default();
        for p in first..=last {
            self.tick += 1;
            let span = self.page_span_bytes(p, offset, len);
            match self.pages.get_mut(&p) {
                Some(e) => {
                    e.0 = self.tick;
                    if !e.1 {
                        e.1 = true;
                        self.dirty_bytes += self.page_size;
                    }
                }
                None => {
                    self.pages.insert(p, (self.tick, true));
                    self.dirty_bytes += self.page_size;
                }
            }
            out.hit += span;
        }
        out.writeback = self.evict();
        // dirty throttling: flush down to the threshold
        let limit =
            ((self.capacity as f64 * self.dirty_ratio) as u64).min(self.dirty_cap);
        if self.dirty_bytes > limit {
            let excess = self.dirty_bytes - limit;
            out.writeback += excess;
            self.clean_pages(excess);
        }
        out
    }

    /// `msync` / `win_sync`: flush all dirty pages; returns bytes to
    /// write to the device.
    pub fn sync(&mut self) -> u64 {
        let dirty = self.dirty_bytes;
        for e in self.pages.values_mut() {
            e.1 = false;
        }
        self.dirty_bytes = 0;
        dirty
    }

    /// Drop everything (e.g. after free).
    pub fn clear(&mut self) {
        self.pages.clear();
        self.dirty_bytes = 0;
    }

    /// Current dirty byte count.
    pub fn dirty(&self) -> u64 {
        self.dirty_bytes
    }

    /// Resident bytes.
    pub fn resident(&self) -> u64 {
        self.pages.len() as u64 * self.page_size
    }

    fn clean_pages(&mut self, mut bytes: u64) {
        // mark oldest dirty pages clean until `bytes` are flushed
        let mut dirty: Vec<(u64, u64)> = self
            .pages
            .iter()
            .filter(|(_, (_, d))| *d)
            .map(|(&p, &(t, _))| (t, p))
            .collect();
        dirty.sort_unstable();
        for (_, p) in dirty {
            if bytes == 0 {
                break;
            }
            if let Some(e) = self.pages.get_mut(&p) {
                e.1 = false;
                self.dirty_bytes = self.dirty_bytes.saturating_sub(self.page_size);
                bytes = bytes.saturating_sub(self.page_size);
            }
        }
    }

    fn page_span_bytes(&self, page: u64, offset: u64, len: u64) -> u64 {
        let pstart = page * self.page_size;
        let pend = pstart + self.page_size;
        let start = offset.max(pstart);
        let end = (offset + len).min(pend);
        end.saturating_sub(start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_read_misses_then_hits() {
        let mut c = PageCache::new(1 << 20, 4096);
        let o1 = c.read(0, 8192);
        assert_eq!(o1.miss, 8192);
        assert_eq!(o1.hit, 0);
        let o2 = c.read(0, 8192);
        assert_eq!(o2.hit, 8192);
        assert_eq!(o2.miss, 0);
    }

    #[test]
    fn writes_absorbed_until_sync() {
        let mut c = PageCache::new(1 << 20, 4096);
        let o = c.write(0, 65536);
        assert_eq!(o.hit, 65536);
        assert_eq!(o.writeback, 0);
        assert_eq!(c.dirty(), 65536);
        assert_eq!(c.sync(), 65536);
        assert_eq!(c.dirty(), 0);
    }

    #[test]
    fn dirty_ratio_throttles() {
        let mut c = PageCache::new(100 * 4096, 4096).with_dirty_ratio(0.1);
        let mut wb = 0;
        for i in 0..50 {
            wb += c.write(i * 4096, 4096).writeback;
        }
        assert!(wb > 0, "expected throttling writeback");
        assert!(c.dirty() <= 11 * 4096);
    }

    #[test]
    fn eviction_bounded_and_flushes_dirty() {
        let mut c = PageCache::new(10 * 4096, 4096);
        let mut wb = 0;
        for i in 0..100 {
            wb += c.write(i * 4096, 4096).writeback;
        }
        assert!(c.resident() <= 10 * 4096);
        assert!(wb >= 80 * 4096, "evictions must write back dirty pages");
    }

    #[test]
    fn working_set_larger_than_cache_keeps_missing() {
        let mut c = PageCache::new(10 * 4096, 4096);
        // stream over 100 pages twice: second pass still misses (LRU)
        for _ in 0..2 {
            for i in 0..100 {
                c.read(i * 4096, 4096);
            }
        }
        let o = c.read(0, 4096);
        assert_eq!(o.miss, 4096);
    }

    #[test]
    fn partial_page_spans() {
        let mut c = PageCache::new(1 << 20, 4096);
        let o = c.read(100, 50);
        assert_eq!(o.miss, 50);
    }
}
