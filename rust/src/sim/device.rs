//! Storage-device models for the SAGE tiers (§3.1).
//!
//! Each device is a queued server in virtual time: an I/O submitted at
//! time `t` starts at `max(t, busy_until)`, runs for a service time
//! derived from the profile (latency + size/bandwidth + seek for
//! rotational random access), and pushes `busy_until` forward. This
//! yields contention when many ranks share a device — the effect behind
//! Fig 3(c), Fig 5 and Fig 7.
//!
//! Profiles are calibrated to the paper's §4.1 testbeds (Blackdog HDD /
//! SSD, Tegner Lustre with its asymmetric 12.3 GB/s read vs 1.37 GB/s
//! write) and §3.1 tier descriptions (3D XPoint NVRAM, SAS, SMR).

use super::clock::SimTime;

/// Storage technology classes in the SAGE hierarchy. `Ord` follows
/// declaration order (fastest tier first) so `BTreeMap<DeviceKind, _>`
/// folds walk the hierarchy top-down deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceKind {
    /// DRAM (memory windows / page-cache hits).
    Dram,
    /// Tier-1: NVRAM (Intel 3D XPoint / emulated NVDIMM).
    Nvram,
    /// Tier-2: flash SSD.
    Ssd,
    /// Tier-3: SAS performance HDD.
    Hdd,
    /// Tier-4: archival SMR / SATA.
    Smr,
    /// Lustre OST (parallel file system server, Tegner).
    LustreOst,
}

impl DeviceKind {
    /// Tier index in the SAGE hierarchy (lower = faster).
    pub fn tier(self) -> u8 {
        match self {
            DeviceKind::Dram => 0,
            DeviceKind::Nvram => 1,
            DeviceKind::Ssd => 2,
            DeviceKind::Hdd | DeviceKind::LustreOst => 3,
            DeviceKind::Smr => 4,
        }
    }
}

/// Read or write.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoOp {
    Read,
    Write,
}

/// Sequential or random access pattern (drives seek costs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Seq,
    Random,
}

/// Performance/capacity description of a device.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub kind: DeviceKind,
    /// Sequential read bandwidth, bytes/s.
    pub read_bw: f64,
    /// Sequential write bandwidth, bytes/s.
    pub write_bw: f64,
    /// Fixed per-I/O latency, seconds.
    pub latency: f64,
    /// Extra cost per *random* I/O (head seek / band rewrite), seconds.
    pub seek: f64,
    /// Capacity in bytes.
    pub capacity: u64,
}

impl DeviceProfile {
    /// DRAM — calibrated to a STREAM-class per-socket copy bandwidth.
    pub fn dram(capacity: u64, bw: f64) -> Self {
        DeviceProfile {
            kind: DeviceKind::Dram,
            read_bw: bw,
            write_bw: bw,
            latency: 100e-9,
            seek: 0.0,
            capacity,
        }
    }

    /// Tier-1 NVRAM (3D XPoint class).
    pub fn nvram(capacity: u64) -> Self {
        DeviceProfile {
            kind: DeviceKind::Nvram,
            read_bw: 2.4e9,
            write_bw: 2.0e9,
            latency: 10e-6,
            seek: 0.0,
            capacity,
        }
    }

    /// Tier-2 SATA flash (Samsung 850 EVO class, Blackdog's SSD).
    pub fn ssd(capacity: u64) -> Self {
        DeviceProfile {
            kind: DeviceKind::Ssd,
            read_bw: 540e6,
            write_bw: 520e6,
            latency: 60e-6,
            seek: 0.0,
            capacity,
        }
    }

    /// Tier-3 SAS / enterprise SATA HDD (WD4000F9YZ class, Blackdog).
    pub fn hdd(capacity: u64) -> Self {
        DeviceProfile {
            kind: DeviceKind::Hdd,
            read_bw: 150e6,
            write_bw: 140e6,
            latency: 4e-3,
            seek: 8e-3,
            capacity,
        }
    }

    /// Tier-4 archival SMR: decent reads, poor random writes.
    pub fn smr(capacity: u64) -> Self {
        DeviceProfile {
            kind: DeviceKind::Smr,
            read_bw: 180e6,
            write_bw: 45e6,
            latency: 12e-3,
            seek: 15e-3,
            capacity,
        }
    }

    /// One Lustre OST slice of Tegner's PFS. The paper measured the
    /// *aggregate* asymmetry read 12,308 MB/s vs write 1,374 MB/s
    /// (Fig 3b); per-OST numbers are aggregate / n_ost.
    pub fn lustre_ost(capacity: u64, n_ost: usize) -> Self {
        DeviceProfile {
            kind: DeviceKind::LustreOst,
            read_bw: 12.308e9 / n_ost as f64,
            write_bw: 1.374e9 / n_ost as f64,
            latency: 0.15e-3,
            seek: 0.0,
            capacity,
        }
    }

    /// Service time (no queueing) for one I/O.
    pub fn service_time(&self, size: u64, op: IoOp, access: Access) -> SimTime {
        let bw = match op {
            IoOp::Read => self.read_bw,
            IoOp::Write => self.write_bw,
        };
        let seek = match access {
            Access::Seq => 0.0,
            Access::Random => self.seek,
        };
        self.latency + seek + size as f64 / bw
    }
}

/// A device instance with queueing state in virtual time.
#[derive(Debug, Clone)]
pub struct Device {
    pub profile: DeviceProfile,
    /// Bytes allocated on this device.
    pub used: u64,
    /// Virtual time until which the device is busy.
    pub busy_until: SimTime,
    /// Total bytes read / written (ADDB counters).
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Failed devices reject I/O; the HA subsystem repairs them.
    pub failed: bool,
}

impl Device {
    pub fn new(profile: DeviceProfile) -> Self {
        Device {
            profile,
            used: 0,
            busy_until: 0.0,
            bytes_read: 0,
            bytes_written: 0,
            failed: false,
        }
    }

    /// Submit an I/O at virtual time `now`; returns completion time and
    /// advances the queue. Panics in debug if the device has failed —
    /// callers must route around failures (SNS degraded mode).
    pub fn io(&mut self, now: SimTime, size: u64, op: IoOp, access: Access) -> SimTime {
        debug_assert!(!self.failed, "I/O to failed device");
        let start = now.max(self.busy_until);
        let end = start + self.profile.service_time(size, op, access);
        self.busy_until = end;
        match op {
            IoOp::Read => self.bytes_read += size,
            IoOp::Write => self.bytes_written += size,
        }
        end
    }

    /// Submit a device-contiguous run of `count` back-to-back I/Os of
    /// `size` bytes each as ONE accounting call (§Perf: the sharded
    /// scheduler coalesces per-shard runs so a striped batch costs one
    /// call per device instead of one per unit). Virtual-time result
    /// is identical to `count` chained [`Device::io`] calls: the run
    /// starts at `max(now, busy_until)` and occupies the queue for
    /// `count` service times.
    pub fn io_run(
        &mut self,
        now: SimTime,
        count: u64,
        size: u64,
        op: IoOp,
        access: Access,
    ) -> SimTime {
        debug_assert!(!self.failed, "I/O run to failed device");
        if count == 0 {
            return now.max(self.busy_until);
        }
        let start = now.max(self.busy_until);
        let end =
            start + count as f64 * self.profile.service_time(size, op, access);
        self.busy_until = end;
        match op {
            IoOp::Read => self.bytes_read += count * size,
            IoOp::Write => self.bytes_written += count * size,
        }
        end
    }

    /// Account a run whose schedule was computed by a QoS-aware
    /// scheduler lane (`sim::sched` per-class frontiers): bytes are
    /// recorded and the queue tail advances to `end` if later, but no
    /// FIFO queueing is imposed here — the scheduler's class frontiers
    /// own the start-time decision. Later schedulers observing
    /// `busy_until` still queue behind everything committed.
    pub fn commit_run(&mut self, end: SimTime, count: u64, size: u64, op: IoOp) {
        debug_assert!(!self.failed, "I/O run to failed device");
        self.busy_until = self.busy_until.max(end);
        match op {
            IoOp::Read => self.bytes_read += count * size,
            IoOp::Write => self.bytes_written += count * size,
        }
    }

    /// Remaining capacity.
    pub fn free(&self) -> u64 {
        self.profile.capacity.saturating_sub(self.used)
    }

    /// Fraction of capacity in use.
    pub fn utilization(&self) -> f64 {
        self.used as f64 / self.profile.capacity.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_time_scales_with_size() {
        let p = DeviceProfile::ssd(1 << 40);
        let t1 = p.service_time(1 << 20, IoOp::Read, Access::Seq);
        let t2 = p.service_time(1 << 21, IoOp::Read, Access::Seq);
        assert!(t2 > t1);
        // dominated by transfer for large I/O: roughly 2x
        assert!((t2 / t1 - 2.0).abs() < 0.1);
    }

    #[test]
    fn random_hdd_pays_seek() {
        let p = DeviceProfile::hdd(1 << 40);
        let seq = p.service_time(4096, IoOp::Read, Access::Seq);
        let rnd = p.service_time(4096, IoOp::Read, Access::Random);
        assert!(rnd > seq + 7e-3);
    }

    #[test]
    fn queueing_serializes() {
        let mut d = Device::new(DeviceProfile::hdd(1 << 40));
        let t1 = d.io(0.0, 150_000_000, IoOp::Write, Access::Seq);
        // second I/O submitted at t=0 but queued behind the first
        let t2 = d.io(0.0, 150_000_000, IoOp::Write, Access::Seq);
        assert!(t1 > 1.0 && t2 > 2.0 * 1.0);
        assert_eq!(d.bytes_written, 300_000_000);
    }

    #[test]
    fn io_run_matches_chained_ios() {
        let mut a = Device::new(DeviceProfile::hdd(1 << 40));
        let mut b = Device::new(DeviceProfile::hdd(1 << 40));
        let mut t_chain = 0.0;
        for _ in 0..4 {
            t_chain = a.io(0.5, 1 << 20, IoOp::Write, Access::Seq);
        }
        let t_run = b.io_run(0.5, 4, 1 << 20, IoOp::Write, Access::Seq);
        assert!((t_run - t_chain).abs() < 1e-12);
        assert!((a.busy_until - b.busy_until).abs() < 1e-12);
        assert_eq!(a.bytes_written, b.bytes_written);
        // empty run is a no-op observation of the queue
        assert_eq!(b.io_run(0.0, 0, 1 << 20, IoOp::Read, Access::Seq), t_run);
        assert_eq!(b.bytes_read, 0);
    }

    #[test]
    fn lustre_asymmetry_matches_paper() {
        let p = DeviceProfile::lustre_ost(1 << 44, 1);
        // Fig 3(b): read ~12,308 MB/s, write ~1,374 MB/s
        assert!(p.read_bw / p.write_bw > 8.0);
    }

    #[test]
    fn tier_ordering() {
        assert!(DeviceKind::Nvram.tier() < DeviceKind::Ssd.tier());
        assert!(DeviceKind::Ssd.tier() < DeviceKind::Hdd.tier());
        assert!(DeviceKind::Hdd.tier() < DeviceKind::Smr.tier());
    }
}
