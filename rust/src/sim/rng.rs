//! Deterministic pseudo-random numbers (offline substitute for `rand`).
//!
//! xoshiro256** seeded via SplitMix64 — fast, high quality, and fully
//! reproducible across runs, which the benchmark harness and the
//! property-test harness both rely on.

/// Deterministic RNG (xoshiro256**).
#[derive(Debug, Clone)]
pub struct SimRng {
    s: [u64; 4],
}

impl SimRng {
    /// Seed deterministically (SplitMix64 expansion of `seed`).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        SimRng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform u64 in `[0, bound)` (Lemire reduction; bound > 0).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn gen_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with mean `mean`.
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        -mean * self.gen_f64().max(1e-300).ln()
    }

    /// Zipf-like rank sample over `n` items with skew `theta` in (0,1);
    /// used by HSM heat traces and the DHT key distribution. Low ranks
    /// are hot: the CDF of rank k approximates (k/n)^(1-theta), so the
    /// inverse transform is k = n * u^(1/(1-theta)).
    pub fn gen_zipf(&mut self, n: u64, theta: f64) -> u64 {
        let u = self.gen_f64();
        let k = n as f64 * u.powf(1.0 / (1.0 - theta).max(1e-6));
        (k as u64).min(n.saturating_sub(1))
    }

    /// Fill `buf` with pseudo-random bytes.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// Fork a child RNG (independent stream) for a labelled subsystem.
    pub fn fork(&mut self, label: u64) -> SimRng {
        SimRng::new(self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SimRng::new(7);
        let mut b = SimRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(SimRng::new(1).next_u64(), SimRng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = SimRng::new(3);
        for _ in 0..1000 {
            let v = r.gen_range(17);
            assert!(v < 17);
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SimRng::new(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gen_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = SimRng::new(5);
        let mut low = 0;
        for _ in 0..1000 {
            if r.gen_zipf(1000, 0.9) < 100 {
                low += 1;
            }
        }
        // with theta=0.9 the low ranks dominate
        assert!(low > 500, "low-rank hits {low}");
    }
}
